"""Quickstart: FedLite in ~40 lines.

Quantizes a batch of activations with the paper's grouped product quantizer,
shows the compression accounting, and runs one gradient-corrected split
training step on the paper's FEMNIST CNN.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core.correction import quantize_with_correction
from repro.core.quantizer import PQConfig, quantize
from repro.core.fedlite import TrainState, make_train_step
from repro.data.synthetic import make_federated_image_data
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def main():
    # --- 1. the quantizer by itself -----------------------------------------
    z = jax.random.normal(jax.random.PRNGKey(0), (20, 9216))  # B=20, d=9216
    pq = PQConfig(num_subvectors=1152, num_clusters=2)        # paper's 490x pt
    out = quantize(z, pq)
    print(f"compression ratio : {pq.compression_ratio(20, 9216):.1f}x "
          "(paper §5: 490x)")
    print(f"mean sq distortion: {float(out.distortion):.3f}")

    # --- 2. gradient correction (eq. 5) -------------------------------------
    lam = 1e-4
    zt, vjp = jax.vjp(lambda x: quantize_with_correction(x, lam, pq), z)
    (g,) = vjp(jnp.ones_like(z))
    print(f"corrected cotangent == g + λ(z − z̃): "
          f"{bool(jnp.allclose(g, 1.0 + lam * (z - zt), atol=1e-6))}")

    # --- 3. one FedLite training step ---------------------------------------
    data = make_federated_image_data(num_clients=8)
    model = FemnistCNN(pq=pq, lam=lam, client_batch=20)
    opt = sgd(10 ** -1.5)
    step = make_train_step(model, opt, donate=False)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    batch = data.sample_batch(0, jax.random.PRNGKey(1), 20)
    state, metrics = step(state, batch)
    print(f"step 1: loss={float(metrics['loss']):.3f} "
          f"ratio={metrics['pq_compression_ratio']:.0f}x")


if __name__ == "__main__":
    main()
