"""End-to-end driver: federated FedLite training on the paper's FEMNIST task.

Trains the paper's CNN (client: 2 conv layers; server: 2 dense layers, cut
at d=9216) for a few hundred rounds with cohort sampling, grouped-PQ uplink
compression and gradient correction, evaluating accuracy and cumulative
communication as it goes. Compares against the SplitFed baseline.

    PYTHONPATH=src python examples/femnist_federated_training.py \
        --rounds 300 --q 1152 --clusters 2 --lam 1e-4
"""

import argparse
import time

import jax

from repro.checkpointing import save_checkpoint
from repro.core.quantizer import PQConfig
from repro.core.split import tree_bits
from repro.data.synthetic import make_federated_image_data
from repro.federated.runtime import FederatedTrainer
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--q", type=int, default=1152)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--lam", type=float, default=1e-4)
    ap.add_argument("--cohort", type=int, default=10)
    ap.add_argument("--client-batch", type=int, default=20)
    ap.add_argument("--baseline", action="store_true",
                    help="run SplitFed (no compression) instead")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    data = make_federated_image_data(num_clients=64, seed=0)
    pq = None if args.baseline else PQConfig(
        num_subvectors=args.q, num_clusters=args.clusters, kmeans_iters=5)
    model = FemnistCNN(pq=pq, lam=args.lam, client_batch=args.client_batch)
    trainer = FederatedTrainer(model, sgd(10 ** -1.5), data,
                               cohort=args.cohort,
                               client_batch=args.client_batch,
                               quantize=not args.baseline)
    state = trainer.init_state(jax.random.PRNGKey(0))

    client_bits = tree_bits(state.params["client"])
    act_bits = 64 * 9216 * args.client_batch
    per_round = client_bits + (pq.message_bits(args.client_batch, 9216)
                               if pq else act_bits)
    eval_batch = data.eval_batch(jax.random.PRNGKey(99), 512)

    t0 = time.time()
    for r in range(args.rounds):
        state, metrics = trainer.round(state, jax.random.fold_in(
            jax.random.PRNGKey(1), r))
        if r % 25 == 0 or r == args.rounds - 1:
            acc = float(model.accuracy(state.params, eval_batch))
            mb = per_round * args.cohort * (r + 1) / 8e6
            print(f"round {r:4d}  loss={float(metrics['loss']):.4f}  "
                  f"acc={acc:.3f}  uplink={mb:8.1f} MB  "
                  f"({time.time() - t0:.0f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, args.rounds, state.params)
        print(f"saved params to {args.ckpt}")
    if pq:
        print(f"activation compression: "
              f"{pq.compression_ratio(args.client_batch, 9216):.0f}x")


if __name__ == "__main__":
    main()
