"""End-to-end driver: federated FedLite training on the paper's FEMNIST task.

Trains the paper's CNN (client: 2 conv layers; server: 2 dense layers, cut
at d=9216) for a few hundred rounds with cohort sampling, grouped-PQ uplink
compression and gradient correction, evaluating accuracy and cumulative
communication as it goes. Compares against the SplitFed baseline.

    PYTHONPATH=src python examples/femnist_federated_training.py \
        --rounds 300 --q 1152 --clusters 2 --lam 1e-4

Heterogeneous-fleet variant: dispatch the same training through the
virtual-clock scheduler over a realistic fleet and a straggler policy,
reporting measured wire bytes and simulated wall-clock:

    PYTHONPATH=src python examples/femnist_federated_training.py \
        --rounds 100 --fleet mobile --policy deadline

Downlink-compressed variant (the cut-layer gradient message through a
`core/compressors.py` codec instead of dense fp32):

    PYTHONPATH=src python examples/femnist_federated_training.py \
        --rounds 100 --fleet lognormal \
        --downlink "chain:topk(k=0.1)+scalarq(bits=8)"

Mesh-parallel cohorts (the `federated/executor.py` engine): shard each
round's client forward/backward over the ``clients`` device axis instead
of stacking on one device. On CPU, force a few host devices first:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
    PYTHONPATH=src python examples/femnist_federated_training.py \
        --rounds 100 --fleet lognormal --executor mesh

Trace-driven autoscaling (the `federated/autoscale.py` controller): run in
segments, letting the observed straggler tail / drop rate / loss slope
move (cohort, policy, downlink codec) between segments:

    PYTHONPATH=src python examples/femnist_federated_training.py \
        --rounds 100 --fleet mobile --autoscale

Telemetry: ``--emit-trace [PATH]`` records the run through the
`repro.obs` recorder — scheduler rounds on host AND virtual-clock lanes,
executor/wire/kmeans spans, the per-round byte ledger — then writes an
append-only JSONL event log (default ``femnist_trace.jsonl``) plus a
Perfetto-loadable twin (``--perfetto PATH`` to relocate; load at
https://ui.perfetto.dev). Summarize with ``python -m repro.obs <jsonl>``:

    PYTHONPATH=src python examples/femnist_federated_training.py \
        --rounds 100 --fleet lognormal --emit-trace
    PYTHONPATH=src python -m repro.obs femnist_trace.jsonl --target 2.0

Inspector cookbook — everything below works on any ``--emit-trace`` log
(the run-forensics layer is always recorded; add ``--chaos`` to make the
flight lifecycles interesting):

    # round table, duration percentiles, byte ledger, time-to-target
    python -m repro.obs femnist_trace.jsonl --target 2.0
    # the same document as JSON, for scripting/jq
    python -m repro.obs femnist_trace.jsonl --json | jq .ledger
    # per-round fault ledger: crashes, retries, quarantines, re-homes
    python -m repro.obs femnist_trace.jsonl --faults
    # grade the run against the default SLOs + one ad-hoc rule
    python -m repro.obs femnist_trace.jsonl --health
    python -m repro.obs femnist_trace.jsonl --slo "drop_rate<=0.3@50"
    # reconstruct one contribution's causal lifecycle end-to-end:
    # sampled -> placed -> uplink (retries/re-homes) -> screening -> state
    python -m repro.obs femnist_trace.jsonl --flight r3-c17-s5
    # ...or every recorded exemplar flight for a client id
    python -m repro.obs femnist_trace.jsonl --flight 17

In the Perfetto UI the exemplar flights render as flow arrows linking
each contribution's uplink span (virtual-clock lane) to the server
screening span, so one straggling or quarantined update is traceable by
eye across lanes.
"""

import argparse
import time

import jax

from repro import obs
from repro.checkpointing import save_checkpoint
from repro.core.quantizer import PQConfig
from repro.core.split import tree_bits
from repro.data.synthetic import make_federated_image_data
from repro.federated import (DEFAULT_CHAOS, AsyncBuffer, Deadline,
                             DropSlowestK, FederatedTrainer, FullSync,
                             lognormal_fleet, mobile_fleet)
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd

FLEETS = {
    "ideal": lambda n: None,  # trainer default: identical ideal clients
    "lognormal": lambda n: lognormal_fleet(n, median_uplink_bps=2e6, seed=0),
    "mobile": lambda n: mobile_fleet(n, flaky_fraction=0.3, seed=0),
}
POLICIES = {
    "full_sync": FullSync,
    "drop2": lambda: DropSlowestK(2),
    "deadline": lambda: Deadline(6.0),
    "async": lambda: AsyncBuffer(4),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=300)
    ap.add_argument("--q", type=int, default=1152)
    ap.add_argument("--clusters", type=int, default=2)
    ap.add_argument("--lam", type=float, default=1e-4)
    ap.add_argument("--cohort", type=int, default=10)
    ap.add_argument("--client-batch", type=int, default=20)
    ap.add_argument("--baseline", action="store_true",
                    help="run SplitFed (no compression) instead")
    ap.add_argument("--fleet", choices=sorted(FLEETS), default="ideal",
                    help="client population for the virtual-clock scheduler")
    ap.add_argument("--policy", choices=sorted(POLICIES), default="full_sync",
                    help="round participation policy")
    ap.add_argument("--downlink", default=None, metavar="SPEC",
                    help="downlink gradient codec spec, e.g. "
                         "'chain:topk(k=0.1)+scalarq(bits=8)'")
    ap.add_argument("--warm-start", action="store_true",
                    help="carry PQ codebooks across rounds (half the Lloyd "
                         "iterations on steady-state rounds)")
    ap.add_argument("--delta-bits", type=int, default=0,
                    help="ship codebooks as pq-delta wire payloads at this "
                         "many bits per delta (0 = fresh fp16 codebooks)")
    ap.add_argument("--executor", choices=["stacked", "mesh"],
                    default="stacked",
                    help="cohort execution engine: stacked single-device "
                         "path or shard_map over the `clients` device axis "
                         "(mesh needs >1 device: set XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N on CPU)")
    ap.add_argument("--autoscale", action="store_true",
                    help="drive the run with the trace-driven autoscaler "
                         "(re-plans cohort/policy/downlink every 8 rounds)")
    ap.add_argument("--chaos", action="store_true",
                    help="arm DEFAULT_CHAOS fault injection (crashes, "
                         "payload corruption, poisoning) so the recorded "
                         "flight lifecycles exercise retries/quarantine; "
                         "the SLO monitor grades the finished run")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--emit-trace", nargs="?", const="femnist_trace.jsonl",
                    default=None, metavar="PATH",
                    help="record obs telemetry (spans on host + virtual "
                         "lanes, byte ledger) and write it as JSONL; "
                         "summarize with `python -m repro.obs PATH`")
    ap.add_argument("--perfetto", default=None, metavar="PATH",
                    help="Perfetto trace_event JSON output (default: the "
                         "--emit-trace path with .jsonl swapped for "
                         ".perfetto.json)")
    args = ap.parse_args()

    if args.emit_trace:
        obs.configure(run="femnist_example", meta={
            "rounds": args.rounds, "fleet": args.fleet,
            "policy": args.policy, "executor": args.executor,
            "autoscale": args.autoscale, "baseline": args.baseline})

    num_clients = 64
    if args.executor == "mesh" and len(jax.devices()) < 2:
        raise SystemExit(
            "--executor mesh needs a multi-device mesh; on CPU set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=4 before "
            "launching")
    data = make_federated_image_data(num_clients=num_clients, seed=0)
    pq = None if args.baseline else PQConfig(
        num_subvectors=args.q, num_clusters=args.clusters, kmeans_iters=5)
    model = FemnistCNN(pq=pq, lam=args.lam, client_batch=args.client_batch)

    def build_trainer(cohort, policy, downlink, seed=0):
        return FederatedTrainer(model, sgd(10 ** -1.5), data, cohort=cohort,
                                client_batch=args.client_batch,
                                quantize=not args.baseline,
                                fleet=FLEETS[args.fleet](num_clients),
                                policy=policy, downlink_compressor=downlink,
                                warm_start=args.warm_start,
                                codebook_delta_bits=args.delta_bits or None,
                                fault_plan=DEFAULT_CHAOS if args.chaos
                                else None,
                                slo_monitor=obs.HealthMonitor()
                                if args.emit_trace else None,
                                seed=seed, executor=args.executor)

    eval_batch = data.eval_batch(jax.random.PRNGKey(99), 512)
    heterogeneous = args.fleet != "ideal" or args.policy != "full_sync" \
        or args.downlink is not None or args.warm_start \
        or bool(args.delta_bits) or args.executor != "stacked"

    if args.autoscale:
        from repro.federated import (AutoscalePlan, TraceAutoscaler,
                                     autoscale_run, make_policy)
        # seed the plan with every CLI knob the controller may later move
        policy_specs = {"full_sync": "full_sync", "drop2": "drop_slowest:2",
                        "deadline": "deadline:6.0", "async": "async:4"}
        plan0 = AutoscalePlan(cohort=args.cohort,
                              policy=policy_specs[args.policy],
                              downlink=args.downlink)

        def make_trainer(plan, seg):
            return build_trainer(plan.cohort, make_policy(plan.policy),
                                 plan.downlink, seed=seg)

        t0 = time.time()
        out = autoscale_run(
            make_trainer, plan0, args.rounds, jax.random.PRNGKey(0),
            controller=TraceAutoscaler(window=8, max_cohort=num_clients),
            interval=8)
        state = out["state"]
        acc = float(model.accuracy(state.params, eval_batch))
        print(f"autoscaled run: {args.rounds} rounds, "
              f"{len(out['plans'])} plan(s), acc={acc:.3f} "
              f"({time.time() - t0:.0f}s real)")
        for i, plan in enumerate(out["plans"]):
            print(f"  plan {i}: cohort={plan.cohort} policy={plan.policy} "
                  f"downlink={plan.downlink or 'dense'}  [{plan.reason}]")
        print(f"  simulated wall-clock : {out['simulated_seconds']:10.1f} s")
        print(f"  measured uplink      : {out['uplink_bytes'] / 1e6:10.2f} MB")
        print(f"  measured downlink    : "
              f"{out['downlink_bytes'] / 1e6:10.2f} MB")
        losses = [h["loss"] for h in out["history"] if "loss" in h]
        if losses:
            print(f"  loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    elif heterogeneous:
        # scheduled run: measured wire bytes + simulated wall-clock per round
        trainer = build_trainer(args.cohort, POLICIES[args.policy](),
                                args.downlink)
        t0 = time.time()
        state, hist = trainer.run(args.rounds, jax.random.PRNGKey(0))
        trace = trainer.last_trace
        acc = float(model.accuracy(state.params, eval_batch))
        s = trace.summary()
        print(f"fleet={args.fleet} policy={args.policy}  "
              f"rounds={s['rounds']}  acc={acc:.3f}  "
              f"({time.time() - t0:.0f}s real)")
        print(f"  simulated wall-clock : {s['simulated_seconds']:10.1f} s")
        print(f"  measured uplink      : {s['uplink_bytes'] / 1e6:10.2f} MB "
              f"({s['uplink_bytes_per_round'] / 1e6:.4f} MB/round)")
        print(f"  measured downlink    : {s['downlink_bytes'] / 1e6:10.2f} MB")
        print(f"  stragglers dropped   : {s['stragglers_dropped']:10d}")
        if s["mean_staleness"]:
            print(f"  mean staleness       : {s['mean_staleness']:10.2f}")
        losses = [h["loss"] for h in hist if "loss" in h]
        if losses:
            print(f"  loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    else:
        # ideal synchronous loop with periodic eval (the paper's simulation);
        # analytic uplink accounting at the params' native phi (fp32: 32-bit)
        trainer = build_trainer(args.cohort, POLICIES[args.policy](),
                                args.downlink)
        state = trainer.init_state(jax.random.PRNGKey(0))
        client_bits = tree_bits(state.params["client"])
        act_bits = 32 * 9216 * args.client_batch
        per_round = client_bits + (pq.message_bits(args.client_batch, 9216,
                                                   phi_bits=32)
                                   if pq else act_bits)
        t0 = time.time()
        for r in range(args.rounds):
            state, metrics = trainer.round(state, jax.random.fold_in(
                jax.random.PRNGKey(1), r))
            if r % 25 == 0 or r == args.rounds - 1:
                acc = float(model.accuracy(state.params, eval_batch))
                mb = per_round * args.cohort * (r + 1) / 8e6
                print(f"round {r:4d}  loss={float(metrics['loss']):.4f}  "
                      f"acc={acc:.3f}  uplink={mb:8.1f} MB  "
                      f"({time.time() - t0:.0f}s)")
    if args.ckpt:
        save_checkpoint(args.ckpt, args.rounds, state.params)
        print(f"saved params to {args.ckpt}")
    if pq:
        print(f"activation compression (phi=32): "
              f"{pq.compression_ratio(args.client_batch, 9216, phi_bits=32):.0f}x")
    recorder = obs.shutdown()
    if args.emit_trace and recorder is not None:
        n = recorder.write_jsonl(args.emit_trace)
        pf = args.perfetto or (
            args.emit_trace[:-len(".jsonl")] + ".perfetto.json"
            if args.emit_trace.endswith(".jsonl")
            else args.emit_trace + ".perfetto.json")
        recorder.write_perfetto(pf)
        print(f"wrote {n} telemetry events to {args.emit_trace}; "
              f"perfetto trace at {pf}")
        print(f"inspect with: python -m repro.obs {args.emit_trace}")


if __name__ == "__main__":
    main()
