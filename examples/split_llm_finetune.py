"""Split-learning fine-tune of a (reduced) assigned LLM with FedLite.

Demonstrates the framework on the transformer zoo: pick any --arch from the
assigned list; its reduced (smoke) variant trains for a few hundred steps on
synthetic non-IID federated text with the cut-layer PQ + gradient
correction. Each sequence is one client (per-client codebooks), exactly as
the production mesh maps cohorts to data shards.

    PYTHONPATH=src python examples/split_llm_finetune.py \
        --arch llama3_8b --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_arch
from repro.core.fedlite import TrainState, comm_report, make_train_step
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_lm_data
from repro.launch.specs import make_model
from repro.optim import get_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_8b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lam", type=float, default=1e-4)
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    if cfg.family in ("vlm", "audio"):
        raise SystemExit("use the text archs for this example; see "
                         "tests/test_archs.py for vlm/audio batches")
    model = make_model(cfg, lam=args.lam)
    opt = get_optimizer("adam", args.lr)
    step = make_train_step(model, opt, donate=False)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)

    data = make_federated_lm_data(num_clients=32, vocab=cfg.vocab_size,
                                  seed=0)
    rep = comm_report(model, state.params, tokens_per_client=args.seq)
    print(f"{args.arch} (reduced): client params "
          f"{rep['fedlite_uplink_bits'] / 8e6:.2f} MB uplink/iter vs "
          f"splitfed {rep['splitfed_uplink_bits'] / 8e6:.2f} MB "
          f"({rep['activation_compression_ratio']:.0f}x activation compression)")

    t0 = time.time()
    for s in range(args.steps):
        # one cohort: each sequence is a distinct client's minibatch
        parts = [data.sample_batch(c, jax.random.fold_in(
            jax.random.PRNGKey(s), c), 1, seq=args.seq)
            for c in range(args.batch)]
        batch = jax.tree.map(lambda *xs: jnp.concatenate(xs), *parts)
        state, m = step(state, batch)
        if s % 20 == 0 or s == args.steps - 1:
            print(f"step {s:4d}  loss={float(m['loss']):.4f}  "
                  f"ce={float(m['ce']):.4f}  "
                  f"distortion={float(m.get('pq_distortion', 0)):.3f}  "
                  f"({time.time() - t0:.0f}s)")


if __name__ == "__main__":
    main()
