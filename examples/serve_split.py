"""Split serving with compressed uplink: batched prefill + decode.

The client side computes the prompt's cut-layer activations, compresses them
with the grouped PQ (the inference uplink is exactly the paper's B·d
message), and the server side completes prefill and serves decode steps
against the KV/SSM caches. Run with any assigned arch (reduced variant):

    PYTHONPATH=src python examples/serve_split.py --arch mamba2_1p3b \
        --prompt-len 48 --gen 16 --batch 4
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_arch
from repro.launch.specs import make_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2_3b", choices=ARCH_IDS)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--no-compress", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=True)
    if cfg.family in ("vlm",):
        raise SystemExit("text archs only in this example")
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, G = args.batch, args.prompt_len, args.gen

    if cfg.num_codebooks > 1:
        prompt = jax.random.randint(jax.random.PRNGKey(1),
                                    (B, cfg.num_codebooks, P), 0,
                                    cfg.vocab_size)
    else:
        prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                    cfg.vocab_size)

    caches = model.init_caches(B, P + G)
    prefill = jax.jit(lambda p, b, c: model.prefill(
        p, b, c, quantize=not args.no_compress))
    decode = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i))

    t0 = time.time()
    logits, caches = prefill(params, {"tokens": prompt}, caches)
    print(f"prefill {P} tokens x{B}: {time.time() - t0:.2f}s "
          f"(uplink {'compressed' if not args.no_compress else 'raw'})")

    if model.pq is not None and not args.no_compress:
        bits = model.pq.message_bits(P, cfg.d_model)
        raw = 64 * cfg.d_model * P
        print(f"uplink per client: {bits / 8e3:.1f} kB vs raw {raw / 8e3:.1f} kB "
              f"({raw / bits:.0f}x)")

    generated = []
    t0 = time.time()
    for i in range(G):
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # greedy
        if cfg.num_codebooks > 1:
            nxt = jnp.moveaxis(nxt, -1, 1)  # (B, K, 1)
        generated.append(nxt)
        logits, caches = decode(params, caches, nxt, P + i)
    dt = time.time() - t0
    print(f"decoded {G} steps x{B} in {dt:.2f}s "
          f"({B * G / dt:.1f} tok/s on CPU, untuned)")
    first = generated[0]
    print("first generated ids:", jnp.squeeze(first)[..., ()] if first.ndim == 0
          else first.reshape(B, -1)[:, 0])


if __name__ == "__main__":
    main()
