"""Sharding context: a process-global mesh used by activation constraints.

Model code calls ``shard(x, "batch_axes", None, "model")`` at key points.
When no mesh is installed (unit tests on a single CPU device) the call is a
no-op, so the same model code runs unsharded on one device and fully sharded
under the production mesh without signature pollution.

Axis names that are not present in the installed mesh are silently dropped
from the spec, so ``shard(x, ("pod", "data"), None)`` works both on the
single-pod ``("data", "model")`` mesh and the multi-pod
``("pod", "data", "model")`` mesh.
"""

from __future__ import annotations

import contextlib
import enum
import inspect
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: Optional[Mesh] = None

AxisEntry = Union[None, str, Sequence[str]]

# The cohort-parallel mesh axis: one shard = one slice of a round's client
# cohort. Built by ``launch/mesh.make_clients_mesh`` and consumed by the
# ``"mesh"`` cohort executor (``federated/executor.py``), which places
# client-major arrays (batches, PRNG keys, EF memories, CutStates) with
# ``NamedSharding(mesh, P(CLIENTS_AXIS))`` and combines shard-local
# per-client gradients with an explicit psum over this axis.
CLIENTS_AXIS = "clients"


def clients_sharding(mesh: Mesh) -> NamedSharding:
    """`NamedSharding` placing a client-major array's leading axis over the
    ``clients`` mesh axis (remaining dims replicated)."""
    return NamedSharding(mesh, P(CLIENTS_AXIS))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement on ``mesh`` (the train state's layout
    under the cohort-parallel executor)."""
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# jax.sharding.AxisType compat shim
#
# AxisType (and make_mesh's axis_types kwarg) only exist in newer JAX; the
# pinned 0.4.x raises AttributeError. All axis-type usage in this repo is
# AxisType.Auto — the 0.4.x default behavior — so on old JAX the enum below
# stands in and make_mesh() silently drops the kwarg.
# ---------------------------------------------------------------------------

try:
    AxisType = jax.sharding.AxisType
except AttributeError:
    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

_MAKE_MESH_HAS_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], *,
              devices=None, axis_types=None) -> Mesh:
    """``jax.make_mesh`` accepting ``axis_types`` on every supported JAX.

    On JAX versions whose ``make_mesh`` lacks the kwarg, non-Auto axis types
    are unrepresentable — reject them rather than silently mis-shard.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None:
        if _MAKE_MESH_HAS_AXIS_TYPES:
            kwargs["axis_types"] = axis_types
        elif any(t is not AxisType.Auto for t in axis_types):
            raise ValueError(
                f"axis_types={axis_types} need jax.make_mesh support for "
                "axis_types (this JAX only provides Auto semantics)")
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def set_mesh(mesh: Optional[Mesh]) -> None:
    """Install (or clear, with None) the process-global mesh."""
    global _MESH
    _MESH = mesh


def current_mesh() -> Optional[Mesh]:
    return _MESH


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh]):
    """Context manager: install ``mesh`` for the duration of the block."""
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _MESH = prev


def axis_size(name: str) -> int:
    """Size of a mesh axis, or 1 if no mesh / axis absent."""
    if _MESH is None or name not in _MESH.axis_names:
        return 1
    return _MESH.shape[name]


def _filter_entry(entry: AxisEntry, names) -> AxisEntry:
    """Drop axis names that the installed mesh does not have."""
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry if entry in names else None
    kept = tuple(a for a in entry if a in names)
    if not kept:
        return None
    if len(kept) == 1:
        return kept[0]
    return kept


def filter_spec(spec: P, mesh: Optional[Mesh] = None) -> P:
    """Rewrite a PartitionSpec so it only references axes of ``mesh``."""
    mesh = mesh if mesh is not None else _MESH
    if mesh is None:
        return P()
    names = set(mesh.axis_names)
    return P(*[_filter_entry(e, names) for e in spec])


def _axis_prod(entry: AxisEntry) -> int:
    if entry is None or _MESH is None:
        return 1
    names = (entry,) if isinstance(entry, str) else entry
    n = 1
    for a in names:
        n *= _MESH.shape[a]
    return n


def shard(x: jax.Array, *entries: AxisEntry) -> jax.Array:
    """Apply a sharding constraint if a mesh is installed; no-op otherwise.

    Each spec entry is additionally guarded by divisibility: a dim that does
    not divide its axis product is replicated instead (so the same constraint
    serves train (S=4096), decode (S=1) and smoke shapes)."""
    if _MESH is None:
        return x
    spec = filter_spec(P(*entries), _MESH)
    guarded = [e if d % _axis_prod(e) == 0 else None
               for d, e in zip(x.shape, list(spec) + [None] * x.ndim)]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_MESH, P(*guarded)))


def shard_residual(x: jax.Array) -> jax.Array:
    """Residual-stream layout (B, S, D): batch over ("pod","data") AND
    sequence over "model" — Megatron-style sequence parallelism. Between
    blocks only norms/adds happen, so seq-sharding there divides the
    layer-scan's saved backward carries by the model-axis size; XLA inserts
    the all-gather (into attention/MLP) and reduce-scatter (out of the
    row-parallel projections) automatically."""
    return shard(x, ("pod", "data"), "model", None)


def named_sharding(*entries: AxisEntry) -> Optional[NamedSharding]:
    if _MESH is None:
        return None
    return NamedSharding(_MESH, filter_spec(P(*entries), _MESH))
