from repro.sharding.ctx import (
    axis_size,
    current_mesh,
    set_mesh,
    shard,
    shard_residual,
    use_mesh,
)
from repro.sharding.rules import param_specs, spec_for_param

__all__ = [
    "axis_size",
    "current_mesh",
    "set_mesh",
    "shard",
    "shard_residual",
    "use_mesh",
    "param_specs",
    "spec_for_param",
]
