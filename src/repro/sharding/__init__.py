from repro.sharding.ctx import (
    CLIENTS_AXIS,
    AxisType,
    axis_size,
    clients_sharding,
    current_mesh,
    make_mesh,
    replicated_sharding,
    set_mesh,
    shard,
    shard_residual,
    use_mesh,
)
from repro.sharding.rules import param_specs, spec_for_param

__all__ = [
    "CLIENTS_AXIS",
    "AxisType",
    "axis_size",
    "clients_sharding",
    "current_mesh",
    "make_mesh",
    "replicated_sharding",
    "set_mesh",
    "shard",
    "shard_residual",
    "use_mesh",
    "param_specs",
    "spec_for_param",
]
