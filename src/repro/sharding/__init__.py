from repro.sharding.ctx import (
    AxisType,
    axis_size,
    current_mesh,
    make_mesh,
    set_mesh,
    shard,
    shard_residual,
    use_mesh,
)
from repro.sharding.rules import param_specs, spec_for_param

__all__ = [
    "AxisType",
    "axis_size",
    "current_mesh",
    "make_mesh",
    "set_mesh",
    "shard",
    "shard_residual",
    "use_mesh",
    "param_specs",
    "spec_for_param",
]
