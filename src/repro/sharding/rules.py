"""Parameter partitioning rules: param path + shape -> PartitionSpec.

Scheme (Megatron-style TP over the "model" axis + FSDP over "data"):

  * column-parallel weights (QKV / up / gate projections, LM head, experts'
    up-projections): last (output) dim -> "model", input d_model dim -> "data"
  * row-parallel weights (attention output / down projections): input dim ->
    "model", output d_model dim -> "data"
  * token embedding: vocab -> "model", d_model -> "data"
  * MoE expert stacks (E, din, dout): experts -> "model" when E divides the
    model-axis size (expert parallelism), otherwise TP inside each expert
  * norms / small vectors: replicated

Every axis assignment is guarded by divisibility against the installed mesh:
if a dim does not divide the axis size, that axis is dropped (replicated on
that dim) instead of failing. Stacked per-layer params (leading scan dim)
get a leading ``None``.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.sharding.ctx import current_mesh, filter_spec

# (regex on the param path, spec builder keyed by rank)
# Specs below are written for the *unstacked* shape; a leading scan dim is
# handled by the caller.
_RULES = [
    # embeddings & heads -------------------------------------------------
    # vocab dim REPLICATED on purpose: a row-gather from a vocab-sharded table
    # forces SPMD "involuntary full rematerialization" (replicates the gather
    # output); d_model-sharded tables gather locally. LM heads stay
    # column-parallel over vocab.
    (r"(^|/)tok_embed$", {2: P(None, "data"), 3: P(None, None, "data")}),
    (r"(^|/)pos_embed$", {2: P(None, "data")}),
    (r"(^|/)head(_\d+)?$", {2: P("data", "model"), 3: P(None, "data", "model")}),
    (r"(^|/)vision_proj$", {2: P(None, "data")}),
    # attention ----------------------------------------------------------
    (r"/(wq|wk|wv)$", {2: P("data", "model")}),
    (r"/wo$", {2: P("model", "data")}),
    (r"/(wq_b|wk_b|wv_b)$", {1: P("model")}),
    (r"/wo_b$", {1: P("data")}),
    # dense mlp ----------------------------------------------------------
    (r"/(w_gate|w_up)$", {2: P("data", "model")}),
    (r"/w_down$", {2: P("model", "data")}),
    (r"/(w_gate_b|w_up_b)$", {1: P("model")}),
    (r"/w_down_b$", {1: P("data")}),
    # MoE ----------------------------------------------------------------
    (r"/router$", {2: P("data", None)}),
    # expert-parallel when E divides the model axis; otherwise Megatron
    # column/row parallel INSIDE each expert (+ FSDP over data) — a small
    # expert count must still shard its d_ff over "model" or expert params
    # alone blow past HBM (mixtral: 13.8 GiB/device without it)
    (r"/(we_gate|we_up)$", {3: ("EXPERT", P("model", "data", None), P(None, "data", "model"))}),
    (r"/we_down$", {3: ("EXPERT", P("model", None, "data"), P(None, "model", "data"))}),
    # SSM (mamba2) ---------------------------------------------------------
    (r"/in_proj(_z|_xbc|_dt)?$", {2: P("data", "model")}),
    (r"/out_proj$", {2: P("model", "data")}),
    (r"/conv_w$", {2: P(None, "model")}),
    (r"/conv_b$", {1: P("model")}),
    (r"/(dt_bias|A_log|ssm_D)$", {1: P(None)}),
    # conv frontends (paper CNN example) ----------------------------------
    (r"/conv\d_w$", {4: P(None, None, None, "model")}),
    (r"/conv\d_b$", {1: P("model")}),
    (r"/(dense\d_w|lstm_.*|emb_w)$", {2: P("data", "model")}),
]


def _fits(dim: int, entry, mesh: Mesh) -> bool:
    if entry is None:
        return True
    names = (entry,) if isinstance(entry, str) else tuple(entry)
    total = 1
    for n in names:
        if n not in mesh.axis_names:
            return False
        total *= mesh.shape[n]
    return dim % total == 0


def _guard(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec axes that do not divide the corresponding dim."""
    spec = filter_spec(spec, mesh)
    entries = list(spec) + [None] * (len(shape) - len(spec))
    return P(*[e if _fits(d, e, mesh) else None for d, e in zip(shape, entries)])


def spec_for_param(path: str, shape, mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a parameter identified by its tree path."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return P()
    stacked = bool(re.search(r"(^|/)layers/", path)) and len(shape) >= 2
    core_shape = shape[1:] if stacked else shape
    for pattern, by_rank in _RULES:
        if re.search(pattern, path):
            rule = by_rank.get(len(core_shape))
            if rule is None:
                continue
            if isinstance(rule, tuple) and rule[0] == "EXPERT":
                # expert-parallel if E divides the model axis, else TP-in-expert
                _, ep_spec, tp_spec = rule
                model = mesh.shape.get("model", 1) if "model" in mesh.axis_names else 1
                spec = ep_spec if core_shape[0] % model == 0 else tp_spec
            else:
                spec = rule
            spec = _guard(spec, core_shape, mesh)
            return P(None, *spec) if stacked else spec
    # default: replicate small things, FSDP-shard big matrices on dim0
    if len(core_shape) >= 2:
        spec = _guard(P("data"), core_shape, mesh)
        return P(None, *spec) if stacked else spec
    return P()


def _paths(tree, prefix=""):
    for k, v in tree.items():
        p = f"{prefix}/{k}" if prefix else k
        if isinstance(v, dict):
            yield from _paths(v, p)
        else:
            yield p, v


def param_specs(params, mesh: Optional[Mesh] = None):
    """Build a pytree of PartitionSpecs matching ``params``."""
    mesh = mesh if mesh is not None else current_mesh()

    def walk(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            p = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                out[k] = walk(v, p)
            else:
                out[k] = spec_for_param(p, v.shape, mesh)
        return out

    return walk(params)


def inference_spec(spec: P, shape, mesh: Optional[Mesh] = None) -> P:
    """Re-layout a training spec for decode serving: fold the FSDP ("data")
    dim into the TP dim instead.

    Training shards matrices (FSDP x TP) so optimizer state fits; decode has
    no optimizer state but all-gathers every FSDP-sharded weight for each
    generated token — the dominant collective cost of serving. Merging
    "data" into the tensor-parallel dim keeps params fully sharded with NO
    per-token weight gathering (the per-layer activation all-reduce spans
    the merged group instead). Falls back to the original spec when the TP
    dim does not divide the merged axis.
    """
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))

    def names(e):
        return () if e is None else ((e,) if isinstance(e, str) else tuple(e))

    data_dims = [i for i, e in enumerate(entries) if "data" in names(e)]
    model_dims = [i for i, e in enumerate(entries) if "model" in names(e)]
    if not data_dims or not model_dims or data_dims[0] == model_dims[0]:
        return spec
    di, mi = data_dims[0], model_dims[0]
    merged = tuple(n for n in names(entries[mi]) if n != "data") + ("data",)
    new = list(entries)
    new[di] = tuple(n for n in names(entries[di]) if n != "data") or None
    if isinstance(new[di], tuple) and len(new[di]) == 1:
        new[di] = new[di][0]
    new[mi] = merged if len(merged) > 1 else merged[0]
    cand = _guard(P(*new), shape, mesh)
    # only accept if the merged axis actually divides (guard keeps it)
    if "data" in names(list(cand)[mi] if mi < len(list(cand)) else None):
        return cand
    return spec


def inference_param_specs(params, mesh: Optional[Mesh] = None):
    """param_specs re-laid-out for serving (see inference_spec)."""
    mesh = mesh if mesh is not None else current_mesh()

    def walk(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            p = f"{prefix}/{k}" if prefix else k
            if isinstance(v, dict):
                out[k] = walk(v, p)
            else:
                out[k] = inference_spec(spec_for_param(p, v.shape, mesh),
                                        v.shape, mesh)
        return out

    return walk(params)


def param_shardings(params, mesh: Optional[Mesh] = None):
    """Like param_specs but returns NamedShardings (or None without a mesh)."""
    mesh = mesh if mesh is not None else current_mesh()
    if mesh is None:
        return jax.tree.map(lambda _: None, params)
    specs = param_specs(params, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
