"""Sharding-aware pytree checkpointing to .npz (no external deps).

Arrays are gathered to host (``jax.device_get`` pulls fully-replicated /
addressable shards), flattened with '/'-joined key paths, and stored in a
single compressed npz per step. Restore rebuilds the tree and (optionally)
re-applies shardings via ``jax.device_put`` with the provided sharding tree —
enough for the single-process simulation; a real multi-host deployment would
swap this module for tensorstore-backed storage behind the same API.
"""

from __future__ import annotations

import json
import os
import re
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

_SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _encode(a: np.ndarray):
    """npz cannot store ml_dtypes (bfloat16 etc., numpy kind 'V'): store a
    bit-cast uint view plus the dtype name, decoded on restore."""
    if a.dtype.kind != "V":
        return a, ""
    return a.view(np.dtype(f"u{a.dtype.itemsize}")), a.dtype.name


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    with obs.span("checkpoint.save", cat="io", step=step):
        os.makedirs(ckpt_dir, exist_ok=True)
        flat = {}
        host = jax.device_get(_flatten(tree))  # one transfer for whole tree
        for k, v in host.items():
            arr, dtname = _encode(np.asarray(v))
            flat[k] = arr
            if dtname:
                flat[f"__dtype__{k}"] = np.asarray(dtname)
        path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
        tmp = path + ".tmp.npz"  # .npz suffix so numpy does not append one
        np.savez_compressed(tmp, **flat)
        os.replace(tmp, path)
        if extra is not None:
            meta = os.path.join(ckpt_dir, f"meta_{step:08d}.json")
            with open(meta, "w") as f:
                json.dump(extra, f)
        return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    with obs.span("checkpoint.restore", cat="io", step=step):
        path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
        with np.load(path) as z:
            dtypes = {k[len("__dtype__"):]: str(z[k]) for k in z.files
                      if k.startswith("__dtype__")}
            flat = {}
            for k in z.files:
                if k.startswith("__dtype__"):
                    continue
                a = z[k]
                if k in dtypes:
                    a = a.view(jnp.dtype(dtypes[k]))
                flat[k] = jnp.asarray(a)
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree, shardings)
        return tree
