"""Sharding-aware pytree checkpointing to .npz (no external deps).

Arrays are gathered to host (``jax.device_get`` pulls fully-replicated /
addressable shards), flattened with '/'-joined key paths, and stored in a
single compressed npz per step. Restore rebuilds the tree and (optionally)
re-applies shardings via ``jax.device_put`` with the provided sharding tree —
enough for the single-process simulation; a real multi-host deployment would
swap this module for tensorstore-backed storage behind the same API.

Crash consistency: every file lands via write-to-tmp + ``os.replace`` (POSIX
rename is atomic within a filesystem), and each step additionally writes a
``manifest_<step>.json`` — LAST, after every payload file it names — carrying
the sha256 of each payload. A step is only *visible* (to `latest_step` /
`restore_checkpoint`) once its manifest exists, so a process killed mid-save
leaves at most an orphaned ``.tmp`` or an unreferenced npz, never a
restorable-but-corrupt step. Restore re-hashes the payload against the
manifest and raises `CheckpointError` on any mismatch, truncation, or
unreadable archive. Pre-manifest checkpoints (bare npz) still restore, with
hash verification skipped.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import zipfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

_SEP = "/"


class CheckpointError(RuntimeError):
    """A checkpoint failed verification: partial write, corrupt payload,
    or a manifest/payload mismatch."""


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def _encode(a: np.ndarray):
    """npz cannot store ml_dtypes (bfloat16 etc., numpy kind 'V'): store a
    bit-cast uint view plus the dtype name, decoded on restore."""
    if a.dtype.kind != "V":
        return a, ""
    return a.view(np.dtype(f"u{a.dtype.itemsize}")), a.dtype.name


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _json_default(o):
    """Meta dicts routinely carry numpy scalars (trace counters, cursor
    times); store them as their Python equivalents."""
    if isinstance(o, np.generic):
        return o.item()
    raise TypeError(f"not JSON-serializable: {type(o).__name__}")


def _write_atomic(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _manifest_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"manifest_{step:08d}.json")


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    with obs.span("checkpoint.save", cat="io", step=step):
        os.makedirs(ckpt_dir, exist_ok=True)
        flat = {}
        host = jax.device_get(_flatten(tree))  # one transfer for whole tree
        for k, v in host.items():
            arr, dtname = _encode(np.asarray(v))
            flat[k] = arr
            if dtname:
                flat[f"__dtype__{k}"] = np.asarray(dtname)
        path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
        tmp = path + ".tmp.npz"  # .npz suffix so numpy does not append one
        np.savez_compressed(tmp, **flat)
        with open(tmp, "rb") as f:   # flush page cache before the rename
            os.fsync(f.fileno())
        os.replace(tmp, path)
        files = {os.path.basename(path): _sha256(path)}
        if extra is not None:
            meta = os.path.join(ckpt_dir, f"meta_{step:08d}.json")
            _write_atomic(meta,
                          json.dumps(extra, default=_json_default).encode())
            files[os.path.basename(meta)] = _sha256(meta)
        # the commit point: the manifest lands LAST, after every file it
        # names — a step without one is invisible, never half-restored
        manifest = {"step": step, "files": files}
        _write_atomic(_manifest_path(ckpt_dir, step),
                      json.dumps(manifest).encode())
        return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    """The newest *committed* step: manifest-backed when any manifest
    exists, falling back to bare npz files (pre-manifest checkpoints)."""
    if not os.path.isdir(ckpt_dir):
        return None
    names = os.listdir(ckpt_dir)
    steps = [int(m.group(1)) for f in names
             if (m := re.match(r"manifest_(\d+)\.json$", f))]
    if steps:
        return max(steps)
    steps = [int(m.group(1)) for f in names
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def verify_checkpoint(ckpt_dir: str, step: int) -> None:
    """Raise `CheckpointError` unless step's manifest matches its payloads
    byte for byte. No-op (nothing to verify against) without a manifest."""
    mpath = _manifest_path(ckpt_dir, step)
    if not os.path.exists(mpath):
        return
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        files = manifest["files"]
    except (json.JSONDecodeError, KeyError, OSError) as e:
        raise CheckpointError(f"unreadable manifest {mpath}: {e}") from e
    for name, digest in files.items():
        path = os.path.join(ckpt_dir, name)
        if not os.path.exists(path):
            raise CheckpointError(f"manifest names missing file {path}")
        actual = _sha256(path)
        if actual != digest:
            raise CheckpointError(
                f"checksum mismatch for {path}: manifest {digest[:12]}…, "
                f"file {actual[:12]}… — partial or corrupt write")


def restore_checkpoint(ckpt_dir: str, step: Optional[int] = None,
                       shardings: Any = None) -> Any:
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    with obs.span("checkpoint.restore", cat="io", step=step):
        verify_checkpoint(ckpt_dir, step)
        path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
        try:
            with np.load(path) as z:
                dtypes = {k[len("__dtype__"):]: str(z[k]) for k in z.files
                          if k.startswith("__dtype__")}
                flat = {}
                for k in z.files:
                    if k.startswith("__dtype__"):
                        continue
                    a = z[k]
                    if k in dtypes:
                        a = a.view(jnp.dtype(dtypes[k]))
                    flat[k] = jnp.asarray(a)
        except (zipfile.BadZipFile, ValueError, EOFError, OSError) as e:
            raise CheckpointError(f"corrupt checkpoint {path}: {e}") from e
        tree = _unflatten(flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                tree, shardings)
        return tree
