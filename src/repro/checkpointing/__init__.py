from repro.checkpointing.checkpoint import (
    CheckpointError,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    verify_checkpoint,
)

__all__ = ["CheckpointError", "save_checkpoint", "restore_checkpoint",
           "latest_step", "verify_checkpoint"]
