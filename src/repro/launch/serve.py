"""Production serving launcher: batched split-inference driver.

Prefill (optionally with FedLite-compressed uplink at the cut layer) then a
decode loop with KV/SSM caches. Use --smoke on CPU; the full configs are
validated via launch/dryrun.py (decode_32k / long_500k shapes).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2_1p3b --smoke \
      --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ARCH_IDS, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import make_model
from repro.sharding import use_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--no-compress", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    mesh = None if args.mesh == "none" else make_production_mesh(
        multi_pod=args.mesh == "multi")

    with use_mesh(mesh):
        model = make_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, P, G = args.batch, args.prompt_len, args.gen
        if cfg.num_codebooks > 1:
            prompt = jax.random.randint(jax.random.PRNGKey(1),
                                        (B, cfg.num_codebooks, P), 0,
                                        cfg.vocab_size)
        else:
            prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                        cfg.vocab_size)
        caches = model.init_caches(B, P + G)

        prefill = jax.jit(lambda p, b, c: model.prefill(
            p, b, c, quantize=not args.no_compress))
        decode = jax.jit(lambda p, c, t, i: model.decode_step(p, c, t, i))

        t0 = time.time()
        logits, caches = prefill(params, {"tokens": prompt}, caches)
        jax.block_until_ready(logits)
        print(f"prefill: {B}x{P} tokens in {time.time() - t0:.2f}s")

        key = jax.random.PRNGKey(7)
        t0 = time.time()
        for i in range(G):
            if args.temperature > 0:
                key, k = jax.random.split(key)
                nxt = jax.random.categorical(
                    k, logits[..., :cfg.vocab_size] / args.temperature
                ).astype(jnp.int32)
            else:
                nxt = jnp.argmax(logits[..., :cfg.vocab_size], -1
                                 ).astype(jnp.int32)
            if cfg.num_codebooks > 1:
                nxt = jnp.moveaxis(nxt, -1, 1)
            logits, caches = decode(params, caches, nxt, P + i)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        print(f"decode: {G} steps x{B} in {dt:.2f}s "
              f"({B * G / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
