"""ShapeDtypeStruct builders for the dry-run: every model input, train state
and KV/SSM cache as an abstract, sharded stand-in (no device allocation).

All shardings are guarded by divisibility (a dim that does not divide the
mesh axis falls back to the next candidate or replication) so one spec
builder serves every (arch × input shape × mesh) combination.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape
from repro.core.quantizer import PQConfig
from repro.models.transformer import TransformerLM
from repro.sharding.ctx import filter_spec
from repro.sharding.rules import param_specs


# ---------------------------------------------------------------------------
# default FedLite quantizer for the big archs
# ---------------------------------------------------------------------------

def default_pq(cfg: ArchConfig, *, subvector_dim: int = 8,
               clusters: int = 16, iters: int = 4) -> PQConfig:
    """Paper-faithful defaults scaled to d_model: subvectors of dim 8 (the
    paper's FEMNIST best ratio uses d/q = 8), R=1, L=16. The encode backend
    comes from the arch config ("auto": fused Pallas on TPU, jnp elsewhere);
    ``cfg.pq_warm_iters`` sets the warm-started Lloyd budget for runs that
    carry `QuantizerState` across rounds (None = kmeans_iters // 2)."""
    q = cfg.d_model // subvector_dim
    return PQConfig(num_subvectors=q, num_clusters=clusters, num_groups=1,
                    kmeans_iters=iters, kmeans_chunk=4096,
                    backend=cfg.pq_backend, warm_iters=cfg.pq_warm_iters)


def make_model(cfg: ArchConfig, *, with_pq: bool = True,
               lam: float = 1e-4) -> TransformerLM:
    """Build the split LM with the arch's per-direction cut codecs.

    ``cfg.uplink_compressor`` — "pq" keeps the paper's grouped PQ fast path
    (``with_pq=False`` or "none" disables it → SplitFed); any other spec is
    parsed by ``core/compressors.make_compressor``. ``cfg.downlink_compressor``
    installs a codec on the server→client gradient message ("none": the
    dense baseline, bitwise-identical backward pass).
    """
    from repro.core.compressors import make_compressor
    # the PQ config exists only when the uplink actually runs PQ — a
    # non-pq uplink spec must not leave a misleading model.pq behind
    # (comm_report attributes PQ bits to whatever model.pq says)
    pq = default_pq(cfg) if with_pq and cfg.uplink_compressor == "pq" \
        else None
    uplink = None if cfg.uplink_compressor in ("pq", "none") \
        else make_compressor(cfg.uplink_compressor,
                             pq=default_pq(cfg) if with_pq else None)
    downlink = None if cfg.downlink_compressor == "none" \
        else make_compressor(cfg.downlink_compressor,
                             pq=default_pq(cfg) if with_pq else None)
    return TransformerLM(cfg, pq=pq, lam=lam, uplink_compressor=uplink,
                         downlink_compressor=downlink)


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

def _axis_size(mesh: Mesh, entry) -> int:
    if entry is None:
        return 1
    names = (entry,) if isinstance(entry, str) else entry
    return math.prod(mesh.shape[n] for n in names if n in mesh.axis_names)


def _fit(mesh: Mesh, shape: Tuple[int, ...], *candidates: P) -> P:
    """First candidate spec whose sharded dims all divide; else replicated."""
    for spec in candidates:
        spec_f = filter_spec(spec, mesh)
        entries = list(spec_f) + [None] * (len(shape) - len(spec_f))
        if all(d % _axis_size(mesh, e) == 0 for d, e in zip(shape, entries)):
            return spec_f
    return P()


def _struct(mesh: Mesh, shape, dtype, *candidates: P) -> jax.ShapeDtypeStruct:
    spec = _fit(mesh, tuple(shape), *candidates)
    return jax.ShapeDtypeStruct(tuple(shape), dtype,
                                sharding=NamedSharding(mesh, spec))


BATCH = ("pod", "data")


# ---------------------------------------------------------------------------
# model inputs per input-shape
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                *, with_labels: bool = True) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch for (arch, input shape): tokens/labels (+ modality)."""
    B, S = shape.global_batch, shape.seq_len
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    tok = jnp.int32
    if cfg.family == "vlm":
        S_vis = int(S * cfg.vision_tokens_frac) // 16 * 16
        S_txt = S - S_vis
        batch["tokens"] = _struct(mesh, (B, S_txt), tok, P(BATCH, None))
        batch["vision_embeds"] = _struct(mesh, (B, S_vis, cfg.vision_embed_dim),
                                         jnp.float32, P(BATCH, None, None))
        batch["positions"] = _struct(mesh, (3, B, S), tok, P(None, BATCH, None))
        if with_labels:
            batch["labels"] = _struct(mesh, (B, S), tok, P(BATCH, None))
    elif cfg.num_codebooks > 1:
        batch["tokens"] = _struct(mesh, (B, cfg.num_codebooks, S), tok,
                                  P(BATCH, None, None))
        if with_labels:
            batch["labels"] = _struct(mesh, (B, cfg.num_codebooks, S), tok,
                                      P(BATCH, None, None))
    else:
        batch["tokens"] = _struct(mesh, (B, S), tok, P(BATCH, None))
        if with_labels:
            batch["labels"] = _struct(mesh, (B, S), tok, P(BATCH, None))
    return batch


def decode_token_specs(cfg: ArchConfig, shape: InputShape, mesh: Mesh):
    B = shape.global_batch
    if cfg.num_codebooks > 1:
        return _struct(mesh, (B, cfg.num_codebooks, 1), jnp.int32,
                       P(BATCH, None, None))
    return _struct(mesh, (B, 1), jnp.int32, P(BATCH, None))


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------

def cache_specs(model: TransformerLM, batch_size: int, max_len: int,
                mesh: Mesh, *, seq_shard_budget: int = 4 << 30):
    """Abstract caches with shardings.

    Adaptive policy (§Perf C2): batch-only sharding when the whole cache
    fits ``seq_shard_budget`` bytes/device (no collectives on the decode
    cache update); otherwise the cache-seq dim is additionally sharded over
    "model" (a 32k-token cache for a 30-50L model is tens of GB per batch
    element — seq sharding costs cheap dynamic-update/softmax collectives
    but keeps HBM bounded). SSM states are head-sharded.
    """
    shapes = jax.eval_shape(
        lambda: model.init_caches(batch_size, max_len))

    # total cache bytes/device under batch-only sharding
    batch_shards = _axis_size(mesh, BATCH)
    total = sum(
        s.size * s.dtype.itemsize for s in jax.tree.leaves(shapes))
    per_dev_batch_only = total / max(batch_shards, 1) \
        if batch_size % max(batch_shards, 1) == 0 else float("inf")
    prefer_batch_only = per_dev_batch_only <= seq_shard_budget

    def spec_of(path: str, s: jax.ShapeDtypeStruct):
        shp = s.shape[1:]  # strip the stacked periods dim
        if path.endswith("/pos"):
            return P()
        if path.endswith("/k") or path.endswith("/v"):
            if prefer_batch_only:
                base = _fit(mesh, shp,
                            P(BATCH, None, None, None),
                            P(BATCH, "model", None, None),
                            P(None, ("data", "model"), None, None),
                            P(None, "data", None, None))
            else:
                base = _fit(mesh, shp,
                            P(BATCH, "model", None, None),
                            P(BATCH, None, "model", None),
                            P(BATCH, None, None, None),
                            P(None, ("data", "model"), None, None),
                            P(None, "data", None, None))
        elif path.endswith("/h"):
            base = _fit(mesh, shp,
                        P(BATCH, "model", None, None),
                        P(BATCH, None, None, None),
                        P(None, "model", None, None))
        elif path.endswith("/conv"):
            base = _fit(mesh, shp,
                        P(BATCH, None, "model"),
                        P(BATCH, None, None),
                        P(None, None, "model"))
        else:
            base = P()
        return P(None, *base)

    def walk(tree, prefix=""):
        out = {}
        for k, v in tree.items():
            p = f"{prefix}/{k}"
            if isinstance(v, dict):
                out[k] = walk(v, p)
            else:
                out[k] = jax.ShapeDtypeStruct(
                    v.shape, v.dtype,
                    sharding=NamedSharding(mesh, spec_of(p, v)))
        return out

    return walk(shapes)


# ---------------------------------------------------------------------------
# train-state specs
# ---------------------------------------------------------------------------

def state_specs(model: TransformerLM, optimizer, mesh: Mesh, *,
                inference: bool = False):
    """Abstract TrainState with param/opt-state shardings from the rules.

    ``inference=True`` uses the serving layout (FSDP dim folded into TP —
    see sharding/rules.py:inference_spec) so decode never all-gathers
    weights per token.
    """
    from repro.core.fedlite import TrainState
    from repro.sharding.rules import inference_param_specs

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(optimizer.init, params_s)

    def apply_specs(tree):
        specs = (inference_param_specs(tree, mesh) if inference
                 else param_specs(tree, mesh))
        return jax.tree.map(
            lambda s, sp: jax.ShapeDtypeStruct(
                s.shape, s.dtype, sharding=NamedSharding(mesh, sp)),
            tree, specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    params_s = apply_specs(params_s)
    opt_s = apply_specs(opt_s)
    step_s = jax.ShapeDtypeStruct((), jnp.int32,
                                  sharding=NamedSharding(mesh, P()))
    return TrainState(params=params_s, opt_state=opt_s, step=step_s)
