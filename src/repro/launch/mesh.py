"""Production mesh construction (TPU v5e pods).

Kept as functions — importing this module never touches jax device state,
so unit tests keep their single CPU device unless a caller explicitly
builds a mesh (the dry-run sets XLA_FLAGS for 512 host devices first).
"""

from __future__ import annotations

import math

import jax
import numpy as np

from repro.sharding.ctx import CLIENTS_AXIS, AxisType, make_mesh

SINGLE_POD = (16, 16)                  # 256 chips / pod
MULTI_POD = (2, 16, 16)                # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    """(data=16, model=16) single-pod or (pod=2, data=16, model=16) multi-pod.

    Uses the first prod(shape) devices, so a 512-device host platform serves
    both meshes.
    """
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)")
    return make_mesh(shape, axes, devices=devices[:n],
                     axis_types=(AxisType.Auto,) * len(shape))


def make_debug_mesh(data: int = 2, model: int = 2, pods: int = 0):
    """Small mesh for CPU sharding tests (requires >= data*model*max(pods,1)
    host devices)."""
    if pods:
        shape, axes = (pods, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    n = math.prod(shape)
    return make_mesh(shape, axes, devices=jax.devices()[:n],
                     axis_types=(AxisType.Auto,) * len(shape))


def make_clients_mesh(shards: int = 0):
    """1-D ``("clients",)`` mesh for cohort-parallel execution.

    ``shards=0`` is host-count-aware: it uses every visible device, so the
    same call serves a real TPU slice and a CPU CI runner that forced 2-4
    host devices via ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
    (which must be set before jax initializes its backend). A single-device
    host yields a valid 1-shard mesh — the mesh executor then degenerates to
    the per-client path on one device, which is what the shard-scaling
    benchmark uses as its baseline.
    """
    devices = jax.devices()
    n = shards or len(devices)
    if n > len(devices):
        raise RuntimeError(
            f"need {n} devices for a {n}-shard clients mesh, have "
            f"{len(devices)} — set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count={n} before importing jax")
    return make_mesh((n,), (CLIENTS_AXIS,), devices=devices[:n],
                     axis_types=(AxisType.Auto,))


# TPU v5e hardware constants for the roofline model (per chip)
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW_PER_LINK = 50e9          # bytes/s per link (~45-100 GB/s depending on gen)
HBM_BYTES = 16 * 1024 ** 3      # 16 GiB
