"""Compiled-HLO analysis: FLOPs/bytes from cost_analysis + collective-bytes
parsed from the partitioned module text — the inputs to the §Roofline model.

cost_analysis() does not expose collective traffic, so we parse the
post-SPMD HLO: every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute op contributes wire bytes estimated with standard ring
formulas over its replica-group size g:

    all-gather, reduce-scatter, all-to-all : bytes · (g-1)/g
    all-reduce                             : bytes · 2(g-1)/g
    collective-permute                     : bytes

where ``bytes`` is the op's (flattened tuple) result payload per device.
"""

from __future__ import annotations

import math
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-gather.5 = bf16[16,512,128]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", )
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{\{")


def _payload_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, world: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,S]<=[...] : G groups of S
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return world


def collective_stats(hlo_text: str, world: int) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, payload_bytes, wire_bytes} (per device)."""
    stats: Dict[str, Dict[str, float]] = {}
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        # async pairs appear as -start/-done; count the op once (on start)
        if "-done(" in line:
            continue
        type_str, kind = m.group(1), m.group(2)
        payload = _payload_bytes(type_str)
        g = _group_size(line, world)
        if kind == "all-reduce":
            wire = payload * 2 * (g - 1) / max(g, 1)
        elif kind == "collective-permute":
            wire = payload
        else:
            wire = payload * (g - 1) / max(g, 1)
        rec = stats.setdefault(kind, {"count": 0, "payload_bytes": 0.0,
                                      "wire_bytes": 0.0})
        rec["count"] += 1
        rec["payload_bytes"] += payload
        rec["wire_bytes"] += wire
    return stats


def total_wire_bytes(stats: Dict[str, Dict[str, float]]) -> float:
    return sum(v["wire_bytes"] for v in stats.values())


def cost_summary(compiled) -> Dict[str, float]:
    """Normalize cost_analysis() across jax versions (dict or list-of-dict)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    out = {}
    for k in ("flops", "bytes accessed", "transcendentals"):
        if k in ca:
            out[k.replace(" ", "_")] = float(ca[k])
    # operand/output byte breakdown if present
    out["utilization_keys"] = None
    return {k: v for k, v in out.items() if v is not None}


def memory_summary(compiled) -> Dict[str, int]:
    ms = compiled.memory_analysis()
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes")
    return {f: int(getattr(ms, f, 0)) for f in fields}


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float, *,
                   peak_flops: float, hbm_bw: float, ici_bw: float,
                   num_links: int = 4) -> Dict[str, float]:
    """Three per-device roofline times (seconds) + the dominant term.

    ``flops``/``hbm_bytes``/``wire_bytes`` are per-device quantities; v5e
    chips have ~4 usable ICI links, so collective bandwidth = num_links·ici_bw.
    """
    t_compute = flops / peak_flops
    t_memory = hbm_bytes / hbm_bw
    t_coll = wire_bytes / (ici_bw * num_links)
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))
    return {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
        "bound": dom[1],
        "step_time_lower_bound_s": max(t_compute, t_memory, t_coll),
    }
