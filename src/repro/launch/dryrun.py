"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

This is the deliverable proving the distribution config is coherent without
hardware: ``jax.jit(step).lower(**abstract_inputs).compile()`` must succeed
on the single-pod (16×16) and multi-pod (2×16×16) production meshes for
every assigned architecture and input shape; memory_analysis() proves the
footprint fits a 16 GiB v5e chip and cost_analysis() + collective parsing
feed the §Roofline tables.

Run:  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b \
          --shape train_4k --mesh single
      PYTHONPATH=src python -m repro.launch.dryrun --all
"""

# The host platform must expose 512 fake devices BEFORE jax initializes —
# these two lines must stay the first statements in this module.
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, INPUT_SHAPES, get_arch,
                                supports_shape)
from repro.core.fedlite import make_train_step
from repro.launch import analysis
from repro.launch.mesh import (HBM_BYTES, HBM_BW, ICI_BW_PER_LINK,
                               PEAK_FLOPS_BF16, make_production_mesh)
from repro.launch.specs import (cache_specs, decode_token_specs, input_specs,
                                make_model, state_specs)
from repro.optim import get_optimizer
from repro.sharding import use_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def lower_combo(arch_id: str, shape_id: str, mesh, *, with_pq: bool = True,
                save_hlo: str | None = None, force_f32: bool = False,
                inference_layout: bool = False):
    # inference_layout=False by default: §Perf C1 measured the TP-only
    # serving layout NEUTRAL on dense decode and WORSE on jamba (256-way
    # column splits cut attention heads below head granularity)
    """Lower + compile one (arch, shape) on ``mesh``; return the record."""
    import dataclasses as _dc
    cfg = get_arch(arch_id)
    if force_f32:
        cfg = _dc.replace(cfg, dtype="float32", param_dtype="float32")
    shape = INPUT_SHAPES[shape_id]
    model = make_model(cfg, with_pq=with_pq)
    world = mesh.devices.size

    t0 = time.time()
    with use_mesh(mesh):
        if shape.kind == "train":
            opt = get_optimizer(cfg.optimizer, 1e-4)
            step = make_train_step(model, opt, quantize=with_pq,
                                   microbatches=cfg.train_microbatches)
            state_s = state_specs(model, opt, mesh)
            batch_s = input_specs(cfg, shape, mesh)
            lowered = step.lower(state_s, batch_s)
        elif shape.kind == "prefill":
            batch_s = input_specs(cfg, shape, mesh, with_labels=False)
            caches_s = cache_specs(model, shape.global_batch, shape.seq_len, mesh)
            params_s = state_specs(model, get_optimizer("sgd", 0.0), mesh).params

            def prefill_fn(params, batch, caches):
                return model.prefill(params, batch, caches, quantize=with_pq)

            lowered = jax.jit(prefill_fn, donate_argnums=(2,)).lower(
                params_s, batch_s, caches_s)
        else:  # decode (optionally with the TP-only serving layout — C1)
            caches_s = cache_specs(model, shape.global_batch, shape.seq_len, mesh)
            params_s = state_specs(model, get_optimizer("sgd", 0.0), mesh,
                                   inference=inference_layout).params
            tok_s = decode_token_specs(cfg, shape, mesh)

            def decode_fn(params, caches, toks, pos):
                return model.decode_step(params, caches, toks, pos)

            pos_s = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jax.jit(decode_fn, donate_argnums=(1,)).lower(
                params_s, caches_s, tok_s, pos_s)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    cost = analysis.cost_summary(compiled)
    mem = analysis.memory_summary(compiled)
    coll = analysis.collective_stats(compiled.as_text(), world)
    wire = analysis.total_wire_bytes(coll)
    roof = analysis.roofline_terms(
        cost.get("flops", 0.0), cost.get("bytes_accessed", 0.0), wire,
        peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW, ici_bw=ICI_BW_PER_LINK)

    # MODEL_FLOPS: 6·N_active·tokens (train fwd+bwd) or 2·N_active·tokens
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        model_flops = 2 * n_active * shape.global_batch
    model_flops_per_device = model_flops / world

    device_bytes = (mem["argument_size_in_bytes"] + mem["output_size_in_bytes"]
                    + mem["temp_size_in_bytes"] - mem["alias_size_in_bytes"])

    rec = {
        "arch": arch_id, "shape": shape_id,
        "inference_layout": inference_layout if shape.kind == "decode" else None,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "world": world, "kind": shape.kind, "with_pq": with_pq,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost": cost, "memory": mem, "collectives": coll,
        "wire_bytes_per_device": wire,
        "device_bytes": device_bytes,
        "fits_16GiB": device_bytes <= HBM_BYTES,
        "model_flops_per_device": model_flops_per_device,
        "useful_flops_fraction": (model_flops_per_device /
                                  max(cost.get("flops", 1.0), 1.0)),
        "roofline": roof,
        "params_total": cfg.param_count(),
        "params_active": n_active,
    }
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(compiled.as_text())
        rec["hlo_path"] = save_hlo
    return rec


def run_one(arch_id, shape_id, mesh_kind, out_dir, *, with_pq=True,
            force=False, save_hlo=False, inference_layout=False):
    tag = f"{arch_id}__{shape_id}__{mesh_kind}" + ("" if with_pq else "__nopq")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip] {tag} (exists)")
        return json.load(open(path))
    if not supports_shape(arch_id, shape_id):
        rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
               "skipped": "long_500k requires sub-quadratic attention "
                          "(see DESIGN.md §3)"}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip-noted] {tag}")
        return rec
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    try:
        hlo_path = path.replace(".json", ".hlo.txt") if save_hlo else None
        rec = lower_combo(arch_id, shape_id, mesh, with_pq=with_pq,
                          save_hlo=hlo_path,
                          inference_layout=inference_layout)
        cfg = get_arch(arch_id)
        if not rec["fits_16GiB"] and cfg.dtype == "bfloat16":
            # The CPU backend legalizes bf16 compute to f32, materializing
            # f32 copies + layout copies of every large bf16 buffer (verified
            # on a minimal repro; see EXPERIMENTS.md §Dry-run). Estimate the
            # TPU-native footprint by compiling the same program in f32
            # (which CPU executes natively, no copies) and halving the temp.
            try:
                rec32 = lower_combo(arch_id, shape_id, mesh, with_pq=with_pq,
                                    force_f32=True)
                temp_est = rec32["memory"]["temp_size_in_bytes"] / 2
                dev_est = (rec["memory"]["argument_size_in_bytes"]
                           + rec["memory"]["output_size_in_bytes"]
                           - rec["memory"]["alias_size_in_bytes"] + temp_est)
                rec["tpu_bf16_estimate"] = {
                    "f32_temp_bytes": rec32["memory"]["temp_size_in_bytes"],
                    "device_bytes_estimate": dev_est,
                    "fits_16GiB_estimate": dev_est <= HBM_BYTES,
                }
            except Exception as e:  # noqa: BLE001
                rec["tpu_bf16_estimate"] = {"error": str(e)[:200]}
        json.dump(rec, open(path, "w"), indent=1)
        r = rec["roofline"]
        est = rec.get("tpu_bf16_estimate", {})
        est_s = (f" tpu_est={est['device_bytes_estimate']/2**30:.1f}GiB"
                 f"(fits={est['fits_16GiB_estimate']})"
                 if "device_bytes_estimate" in est else "")
        print(f"[ok] {tag}: compile={rec['compile_s']:.0f}s "
              f"bytes/dev={rec['device_bytes']/2**30:.2f}GiB "
              f"fits={rec['fits_16GiB']}{est_s} bound={r['bound']} "
              f"t=(c {r['compute_s']*1e3:.2f} | m {r['memory_s']*1e3:.2f} | "
              f"coll {r['collective_s']*1e3:.2f}) ms")
        return rec
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = {"arch": arch_id, "shape": shape_id, "mesh": mesh_kind,
               "error": f"{type(e).__name__}: {e}",
               "traceback": traceback.format_exc()[-4000:]}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}")
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, help="input shape id or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-pq", action="store_true",
                    help="lower the SplitFed baseline (no quantizer)")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--inference-layout-decode", action="store_true",
                    help="decode with the TP-only serving param layout "
                         "(measured neutral-to-worse; see §Perf C1)")
    ap.add_argument("--out", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCH_IDS if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape in (None, "all")) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_one(arch, shape, mesh_kind, args.out,
                              with_pq=not args.no_pq, force=args.force,
                              save_hlo=args.save_hlo,
                              inference_layout=args.inference_layout_decode)
                failures += 1 if "error" in rec else 0
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
