"""Production training launcher.

Runs FedLite split training for any assigned architecture on the installed
device topology. On real hardware this runs under the production mesh
(launch/mesh.py); on this CPU container use --smoke for the reduced configs
(the full configs are exercised via launch/dryrun.py).

  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --smoke \
      --steps 100 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpointing import latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import ARCH_IDS, get_arch
from repro.core.fedlite import TrainState, comm_report, make_train_step
from repro.data.synthetic import make_lm_batch
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import default_pq, make_model
from repro.optim import get_optimizer, warmup_cosine
from repro.sharding import use_mesh
from repro.sharding.rules import param_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--lam", type=float, default=1e-4)
    ap.add_argument("--no-pq", action="store_true", help="SplitFed baseline")
    ap.add_argument("--mesh", choices=["none", "single", "multi"],
                    default="none")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_arch(args.arch, smoke=args.smoke)
    mesh = None if args.mesh == "none" else make_production_mesh(
        multi_pod=args.mesh == "multi")

    with use_mesh(mesh):
        model = make_model(cfg, with_pq=not args.no_pq, lam=args.lam)
        opt = get_optimizer(cfg.optimizer if not args.smoke else "adam",
                            warmup_cosine(args.lr, 10, args.steps))
        step_fn = make_train_step(model, opt, quantize=not args.no_pq)

        params = model.init(jax.random.PRNGKey(0))
        if mesh is not None:
            params = jax.tree.map(
                lambda x, s: jax.device_put(x, s) if s is not None else x,
                params, param_shardings(params, mesh))
        state = TrainState.create(params, opt)
        start = 0
        if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
            start = latest_step(args.ckpt_dir)
            state = TrainState(
                params=restore_checkpoint(args.ckpt_dir, start)["params"],
                opt_state=state.opt_state, step=jnp.asarray(start))
            print(f"resumed from step {start}")

        rep = comm_report(model, state.params, tokens_per_client=args.seq)
        if "activation_compression_ratio" in rep:
            print(f"uplink compression: "
                  f"{rep['activation_compression_ratio']:.0f}x activations, "
                  f"{rep['uplink_reduction_vs_splitfed']:.1f}x total vs SplitFed")

        def make_batch(key):
            if cfg.num_codebooks > 1:   # audio: (B, K, S) token grids
                t = jax.random.randint(key, (args.batch, cfg.num_codebooks,
                                             args.seq), 0, cfg.vocab_size)
                return {"tokens": t, "labels": t}
            if cfg.family == "vlm":     # stubbed patch embeddings + text
                k1, k2 = jax.random.split(key)
                s_vis = args.seq // 4
                s_txt = args.seq - s_vis
                pos = jnp.broadcast_to(
                    jnp.arange(args.seq, dtype=jnp.int32),
                    (3, args.batch, args.seq))
                toks = jax.random.randint(k1, (args.batch, s_txt), 0,
                                          cfg.vocab_size)
                return {
                    "tokens": toks,
                    "vision_embeds": jax.random.normal(
                        k2, (args.batch, s_vis, cfg.vision_embed_dim)),
                    "positions": pos,
                    "labels": jnp.concatenate(
                        [jnp.full((args.batch, s_vis), -1, jnp.int32),
                         toks], axis=1),
                }
            return make_lm_batch(key, args.batch, args.seq, cfg.vocab_size)

        t0 = time.time()
        for s in range(start, args.steps):
            batch = make_batch(jax.random.fold_in(jax.random.PRNGKey(1), s))
            state, m = step_fn(state, batch)
            if s % args.log_every == 0 or s == args.steps - 1:
                print(f"step {s:5d}  loss={float(m['loss']):.4f}  "
                      f"ce={float(m['ce']):.4f}  "
                      f"{(time.time() - t0):.0f}s")
            if args.ckpt_dir and args.ckpt_every and \
                    (s + 1) % args.ckpt_every == 0:
                save_checkpoint(args.ckpt_dir, s + 1, {"params": state.params})
        print("done")


if __name__ == "__main__":
    main()
