"""Synthetic federated datasets (offline stand-ins for FEMNIST/StackOverflow).

The real TFF datasets are unavailable offline; these generators reproduce the
*structural* properties the paper's claims depend on:

  * non-IID client partitions — per-client Dirichlet(α) label/topic skew
    (Kairouz et al. 2019 §3.1's standard simulation of FL heterogeneity);
  * learnable signal — class prototypes + noise (images), per-client
    topic-biased Markov chains (LM), topic-linked multi-hot tags — so
    accuracy-vs-compression orderings (Figs. 4/5) are meaningful;
  * within-batch activation redundancy — examples of the same class/topic
    produce similar cut-layer activations, the redundancy FedLite exploits.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class FederatedDataset:
    """num_clients client shards; sample_batch(client_id, key, batch) -> dict."""
    num_clients: int
    client_weights: np.ndarray                    # p_i ∝ n_i
    sample_batch: Callable[[int, jax.Array, int], Dict[str, jax.Array]]
    eval_batch: Callable[[jax.Array, int], Dict[str, jax.Array]]


def _dirichlet_partition(rng: np.random.Generator, num_clients: int,
                         num_classes: int, alpha: float) -> np.ndarray:
    """(num_clients, num_classes) class-mixture per client."""
    return rng.dirichlet(alpha * np.ones(num_classes), size=num_clients)


# ---------------------------------------------------------------------------
# images (FEMNIST-like)
# ---------------------------------------------------------------------------

def make_federated_image_data(num_clients: int = 64, num_classes: int = 62,
                              alpha: float = 0.5, noise: float = 0.35,
                              seed: int = 0) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(num_classes, 28, 28, 1)).astype(np.float32)
    # smooth prototypes a little so conv nets have local structure to use
    k = np.ones((3, 3)) / 9.0
    for c in range(num_classes):
        from numpy.lib.stride_tricks import sliding_window_view
        padded = np.pad(protos[c, :, :, 0], 1, mode="edge")
        protos[c, :, :, 0] = (sliding_window_view(padded, (3, 3)) * k).sum((-1, -2))
    mixtures = _dirichlet_partition(rng, num_clients, num_classes, alpha)
    weights = rng.integers(50, 500, size=num_clients).astype(np.float64)
    weights /= weights.sum()
    protos_j = jnp.asarray(protos)
    mix_j = jnp.asarray(mixtures)

    def sample(client_id: int, key: jax.Array, batch: int):
        k1, k2 = jax.random.split(key)
        labels = jax.random.categorical(
            k1, jnp.log(mix_j[client_id] + 1e-9), shape=(batch,))
        imgs = protos_j[labels] + noise * jax.random.normal(
            k2, (batch, 28, 28, 1))
        return {"image": imgs, "label": labels}

    def eval_batch(key: jax.Array, batch: int):
        k1, k2 = jax.random.split(key)
        labels = jax.random.randint(k1, (batch,), 0, num_classes)
        imgs = protos_j[labels] + noise * jax.random.normal(
            k2, (batch, 28, 28, 1))
        return {"image": imgs, "label": labels}

    return FederatedDataset(num_clients, weights, sample, eval_batch)


# ---------------------------------------------------------------------------
# language modeling (SO NWP-like and big-arch token streams)
# ---------------------------------------------------------------------------

def make_federated_lm_data(num_clients: int = 64, vocab: int = 10_000,
                           num_topics: int = 16, alpha: float = 0.3,
                           seed: int = 0) -> FederatedDataset:
    """Per-topic unigram tables + per-client topic mixtures; first-order
    Markov structure (topic-dependent bigram shift) gives NWP signal."""
    rng = np.random.default_rng(seed)
    topic_logits = rng.normal(scale=2.0, size=(num_topics, vocab)).astype(np.float32)
    shifts = rng.integers(1, vocab - 1, size=num_topics)
    mixtures = _dirichlet_partition(rng, num_clients, num_topics, alpha)
    weights = rng.integers(50, 500, size=num_clients).astype(np.float64)
    weights /= weights.sum()

    # NOTE: the generator is pure numpy on purpose — the eager jax version
    # (threefry splits inside a lax.scan) intermittently hits an XLA CPU
    # "Failed to materialize symbols" JIT failure in long benchmark
    # processes; data generation needs no accelerator anyway.
    def _seed_of(key) -> int:
        return int(np.asarray(jax.random.key_data(key)).astype(np.uint64)[-1])

    def _gen(key, batch, seq, mixture):
        r = np.random.default_rng(_seed_of(key))
        topics = r.choice(num_topics, p=mixture / mixture.sum(), size=batch)
        logits = topic_logits[topics]                       # (B, V)

        def categorical():
            g = r.gumbel(size=(batch, vocab)).astype(np.float32)
            return np.argmax(logits + g, axis=-1)

        toks = np.empty((batch, seq), np.int64)
        toks[:, 0] = categorical()
        for t in range(1, seq):
            # token_t = (token_{t-1} + shift_topic) % V w.p. .5 else unigram
            markov = (toks[:, t - 1] + shifts[topics]) % vocab
            uni = categorical()
            use_markov = r.random(batch) < 0.5
            toks[:, t] = np.where(use_markov, markov, uni)
        labels = np.concatenate(
            [toks[:, 1:], np.full((batch, 1), -1, np.int64)], axis=1)
        return {"tokens": jnp.asarray(toks, jnp.int32),
                "labels": jnp.asarray(labels, jnp.int32)}

    def sample(client_id: int, key: jax.Array, batch: int, seq: int = 30):
        return _gen(key, batch, seq, mixtures[client_id])

    def eval_batch(key: jax.Array, batch: int, seq: int = 30):
        return _gen(key, batch, seq, np.ones(num_topics) / num_topics)

    return FederatedDataset(num_clients, weights, sample, eval_batch)


def make_lm_batch(key: jax.Array, batch: int, seq: int, vocab: int):
    """Plain random-token batch for smoke tests / dry-run-shaped runs."""
    toks = jax.random.randint(key, (batch, seq), 0, vocab, dtype=jnp.int32)
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((batch, 1), -1, jnp.int32)], axis=1)
    return {"tokens": toks, "labels": labels}


# ---------------------------------------------------------------------------
# tag prediction (SO Tag-like, multi-label bow)
# ---------------------------------------------------------------------------

def make_federated_tag_data(num_clients: int = 64, bow_dim: int = 5000,
                            num_tags: int = 1000, num_topics: int = 32,
                            alpha: float = 0.3, seed: int = 0) -> FederatedDataset:
    rng = np.random.default_rng(seed)
    topic_words = rng.normal(scale=1.0, size=(num_topics, bow_dim)).astype(np.float32)
    topic_tags = np.zeros((num_topics, num_tags), np.float32)
    for t in range(num_topics):
        topic_tags[t, rng.choice(num_tags, size=12, replace=False)] = 1.0
    mixtures = _dirichlet_partition(rng, num_clients, num_topics, alpha)
    weights = rng.integers(50, 500, size=num_clients).astype(np.float64)
    weights /= weights.sum()
    tw, tt, mix_j = jnp.asarray(topic_words), jnp.asarray(topic_tags), jnp.asarray(mixtures)

    def _gen(key, batch, mixture):
        kt, kw, kg = jax.random.split(key, 3)
        topics = jax.random.categorical(kt, jnp.log(mixture + 1e-9), shape=(batch,))
        bow = jax.nn.relu(tw[topics] + 0.5 * jax.random.normal(kw, (batch, bow_dim)))
        tags = tt[topics]
        drop = jax.random.bernoulli(kg, 0.25, tags.shape)
        return {"bow": bow, "tags": (tags * (1 - drop)).astype(jnp.float32)}

    def sample(client_id: int, key: jax.Array, batch: int):
        return _gen(key, batch, mix_j[client_id])

    def eval_batch(key: jax.Array, batch: int):
        return _gen(key, batch, jnp.ones((num_topics,)) / num_topics)

    return FederatedDataset(num_clients, weights, sample, eval_batch)
