from repro.data.synthetic import (
    FederatedDataset,
    make_federated_image_data,
    make_federated_lm_data,
    make_federated_tag_data,
    make_lm_batch,
)

__all__ = [
    "FederatedDataset",
    "make_federated_image_data",
    "make_federated_lm_data",
    "make_federated_tag_data",
    "make_lm_batch",
]
