"""Grouped-query attention with RoPE/M-RoPE, three execution paths:

  * ``row_block``: causal (optionally windowed) attention computed in query
    row-blocks via ``lax.scan`` — peak memory O(q_chunk · S_kv) instead of
    O(S²). The block body is wrapped in ``jax.checkpoint`` so the backward
    pass rematerializes per-block probabilities instead of storing them.
  * ``local``: exact sliding-window attention for long sequences — queries are
    reshaped into window-sized blocks that attend to (previous ‖ own) key
    blocks; compute is O(S · 2W) rather than O(S²).
  * ``decode``: one query token against a (possibly ring-buffered) KV cache.

KV caches are dicts {k, v, pos}; ``pos`` records the absolute position held
in each slot so windowed ring buffers and full caches share one code path.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, row
from repro.models.rope import apply_rope, rope_angles
from repro.sharding import shard, shard_residual

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def attn_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    D, Q, KV = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], D, Q, dtype),
        "wk": dense_init(ks[1], D, KV, dtype),
        "wv": dense_init(ks[2], D, KV, dtype),
        "wo": dense_init(ks[3], Q, D, dtype),
    }
    if cfg.use_bias:
        p["wq_b"] = jnp.zeros((Q,), dtype)
        p["wk_b"] = jnp.zeros((KV,), dtype)
        p["wv_b"] = jnp.zeros((KV,), dtype)
        p["wo_b"] = jnp.zeros((D,), dtype)
    return p


def _project(p, x, cfg, angles):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,Kv,hd) with RoPE applied."""
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "wq_b" in p:
        q = q + row(p["wq_b"], q.ndim)
        k = k + row(p["wk_b"], k.ndim)
        v = v + row(p["wv_b"], v.ndim)
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    q = apply_rope(q, angles)
    k = apply_rope(k, angles)
    return q, k, v


# ---------------------------------------------------------------------------
# score computation (shared)
# ---------------------------------------------------------------------------

def _gqa_scores(q, k, scale):
    """q: (B,Sq,Kv,G,hd), k: (B,Skv,Kv,hd) -> (B,Kv,G,Sq,Skv) fp32."""
    return jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                      preferred_element_type=jnp.float32) * scale


def _gqa_out(probs, v):
    """probs: (B,Kv,G,Sq,Skv), v: (B,Skv,Kv,hd) -> (B,Sq,Kv,G,hd)."""
    return jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v)


def _mask(qpos, kpos, window: Optional[int]):
    """(Sq,) x (Skv,) -> (Sq, Skv) bool keep-mask: causal + sliding window."""
    m = qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    m &= kpos[None, :] >= 0  # invalid / unwritten slots carry pos = -1
    return m


# ---------------------------------------------------------------------------
# path 1: row-block causal attention
# ---------------------------------------------------------------------------

def row_block_attention(q, k, v, qpos, kpos, *, window: Optional[int],
                        q_chunk: int, scale: float):
    """q: (B,Sq,H,hd), k/v: (B,Skv,Kv,hd), qpos: (Sq,), kpos: (Skv,)."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, hd)

    if Sq % q_chunk != 0:
        q_chunk = Sq  # small sequences: single block
    nb = Sq // q_chunk

    @jax.checkpoint
    def block(qb, qpb):
        s = _gqa_scores(qb, k, scale)
        keep = _mask(qpb, kpos, window)
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_out(p, v)

    if nb == 1:
        out = block(qg, qpos)
    else:
        qb = qg.reshape(B, nb, q_chunk, Kv, G, hd).transpose(1, 0, 2, 3, 4, 5)
        qpb = qpos.reshape(nb, q_chunk)
        _, outs = jax.lax.scan(lambda c, x: (c, block(*x)), None, (qb, qpb))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Kv, G, hd)
    return out.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# path 2: exact block-local sliding-window attention
# ---------------------------------------------------------------------------

def local_window_attention(q, k, v, qpos, kpos, *, window: int, scale: float):
    """Exact SWA when S % window == 0: block b attends to blocks {b-1, b}."""
    B, S, H, hd = q.shape
    Kv = k.shape[2]
    G = H // Kv
    W = window
    assert S % W == 0, "local attention requires seq divisible by window"
    nb = S // W

    qg = q.reshape(B, nb, W, Kv, G, hd)
    kb = k.reshape(B, nb, W, Kv, hd)
    vb = v.reshape(B, nb, W, Kv, hd)
    # previous block (zeros + pos=-1 for block 0)
    prev = lambda x: jnp.concatenate([jnp.zeros_like(x[:, :1]), x[:, :-1]], axis=1)
    k2 = jnp.concatenate([prev(kb), kb], axis=2)  # (B, nb, 2W, Kv, hd)
    v2 = jnp.concatenate([prev(vb), vb], axis=2)
    qpb = qpos.reshape(nb, W)
    kpb = kpos.reshape(nb, W)
    kprev = jnp.concatenate([jnp.full((1, W), -1, kpos.dtype), kpb[:-1]], axis=0)
    kpb2 = jnp.concatenate([kprev, kpb], axis=1)  # (nb, 2W)

    @jax.checkpoint
    def block(qb, kb_, vb_, qp, kp):
        s = _gqa_scores(qb, kb_, scale)
        keep = _mask(qp, kp, W)
        s = jnp.where(keep[None, None, None], s, NEG_INF)
        return _gqa_out(jax.nn.softmax(s, axis=-1), vb_)

    out = jax.vmap(block, in_axes=(1, 1, 1, 0, 0), out_axes=1)(
        qg, k2, v2, qpb, kpb2)
    return out.reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# path 3: single-token decode against a cache
# ---------------------------------------------------------------------------

def decode_attention(q, cache_k, cache_v, cache_pos, qpos, *,
                     window: Optional[int], scale: float):
    """q: (B,1,H,hd); cache_k/v: (B,Sc,Kv,hd); cache_pos: (Sc,); qpos scalar."""
    B, _, H, hd = q.shape
    Kv = cache_k.shape[2]
    G = H // Kv
    qg = q.reshape(B, 1, Kv, G, hd)
    s = _gqa_scores(qg, cache_k, scale)  # (B,Kv,G,1,Sc)
    keep = _mask(jnp.asarray(qpos)[None], cache_pos, window)  # (1, Sc)
    s = jnp.where(keep[None, None, None], s, NEG_INF)
    out = _gqa_out(jax.nn.softmax(s, axis=-1), cache_v)
    return out.reshape(B, 1, H, hd)


# ---------------------------------------------------------------------------
# full block: projections + attention + output
# ---------------------------------------------------------------------------

def init_attn_cache(cfg, batch: int, max_len: int, dtype):
    """Cache length = window size for SWA models (ring buffer), else max_len."""
    Sc = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {
        "k": jnp.zeros((batch, Sc, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, Sc, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.full((Sc,), -1, jnp.int32),
    }


def apply_attention(p, x, cfg, positions, *, mode: str = "train",
                    cache=None, decode_pos=None):
    """Attention block.

    mode "train"/"prefill": x (B,S,D), positions (B,S) or (3,B,S) for M-RoPE.
      prefill additionally fills and returns the cache.
    mode "decode": x (B,1,D); decode_pos scalar absolute position; cache req'd.
    Returns (y, new_cache).
    """
    scale = 1.0 / math.sqrt(cfg.head_dim)
    angles = rope_angles(positions, cfg.head_dim, cfg.rope_theta,
                         cfg.mrope_sections)
    q, k, v = _project(p, x, cfg, angles)
    B, S = x.shape[:2]
    # token positions along the sequence (1D; batch-uniform by construction)
    pos1d = positions[0, 0] if positions.ndim == 3 else positions[0]

    new_cache = cache
    if mode == "decode":
        assert cache is not None
        Sc = cache["k"].shape[1]
        slot = jnp.mod(decode_pos, Sc)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], jnp.asarray(decode_pos, jnp.int32)[None], slot, axis=0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        out = decode_attention(q, ck, cv, cpos, decode_pos,
                               window=cfg.sliding_window, scale=scale)
    else:
        if cfg.sliding_window and S > 2 * cfg.sliding_window and S % cfg.sliding_window == 0:
            out = local_window_attention(q, k, v, pos1d, pos1d,
                                         window=cfg.sliding_window, scale=scale)
        else:
            out = row_block_attention(q, k, v, pos1d, pos1d,
                                      window=cfg.sliding_window,
                                      q_chunk=cfg.attn_q_chunk, scale=scale)
        if mode == "prefill":
            assert cache is not None
            Sc = cache["k"].shape[1]
            if Sc >= S:
                ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, axis=1)
                cpos = jax.lax.dynamic_update_slice_in_dim(
                    cache["pos"], pos1d.astype(jnp.int32), 0, axis=0)
            else:  # windowed ring cache: keep the last Sc tokens, ring-aligned
                # slot invariant: position p lives in slot p % Sc, so later
                # decode writes (slot = pos % Sc) evict exactly the oldest token
                shift = S % Sc
                ck = jnp.roll(k[:, S - Sc:], shift, axis=1)
                cv = jnp.roll(v[:, S - Sc:], shift, axis=1)
                cpos = jnp.roll(pos1d[S - Sc:].astype(jnp.int32), shift, axis=0)
            new_cache = {"k": ck, "v": cv, "pos": cpos}

    y = out.reshape(B, S, cfg.q_dim) @ p["wo"]
    if "wo_b" in p:
        y = y + row(p["wo_b"], y.ndim)
    return shard_residual(y), new_cache
