"""Unified decoder LM covering all six assigned families.

A model is a repeated *period* of blocks (``cfg.layer_pattern``): pure dense
archs have period ("attn",); jamba has an 8-block mamba/attention interleave;
MoE FFNs replace dense FFNs on layers selected by (moe_period, moe_offset).
Weights for each position in the period are stacked over periods and the
period is applied under ``lax.scan`` (+ per-period remat for training), so
HLO size and compile time are independent of depth.

FedLite split: ``params = {"client": ..., "server": ...}``. The client owns
the embedding (+ modality projector) and the first ``cfg.cut_periods``
periods; the server owns the rest, the final norm and the (frequently
enormous — 256k vocab) LM head, exactly the paper's resource-constrained
regime. ``client_forward`` emits the cut-layer activation that FedLite
quantizes.

Modality carve-out (per assignment): VLM vision towers and audio codecs are
stubs — batches carry precomputed ``vision_embeds`` (projected here) or
multi-codebook token grids; this module implements only the decoder backbone.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.compressors import (CutCompressor, CutState, PQCompressor,
                                    compress_downlink,
                                    compress_downlink_keyed,
                                    compress_with_correction_carry,
                                    compress_with_correction_stats)
from repro.core.correction import quantize_with_correction_stats
from repro.core.quantizer import PQConfig
from repro.core.split import dtype_bits
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, embed_init, dense_init,
                                 mlp_init, norm_init)
from repro.sharding import shard, shard_residual

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TransformerLM:
    cfg: ArchConfig
    pq: Optional[PQConfig] = None     # FedLite quantizer at the cut layer
    lam: float = 0.0                  # gradient-correction strength (eq. 5)
    downlink_pq: Optional[PQConfig] = None  # legacy: PQ on the downlink
    #                                   (subsumed by downlink_compressor)
    # direction-agnostic cut-layer codecs (core/compressors.py):
    # uplink_compressor replaces the PQ fast path when set; the downlink
    # compressor squeezes the server->client gradient COTANGENT in the VJP
    uplink_compressor: Optional[CutCompressor] = None
    downlink_compressor: Optional[CutCompressor] = None

    # ------------------------------------------------------------------ init
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        k_embed, k_client, k_server, k_head, k_vis = jax.random.split(key, 5)

        client: Params = {}
        if cfg.num_codebooks > 1:
            client["tok_embed"] = jnp.stack([
                embed_init(k, cfg.padded_vocab, cfg.d_model, dtype)
                for k in jax.random.split(k_embed, cfg.num_codebooks)])
        else:
            client["tok_embed"] = embed_init(k_embed, cfg.padded_vocab,
                                             cfg.d_model, dtype)
        if cfg.vision_embed_dim:
            client["vision_proj"] = dense_init(k_vis, cfg.vision_embed_dim,
                                               cfg.d_model, dtype)
        client["layers"] = self._init_stack(k_client, cfg.cut_periods, dtype)

        server: Params = {
            "layers": self._init_stack(
                k_server, cfg.num_periods - cfg.cut_periods, dtype),
            "final_norm": norm_init(cfg.d_model, cfg.norm_type, dtype),
        }
        if not cfg.tie_embeddings:
            if cfg.num_codebooks > 1:
                server["head"] = jnp.stack([
                    dense_init(k, cfg.d_model, cfg.padded_vocab, dtype)
                    for k in jax.random.split(k_head, cfg.num_codebooks)])
            else:
                server["head"] = dense_init(k_head, cfg.d_model,
                                            cfg.padded_vocab, dtype)
        return {"client": client, "server": server}

    def _init_stack(self, key, n_periods: int, dtype) -> Params:
        cfg = self.cfg

        def init_period(k):
            p = {}
            ks = jax.random.split(k, cfg.period)
            for pos in range(cfg.period):
                kk = jax.random.split(ks[pos], 3)
                kind = cfg.layer_pattern[pos]
                lp = {"ln1": norm_init(cfg.d_model, cfg.norm_type, dtype),
                      "ln2": norm_init(cfg.d_model, cfg.norm_type, dtype)}
                if kind == "attn":
                    lp["mixer"] = attn_mod.attn_init(kk[0], cfg, dtype)
                else:
                    lp["mixer"] = ssm_mod.ssm_init(kk[0], cfg, dtype)
                if self._pos_is_moe(pos):
                    lp["ffn"] = moe_mod.moe_init(kk[1], cfg, dtype)
                elif cfg.d_ff:
                    lp["ffn"] = mlp_init(kk[1], cfg.d_model, cfg.d_ff,
                                         cfg.mlp_type, cfg.use_bias, dtype)
                p[f"p{pos}"] = lp
            return p

        keys = jax.random.split(key, max(n_periods, 1))[:n_periods]
        periods = [init_period(k) for k in keys]
        if not periods:
            return {}
        return jax.tree.map(lambda *xs: jnp.stack(xs), *periods)

    def _pos_is_moe(self, pos: int) -> bool:
        # valid because period % moe_period == 0 and the cut offset is a whole
        # number of periods, so the flag is position-static across the scan
        return bool(self.cfg.num_experts) and \
            (pos % self.cfg.moe_period == self.cfg.moe_offset)

    # ----------------------------------------------------------- embeddings
    def embed(self, client_params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        emb = client_params["tok_embed"]
        tokens = batch["tokens"]
        if cfg.num_codebooks > 1:       # audio: (B, K, S) token grid
            x = sum(jnp.take(emb[k], tokens[:, k], axis=0)
                    for k in range(cfg.num_codebooks))
        else:
            x = jnp.take(emb, tokens, axis=0)
        if cfg.scale_embed:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        if cfg.vision_embed_dim and "vision_embeds" in batch:
            vis = batch["vision_embeds"].astype(x.dtype) @ client_params["vision_proj"]
            x = jnp.concatenate([vis, x], axis=1)
        x = x.astype(cfg.compute_dtype)
        return shard_residual(x)

    # ------------------------------------------------------------- periods
    def _apply_period(self, pp: Params, x, positions, mode, caches, decode_pos):
        cfg = self.cfg
        aux = jnp.zeros((), jnp.float32)
        new_caches = {} if caches is not None else None
        # nested remat: with multi-block periods (jamba's 8), rematerializing
        # the whole period at once would hold every block's internals (SSD
        # chunk stacks, MoE buffers) live simultaneously during the backward
        # pass — per-block checkpoints keep only one block's internals alive
        inner_ckpt = (mode == "train" and cfg.remat and cfg.period > 1)

        def maybe_ckpt(fn):
            return jax.checkpoint(fn) if inner_ckpt else fn

        for pos in range(cfg.period):
            lp = pp[f"p{pos}"]
            kind = cfg.layer_pattern[pos]
            cache = caches[f"p{pos}"] if caches is not None else None

            if kind == "attn":
                def mixer_fn(lp_, x_, cache_):
                    h = apply_norm(lp_["ln1"], x_, cfg.norm_type, cfg.norm_eps)
                    return attn_mod.apply_attention(
                        lp_["mixer"], h, cfg, positions, mode=mode,
                        cache=cache_, decode_pos=decode_pos)
            else:
                def mixer_fn(lp_, x_, cache_):
                    h = apply_norm(lp_["ln1"], x_, cfg.norm_type, cfg.norm_eps)
                    return ssm_mod.apply_ssm(lp_["mixer"], h, cfg, mode=mode,
                                             cache=cache_)
            y, new_c = maybe_ckpt(mixer_fn)(lp, x, cache)
            x = x + y
            if "ffn" in lp:
                if self._pos_is_moe(pos):
                    def ffn_fn(lp_, x_):
                        h = apply_norm(lp_["ln2"], x_, cfg.norm_type,
                                       cfg.norm_eps)
                        return moe_mod.apply_moe(lp_["ffn"], h, cfg)
                    y, a = maybe_ckpt(ffn_fn)(lp, x)
                    aux = aux + a
                else:
                    def ffn_fn(lp_, x_):
                        h = apply_norm(lp_["ln2"], x_, cfg.norm_type,
                                       cfg.norm_eps)
                        return apply_mlp(lp_["ffn"], h, cfg.mlp_type)
                    y = maybe_ckpt(ffn_fn)(lp, x)
                x = x + y
            if new_caches is not None:
                new_caches[f"p{pos}"] = new_c
        return x, new_caches, aux

    def _run_stack(self, layers: Params, x, positions, mode, caches, decode_pos):
        """Scan the stacked periods. caches: stacked pytree or None."""
        if not layers:
            return x, caches, jnp.zeros((), jnp.float32)
        cfg = self.cfg

        has_caches = caches is not None

        def body(carry, xs):
            x, aux = carry
            pslice, cslice = xs
            x, new_c, a = self._apply_period(pslice, x, positions, mode,
                                             cslice if has_caches else None,
                                             decode_pos)
            return (x, aux + a), (new_c if has_caches else cslice)

        if cfg.remat and mode == "train":
            policy = None
            if cfg.remat_policy == "dots":
                # save matmul outputs across the period boundary: trades HBM
                # headroom for skipping most of the backward recompute pass
                policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            body = jax.checkpoint(body, policy=policy)

        n = jax.tree.leaves(layers)[0].shape[0]
        cs = caches if caches is not None else _none_like(layers, n)
        (x, aux), new_caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                            (layers, cs))
        if caches is None:
            new_caches = None
        return x, new_caches, aux

    # ------------------------------------------------------- fedlite split
    def client_forward(self, client_params: Params, batch, *, mode="train",
                       caches=None, decode_pos=None):
        """Embed + first cut_periods periods -> cut-layer activation."""
        x = self.embed(client_params, batch)
        positions = self._positions(batch, x.shape[1], decode_pos)
        x, new_caches, aux = self._run_stack(client_params["layers"], x,
                                             positions, mode, caches, decode_pos)
        return x, new_caches, aux

    def _downlink(self) -> Optional[CutCompressor]:
        if self.downlink_compressor is not None:
            return self.downlink_compressor
        if self.downlink_pq is not None:       # legacy PQConfig field
            return PQCompressor(self.downlink_pq)
        return None

    def cut_activation(self, x: jax.Array, *, quantize: bool,
                       lam_override=None, key: Optional[jax.Array] = None,
                       cut_state: Optional[CutState] = None
                       ) -> Tuple[jax.Array, Dict]:
        """Apply the cut-layer codecs (paper Fig. 1 generalized) at the cut.

        Each batch row (sequence) is one *client*: codebooks are built
        per-row (vmap), matching the paper's per-client, per-iteration
        clustering — and making the compression step embarrassingly parallel
        over the batch-sharded mesh axis (zero added collectives).

        Uplink: ``pq`` (the paper's grouped PQ with the corrected VJP — the
        exact pre-refactor path) unless ``uplink_compressor`` overrides it.
        Downlink: ``downlink_compressor`` squeezes the activation COTANGENT
        inside the VJP before it reaches the client stack; ``None``/"none"
        leaves the backward pass untouched bitwise.

        ``cut_state`` (leaves carrying a leading batch/client axis) routes
        the uplink through the state-carrying hook — cross-round codebook
        warm-start + optional error feedback — and the updated state comes
        back under ``stats["cut_state"]``. ``key`` makes the downlink codec
        round stochastically. Both default to ``None``: the historical
        bitwise-identical path.
        """
        up = self.uplink_compressor
        dl = self._downlink()
        has_up = quantize and (up is not None or self.pq is not None)
        has_dl = quantize and dl is not None and dl.name != "none"
        if not has_up and not has_dl:
            return x, {}
        # gather each client's (sequence-sharded) activation so the per-client
        # compression runs locally — exactly what a real client does, and it
        # keeps the codecs free of collectives
        x = shard(x, ("pod", "data"), None, None)
        lam = self.lam if lam_override is None else lam_override
        n_per_client = int(x.shape[1])  # tokens per client (= sequence)
        phi = dtype_bits(getattr(self.cfg, "dtype", "float32"))
        z_tilde, stats = x, {}
        if has_up and cut_state is not None:
            comp = up if up is not None else PQCompressor(self.pq)
            z_tilde, dist, new_state = jax.vmap(
                lambda zi, si: compress_with_correction_carry(
                    zi, lam, si, comp))(x, cut_state)
            stats = {"pq_distortion": jnp.mean(dist),
                     "cut_state": new_state}
            # same wire accounting the stateless branches emit, so metrics
            # consumers see identical keys with the carry on or off
            if up is None:
                stats.update({
                    "pq_message_bits": float(
                        x.shape[0] * self.pq.message_bits(n_per_client,
                                                          x.shape[-1])),
                    "pq_compression_ratio": float(
                        self.pq.compression_ratio(n_per_client,
                                                  x.shape[-1])),
                })
            else:
                msg = up.analytic_bits(n_per_client, x.shape[-1],
                                       phi_bits=phi)
                stats.update({
                    "uplink_message_bits": float(x.shape[0] * msg),
                    "uplink_compression_ratio":
                        phi * n_per_client * x.shape[-1] / max(msg, 1),
                })
        elif has_up and up is None:
            # the PQ fast path: fused backend encode + residual reuse
            z_tilde, dist = jax.vmap(
                lambda zi: quantize_with_correction_stats(zi, lam, self.pq))(x)
            stats = {
                "pq_distortion": jnp.mean(dist),
                "pq_message_bits": float(
                    x.shape[0] * self.pq.message_bits(n_per_client,
                                                      x.shape[-1])),
                "pq_compression_ratio": float(
                    self.pq.compression_ratio(n_per_client, x.shape[-1])),
            }
        elif has_up:
            z_tilde, dist = jax.vmap(
                lambda zi: compress_with_correction_stats(zi, lam, up))(x)
            msg = up.analytic_bits(n_per_client, x.shape[-1], phi_bits=phi)
            stats = {
                "pq_distortion": jnp.mean(dist),
                "uplink_message_bits": float(x.shape[0] * msg),
                "uplink_compression_ratio":
                    phi * n_per_client * x.shape[-1] / max(msg, 1),
            }
        if has_dl:
            if key is None:
                z_tilde = jax.vmap(
                    lambda zi: compress_downlink(zi, dl))(z_tilde)
            else:
                dkeys = jax.random.split(key, z_tilde.shape[0])
                z_tilde = jax.vmap(
                    lambda zi, ki: compress_downlink_keyed(
                        zi, ki, dl))(z_tilde, dkeys)
            stats["downlink_message_bits"] = float(
                x.shape[0] * dl.analytic_bits(n_per_client, x.shape[-1],
                                              phi_bits=phi))
        z_tilde = shard_residual(z_tilde)
        return z_tilde, stats

    def server_forward(self, server_params: Params, acts, batch, *, mode="train",
                       caches=None, decode_pos=None):
        positions = self._positions(batch, acts.shape[1], decode_pos)
        x, new_caches, aux = self._run_stack(server_params["layers"], acts,
                                             positions, mode, caches, decode_pos)
        x = apply_norm(server_params["final_norm"], x, self.cfg.norm_type,
                       self.cfg.norm_eps)
        return x, new_caches, aux

    def head_matrix(self, params: Params) -> jax.Array:
        """(D, Vp) LM head in column-parallel layout. For tied embeddings the
        (d_model-sharded) table is transposed and re-constrained HERE — once,
        outside the CE chunk scan — so the vocab-sharded layout is
        established before any (B, chunk, V) logits exist."""
        cfg = self.cfg
        if cfg.tie_embeddings:
            head = params["client"]["tok_embed"].T  # (D, Vp)
        else:
            head = params["server"]["head"]
        if cfg.num_codebooks > 1:
            return shard(head, None, "data", "model")
        return shard(head, "data", "model")

    def logits(self, params: Params, x: jax.Array,
               head: Optional[jax.Array] = None) -> jax.Array:
        cfg = self.cfg
        head = head if head is not None else self.head_matrix(params)
        if cfg.num_codebooks > 1:
            out = jnp.einsum("bsd,kdv->bskv", x, head.astype(x.dtype))
        else:
            out = x @ head.astype(x.dtype)
        return shard(out.astype(jnp.float32), ("pod", "data"), None, "model")

    # ------------------------------------------------------------- losses
    def loss(self, params: Params, batch, *, quantize: bool = True,
             lam_override=None, key=None, cut_state=None):
        """Full FedLite forward: client -> PQ (+corrected VJP) -> server -> CE."""
        acts, _, aux_c = self.client_forward(params["client"], batch, mode="train")
        acts, pq_stats = self.cut_activation(acts, quantize=quantize,
                                             lam_override=lam_override,
                                             key=key, cut_state=cut_state)
        x, _, aux_s = self.server_forward(params["server"], acts, batch,
                                          mode="train")
        ce = self.chunked_ce(params, x, batch["labels"])
        metrics = {"ce": ce, "aux": aux_c + aux_s, **pq_stats}
        return ce + aux_c + aux_s, metrics

    def chunked_ce(self, params: Params, x: jax.Array, labels: jax.Array,
                   chunk: int = 512) -> jax.Array:
        """CE without materializing full (B, S, V) logits: scan over sequence
        chunks, rematerializing each chunk's logits in the backward pass —
        peak logits memory drops from S/chunk× to 1×."""
        cfg = self.cfg
        if cfg.num_codebooks > 1:
            labels = jnp.moveaxis(labels, 1, 2)          # (B,S,K)
        B, S = x.shape[:2]
        if S % chunk != 0 or S <= chunk:
            lg = self.logits(params, x)
            return self._ce_sum(lg, labels) / jnp.maximum(
                jnp.sum(labels >= 0), 1)

        nc = S // chunk
        xc = x.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3)
        lc = labels.reshape((B, nc, chunk) + labels.shape[2:])
        lc = jnp.moveaxis(lc, 1, 0)
        head = self.head_matrix(params)   # resharded once, outside the scan

        @jax.checkpoint
        def body(carry, inp):
            xb, lb = inp
            lg = self.logits(params, xb, head=head)
            return carry + self._ce_sum(lg, lb), None

        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc))
        return tot / jnp.maximum(jnp.sum(labels >= 0), 1)

    def _ce_sum(self, logits: jax.Array, labels: jax.Array) -> jax.Array:
        """Sum of masked token CE. labels already (B,S[,K])-shaped."""
        vocab_ok = jnp.arange(logits.shape[-1]) < self.cfg.vocab_size
        logits = jnp.where(vocab_ok, logits, -1e30)
        mask = labels >= 0
        safe = jnp.maximum(labels, 0)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
        return jnp.sum((lse - picked) * mask)

    def token_ce(self, logits: jax.Array, labels: jax.Array) -> jax.Array:
        if self.cfg.num_codebooks > 1:   # (B,S,K,V) vs (B,K,S)
            labels = jnp.moveaxis(labels, 1, 2)  # (B,S,K)
        return self._ce_sum(logits, labels) / jnp.maximum(
            jnp.sum(labels >= 0), 1)

    # --------------------------------------------------------- inference
    def init_caches(self, batch_size: int, max_len: int) -> Params:
        cfg = self.cfg
        dtype = cfg.compute_dtype

        def stack_caches(n_periods):
            if n_periods == 0:
                return {}
            per = {}
            for pos in range(cfg.period):
                if cfg.layer_pattern[pos] == "attn":
                    c = attn_mod.init_attn_cache(cfg, batch_size, max_len, dtype)
                else:
                    c = ssm_mod.init_ssm_cache(cfg, batch_size, dtype)
                per[f"p{pos}"] = c
            return jax.tree.map(
                lambda x: jnp.broadcast_to(x, (n_periods,) + x.shape), per)

        return {"client": stack_caches(cfg.cut_periods),
                "server": stack_caches(cfg.num_periods - cfg.cut_periods)}

    def prefill(self, params: Params, batch, caches, *, quantize: bool = False):
        """Process the prompt, fill caches, return last-token logits.

        ``quantize=True`` compresses the cut-layer activation with the paper's
        PQ before it crosses the client->server link (split inference).
        """
        acts, c_caches, _ = self.client_forward(
            params["client"], batch, mode="prefill", caches=caches["client"])
        acts, _ = self.cut_activation(acts, quantize=quantize)
        x, s_caches, _ = self.server_forward(
            params["server"], acts, batch, mode="prefill", caches=caches["server"])
        lg = self.logits(params, x[:, -1:])
        return lg, {"client": c_caches, "server": s_caches}

    def decode_step(self, params: Params, caches, tokens, decode_pos):
        """One token (B,1) / (B,K,1) at absolute position ``decode_pos``."""
        batch = {"tokens": tokens}
        acts, c_caches, _ = self.client_forward(
            params["client"], batch, mode="decode", caches=caches["client"],
            decode_pos=decode_pos)
        x, s_caches, _ = self.server_forward(
            params["server"], acts, batch, mode="decode",
            caches=caches["server"], decode_pos=decode_pos)
        lg = self.logits(params, x)
        return lg, {"client": c_caches, "server": s_caches}

    # ------------------------------------------------------------- helpers
    def _positions(self, batch, seq_len: int, decode_pos):
        cfg = self.cfg
        if "positions" in batch:
            return batch["positions"]
        B = batch["tokens"].shape[0]
        if decode_pos is not None:
            pos = jnp.full((B, 1), decode_pos, jnp.int32)
        else:
            pos = jnp.broadcast_to(jnp.arange(seq_len, dtype=jnp.int32), (B, seq_len))
        if cfg.mrope_sections is not None:
            pos = jnp.broadcast_to(pos, (3,) + pos.shape)
        return pos


def _none_like(layers: Params, n: int):
    """A scannable placeholder cache (zero-size) when no caches are used."""
    return jnp.zeros((n, 0), jnp.float32)
