"""Basic building blocks: inits, norms, dense projections, gated MLPs.

All modules are (init, apply) function pairs over plain dict pytrees —
no framework dependency, trivially shardable via repro.sharding rules.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding import shard, shard_residual


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0):
    std = scale / jnp.sqrt(jnp.asarray(d_in, jnp.float32))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_init(d: int, norm_type: str, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if norm_type == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def row(v, ndim):
    """Explicitly lift a (d,) parameter to rank ``ndim`` for broadcasting
    (the repo runs with jax_numpy_rank_promotion='raise' under test)."""
    return v.reshape((1,) * (ndim - 1) + v.shape)


def apply_norm(p, x, norm_type: str, eps: float):
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) \
            * row(p["scale"].astype(jnp.float32), xf.ndim)
    elif norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * row(p["scale"].astype(jnp.float32), xf.ndim) \
            + row(p["bias"].astype(jnp.float32), xf.ndim)
    else:
        raise ValueError(norm_type)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# dense MLP (swiglu / geglu / gelu)
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, mlp_type: str, use_bias: bool, dtype):
    ks = jax.random.split(key, 3)
    p = {}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], d_model, d_ff, dtype)
        p["w_up"] = dense_init(ks[1], d_model, d_ff, dtype)
    else:  # plain gelu
        p["w_up"] = dense_init(ks[1], d_model, d_ff, dtype)
    p["w_down"] = dense_init(ks[2], d_ff, d_model, dtype)
    if use_bias:
        p["w_up_b"] = jnp.zeros((d_ff,), dtype)
        p["w_down_b"] = jnp.zeros((d_model,), dtype)
    return p


def apply_mlp(p, x, mlp_type: str):
    """x: (..., d_model). Column-parallel up/gate, row-parallel down."""
    if mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    elif mlp_type == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"], approximate=True) * (x @ p["w_up"])
    else:
        h = x @ p["w_up"]
        if "w_up_b" in p:
            h = h + row(p["w_up_b"], h.ndim)
        h = jax.nn.gelu(h, approximate=True)
    h = shard(h, ("pod", "data"), None, "model")
    y = h @ p["w_down"]
    if "w_down_b" in p:
        y = y + row(p["w_down_b"], y.ndim)
    return shard_residual(y) if y.ndim == 3 else y
