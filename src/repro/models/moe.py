"""Mixture-of-Experts layer with grouped, capacity-based scatter dispatch.

Dispatch is GShard/MaxText-style *grouped*: tokens are split into G groups
aligned with the batch sharding (G = pod·data shards when a mesh is
installed), and each group scatters into its own (E, C_g, D) buffer with
per-group capacity C_g = ceil(k·N_g/E · capacity_factor). This keeps the
position-cumsum and the scatter strictly local to a shard — without
grouping, XLA must treat the (E, C, D) scatter operand as replicated
("involuntary full rematerialization"), which costs hundreds of GiB/device
at 1M-token batches.

Expert parallelism: expert-stacked weights are sharded over "model" whenever
E divides the model axis (see sharding/rules.py); the grouped buffer carries
(batch-axes, "model") sharding so the token->expert all-to-all is inserted
by XLA from the constraints alone. When E does not divide (mixtral's 8
experts on a 16-wide axis), weights fall back to FSDP and the buffer shards
its capacity dim over "model" instead.

Overflow beyond capacity is dropped (Switch/GShard semantics, tested).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.sharding import axis_size, shard, shard_residual


def moe_init(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {"router": dense_init(ks[0], D, E, jnp.float32)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["we_gate"] = _expert_init(ks[1], E, D, F, dtype)
    p["we_up"] = _expert_init(ks[2], E, D, F, dtype)
    p["we_down"] = _expert_init(ks[3], E, F, D, dtype)
    return p


def _expert_init(key, e, d_in, d_out, dtype):
    std = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (e, d_in, d_out), jnp.float32) * std).astype(dtype)


def _buffer_specs(num_experts: int):
    """(ebuf/out spec, hidden spec) for the grouped dispatch buffers.

    Expert-parallel: both sharded over experts. TP-in-expert fallback: the
    (G,E,C,D) buffers shard only over groups; the hidden (G,E,C,F) shards F
    over "model" to match the column-parallel expert weights (Megatron
    pattern), so w_down's row-parallel contraction reduce-scatters back."""
    if num_experts % max(axis_size("model"), 1) == 0:
        ep = (("pod", "data"), "model", None, None)
        return ep, ep
    return ((("pod", "data"), None, None, None),
            (("pod", "data"), None, None, "model"))


def _num_groups(batch: int) -> int:
    """Dispatch groups = batch shards (so each group is shard-local)."""
    shards = max(axis_size("pod"), 1) * max(axis_size("data"), 1)
    if shards > 1 and batch % shards == 0:
        return shards
    return 1


def apply_moe(p, x, cfg):
    """x: (B, S, D) -> (y, aux_loss). Grouped top-k routing with capacity."""
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    G = _num_groups(B)
    N = B * S
    Ng = N // G
    xg = x.reshape(G, Ng, D)

    logits = (xg.astype(jnp.float32) @ p["router"])            # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_idx = jax.lax.top_k(probs, k)                 # (G, Ng, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance auxiliary loss (Switch-style), over ALL tokens
    me = jnp.mean(probs, axis=(0, 1))                          # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32),
                          axis=2), axis=(0, 1))
    aux = E * jnp.sum(me * ce) * cfg.router_aux_weight

    capacity = int(math.ceil(k * Ng / E * cfg.capacity_factor))
    capacity = max(8, -(-capacity // 8) * 8)                   # round up to 8

    def dispatch(xf, idx, w):
        """One group: (Ng, D), (Ng, k), (Ng, k) -> buffer + combine info.

        Scatters one expert-choice at a time (k <= 2 unrolled) — an
        (Ng·k, D) repeated-token buffer would double the live activation
        footprint per MoE layer."""
        flat_idx = idx.reshape(-1)                             # (Ng·k,)
        onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.sum(pos * onehot, axis=-1)
        keep = pos < capacity
        dest = jnp.where(keep, flat_idx * capacity + pos, E * capacity)
        dest2 = dest.reshape(-1, k)                            # (Ng, k)
        buf = jnp.zeros((E * capacity + 1, D), x.dtype)
        for j in range(k):
            buf = buf.at[dest2[:, j]].add(xf)
        return buf[:-1].reshape(E, capacity, D), dest2, keep.reshape(-1, k)

    buf_spec, hid_spec = _buffer_specs(E)
    ebuf, dest, keep = jax.vmap(dispatch)(xg, gate_idx, gate_w)
    ebuf = shard(ebuf, *buf_spec)                              # (G,E,C,D)

    if "we_gate" in p:
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else (
            lambda v: jax.nn.gelu(v, approximate=True))
        h = act(jnp.einsum("gecd,edf->gecf", ebuf, p["we_gate"])) * \
            jnp.einsum("gecd,edf->gecf", ebuf, p["we_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", ebuf, p["we_up"]),
                        approximate=True)
    h = shard(h, *hid_spec)
    out_buf = jnp.einsum("gecf,efd->gecd", h, p["we_down"])
    out_buf = shard(out_buf, *buf_spec)

    def combine(flat_out, dest_g, w, keep_g):
        padded = jnp.concatenate(
            [flat_out.reshape(E * capacity, D), jnp.zeros((1, D), x.dtype)])
        y = jnp.zeros((Ng, D), x.dtype)
        for j in range(k):   # one gather per choice; no (Ng·k, D) buffer
            wj = (w[:, j] * keep_g[:, j]).astype(x.dtype)
            y = y + padded[dest_g[:, j]] * wj[:, None]
        return y

    y = jax.vmap(combine)(out_buf, dest, gate_w, keep)          # (G, Ng, D)
    y = y.reshape(B, S, D)
    return shard_residual(y), aux
