"""Mamba-2 style state-space block (SSD — state-space duality, arXiv:2405.21060).

Recurrence per head h with state (P=head_dim, N=d_state):

    H_t = exp(dt_t·A_h)·H_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · H_t + D_h · x_t

computed with the chunked SSD algorithm: quadratic attention-like compute
inside chunks of ``ssm_chunk`` tokens (MXU-friendly) plus a `lax.scan`
recurrence over chunk boundary states — O(S·Cs) instead of O(S²), and the
scan carry is exactly the decode state, so prefill hands the cache to decode
for free.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, norm_init, apply_norm
from repro.sharding import shard, shard_residual


def _conv_dim(cfg) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def ssm_init(key, cfg, dtype):
    ks = jax.random.split(key, 7)
    D, din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    # four separate projections (z | x | BC | dt) rather than one fused
    # (D, 2·din+2N+H) matrix: slicing a fused model-sharded output at
    # non-shard-aligned boundaries costs XLA a collective-permute chain per
    # block (§Perf C2); separate weights/streams shard cleanly
    p = {
        "in_proj_z": dense_init(ks[5], D, din, dtype),
        "in_proj_x": dense_init(ks[0], D, din, dtype),
        "in_proj_bc": dense_init(ks[4], D, 2 * N, dtype),
        "in_proj_dt": dense_init(ks[6], D, H, dtype),
        "out_proj": dense_init(ks[1], din, D, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv_width, din),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((din,), dtype),
        "conv_w_bc": (jax.random.normal(ks[3], (cfg.ssm_conv_width, 2 * N),
                                        jnp.float32) * 0.1).astype(dtype),
        "conv_b_bc": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "ssm_D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "gate_norm": norm_init(din, "rmsnorm", dtype),
    }
    return p


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted sums. x: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    S = x.shape[1]
    y = sum(pad[:, i:i + S] * w[i][None, None, :] for i in range(W))
    return y + b[None, None, :]


def _project_in(p, x):
    """x -> (z, x_ssm, BC, dt) via the four aligned projections."""
    return (x @ p["in_proj_z"], x @ p["in_proj_x"], x @ p["in_proj_bc"],
            x @ p["in_proj_dt"])


def _segsum_decay(dA):
    """dA: (..., Cs, H) -> decay L (..., H, Cs, Cs): L[i,j]=exp(Σ_{j<t<=i} dA_t)."""
    cum = jnp.cumsum(dA, axis=-2)                       # (..., Cs, H)
    cum = jnp.moveaxis(cum, -1, -2)                     # (..., H, Cs)
    diff = cum[..., :, None] - cum[..., None, :]        # (..., H, Cs, Cs)
    Cs = dA.shape[-2]
    mask = jnp.tril(jnp.ones((Cs, Cs), bool))
    # mask BEFORE exp: upper-triangle diffs are large-positive and exp(·)=inf
    # would poison the backward pass via where's 0·inf
    return jnp.exp(jnp.where(mask, diff, -jnp.inf))


def ssd_scan(xh, dt, A, Bm, Cm, chunk: int,
             h0: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    xh: (B,S,H,P); dt: (B,S,H); A: (H,) negative; Bm/Cm: (B,S,N).
    Returns (y (B,S,H,P), final state (B,H,P,N)).
    """
    B, S, H, P = xh.shape
    N = Bm.shape[-1]
    Cs = min(chunk, S)
    if S % Cs != 0:
        Cs = S
    nc = S // Cs

    # NOTE: scan xs are the RAW tensors (xh, dt, B, C); the derived xdt/dA /
    # decay products are computed inside the checkpointed chunk body — a
    # precomputed (B,S,H,P) xdt stack would add a full activation-sized
    # buffer per SSM layer that lives for the whole scan
    xc = xh.reshape(B, nc, Cs, H, P)
    dtc = dt.reshape(B, nc, Cs, H)
    Bc = Bm.reshape(B, nc, Cs, N)
    Cc = Cm.reshape(B, nc, Cs, N)

    @jax.checkpoint
    def chunk_stats(x_c, dt_c, B_c, C_c):
        dA_c = dt_c * A[None, None, :]                  # (B,Cs,H), <= 0
        xdt_c = x_c * dt_c[..., None]                   # (B,Cs,H,P)
        # intra-chunk (quadratic within chunk)
        L = _segsum_decay(dA_c)                         # (B,H,Cs,Cs)
        CB = jnp.einsum("bin,bjn->bij", C_c, B_c)       # (B,Cs,Cs)
        y_intra = jnp.einsum("bij,bhij,bjhp->bihp", CB, L, xdt_c)
        # state contributed by this chunk (decay to chunk end)
        cum = jnp.cumsum(dA_c, axis=1)                  # (B,Cs,H)
        decay_end = jnp.exp(cum[:, -1:, :] - cum)       # (B,Cs,H)
        state = jnp.einsum("bjn,bjh,bjhp->bhpn", B_c, decay_end, xdt_c)
        # decay from chunk start to each position (for the carried-in state)
        decay_in = jnp.exp(cum)                         # (B,Cs,H)
        chunk_decay = jnp.exp(cum[:, -1, :])            # (B,H)
        return y_intra, state, decay_in, chunk_decay

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), xh.dtype)

    def step(h, inp):
        x_c, dt_c, B_c, C_c = inp
        y_intra, state, decay_in, chunk_decay = chunk_stats(x_c, dt_c, B_c, C_c)
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", C_c, h.astype(y_intra.dtype),
                             decay_in)
        h_next = chunk_decay[:, :, None, None] * h + state.astype(h.dtype)
        return h_next, (y_intra + y_inter)

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y, h_final


def init_ssm_cache(cfg, batch: int, dtype):
    W = cfg.ssm_conv_width - 1
    return {
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                       dtype),
        "conv": jnp.zeros((batch, W, cfg.d_inner), dtype),
        "conv_bc": jnp.zeros((batch, W, 2 * cfg.ssm_state), dtype),
    }


def apply_ssm(p, x, cfg, *, mode: str = "train", cache=None):
    """Mamba-2 block. x: (B,S,D) (S=1 for decode). Returns (y, new_cache)."""
    B, S, D = x.shape
    din, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim

    z, x_in, bc_in, dt_raw = _project_in(p, x)

    def conv_stream(stream, cache_key, w, b):
        """Depthwise causal conv on one aligned stream; returns (y, state)."""
        if mode == "decode":
            window = jnp.concatenate([cache[cache_key], stream], axis=1)
            y = (jnp.einsum("bwc,wc->bc", window, w) + b[None, :])[:, None]
            return y, window[:, 1:]
        conv_in = stream
        if cache is not None:  # continue from conv tail
            conv_in = jnp.concatenate([cache[cache_key], stream],
                                      axis=1)[:, -(S + cfg.ssm_conv_width - 1):]
        y = _causal_conv(conv_in, w, b)[:, -S:]
        C = stream.shape[-1]
        state = jnp.concatenate(
            [jnp.zeros((B, max(cfg.ssm_conv_width - 1 - S, 0), C), x.dtype),
             conv_in[:, -(cfg.ssm_conv_width - 1):]], axis=1)
        return y, state

    if mode == "decode":
        assert cache is not None and S == 1
    x_c, conv_state = conv_stream(x_in, "conv", p["conv_w"], p["conv_b"])
    bc_c, conv_bc_state = conv_stream(bc_in, "conv_bc", p["conv_w_bc"],
                                      p["conv_b_bc"])
    x_c = jax.nn.silu(x_c)
    bc_c = jax.nn.silu(bc_c)

    xs = x_c.reshape(B, S, H, P)
    Bm = bc_c[..., :N]
    Cm = bc_c[..., N:]
    # head-parallel SSD (Mamba TP): every SSD tensor below is independent per
    # head, so sharding heads over "model" divides the chunk stacks, decay
    # matrices and y buffers by the model-axis size. B/C (ngroups=1) are
    # shared across heads and stay replicated.
    xs = shard(xs, ("pod", "data"), None, "model", None)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                     + p["dt_bias"][None, None, :])             # (B,S,H)
    A = -jnp.exp(p["A_log"])                                          # (H,)

    new_cache = cache
    if mode == "decode":
        h_prev = cache["h"]
        dA = jnp.exp(dt[:, 0] * A[None, :])                           # (B,H) f32
        upd = jnp.einsum("bn,bh,bhp->bhpn", Bm[:, 0], dt[:, 0],
                         xs[:, 0])                                    # (B,H,P,N)
        # keep the recurrent state in its cache dtype (scan carry typing)
        h = (dA[:, :, None, None] * h_prev + upd).astype(h_prev.dtype)
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], h)[:, None]          # (B,1,H,P)
        y = y.astype(x.dtype)
        new_cache = {"h": h, "conv": conv_state.astype(cache["conv"].dtype),
                     "conv_bc": conv_bc_state.astype(cache["conv_bc"].dtype)}
    else:
        h0 = cache["h"] if cache is not None else None
        y, h_final = ssd_scan(xs, dt.astype(xs.dtype), A.astype(xs.dtype),
                              Bm, Cm, cfg.ssm_chunk, h0=h0)
        if mode == "prefill":
            new_cache = {"h": h_final, "conv": conv_state,
                         "conv_bc": conv_bc_state}

    y = y + p["ssm_D"].astype(y.dtype)[None, None, :, None] * xs
    y = shard(y, ("pod", "data"), None, "model", None)
    y = y.reshape(B, S, din) * jax.nn.silu(z)
    y = shard(y, ("pod", "data"), None, "model")
    y = apply_norm(p["gate_norm"], y, "rmsnorm", cfg.norm_eps)
    y = y @ p["out_proj"]
    return shard_residual(y), new_cache
