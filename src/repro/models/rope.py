"""Rotary position embeddings: standard RoPE and Qwen2-VL style M-RoPE.

M-RoPE (multimodal RoPE, arXiv:2409.12191) splits the head_dim/2 frequency
bands into (temporal, height, width) sections; each section rotates by the
corresponding component of a 3-vector position id. Text tokens carry
(t, t, t) so M-RoPE degenerates to RoPE on text.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def _angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions: (..., S) -> angles (..., S, head_dim//2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # explicit rank lift: (..., S, 1) * (1, ..., 1, half) — rank promotion is
    # an error under test
    return positions[..., None].astype(jnp.float32) \
        * freqs.reshape((1,) * positions.ndim + (half,))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                mrope_sections: Optional[Tuple[int, int, int]] = None) -> jnp.ndarray:
    """Build rotation angles (B, S, head_dim//2).

    positions: (B, S) for RoPE, (3, B, S) for M-RoPE.
    """
    if mrope_sections is None:
        return _angles(positions, head_dim, theta)
    assert positions.ndim == 3 and positions.shape[0] == 3, "M-RoPE needs (3,B,S) ids"
    ang = _angles(positions, head_dim, theta)  # (3, B, S, half)
    half = ang.shape[-1]
    sections = jnp.asarray(mrope_sections)
    # frequency band b belongs to section: first section whose cumsum exceeds b
    band_section = jnp.searchsorted(jnp.cumsum(sections), jnp.arange(half),
                                    side="right")                    # (half,)
    onehot = (band_section[None, :] == jnp.arange(3)[:, None])       # (3, half)
    return jnp.sum(ang * onehot[:, None, None, :], axis=0)           # (B, S, half)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """Rotate. x: (B, S, H, head_dim); angles: (B, S, head_dim//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
