"""The paper's own three task models (Appendix C), split exactly as in §5.

  * FEMNIST CNN  — client: Conv(32,3x3) + Conv(64,3x3) + MaxPool + Flatten
                   (cut activation d = 12·12·64 = 9216, the paper's d);
                   server: Dense(128) + Dense(62).   client ≈ 1.6% of params.
  * SO Tag MLP   — client: one dense layer (bow 5000 -> 2000 = d);
                   server: one dense layer (2000 -> 1000 tags, multi-label).
  * SO NWP LSTM  — client: Embedding(vocab, 96) + LSTM + Dense (d = 96);
                   server: Dense(96 -> vocab).

Each model follows the same split API as TransformerLM (params =
{"client", "server"}; ``loss(params, batch, quantize=...)`` applies the
grouped PQ + gradient-corrected VJP at the cut), so ``make_train_step``
drives the paper models and the billion-parameter archs identically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressors import (CutCompressor, CutState, PQCompressor,
                                    compress_downlink,
                                    compress_downlink_keyed,
                                    compress_with_correction_carry)
from repro.core.correction import quantize_with_correction_stats
from repro.core.quantizer import PQConfig
from repro.models.layers import row

Params = Dict[str, Any]


def _maybe_quantize(x, pq: Optional[PQConfig], lam, quantize: bool,
                    client_batch: int = 0, lam_override=None,
                    downlink: Optional[CutCompressor] = None, *,
                    key: Optional[jax.Array] = None,
                    cut_state: Optional[CutState] = None):
    """Apply the cut-layer codecs per client: the leading dim is split into
    cohorts of ``client_batch`` examples, each clustered with its own
    codebooks (vmap). client_batch=0 treats the whole batch as a single
    client. ``downlink`` (a `CutCompressor`) squeezes the server→client
    gradient cotangent inside the VJP; None/"none" leaves the backward
    pass bitwise-untouched.

    ``cut_state`` (a `CutState`, leaves with a leading client axis under
    per-client splitting) switches the uplink to the state-carrying hook:
    codebook warm-start + optional error feedback, with the updated state
    returned under ``stats["cut_state"]``. ``key`` is a per-step PRNG key:
    the downlink codec then uses stochastic rounding (scalarq). Both
    default to ``None`` — the historical, bitwise-unchanged path."""
    if lam_override is not None:
        lam = lam_override
    has_dl = quantize and downlink is not None and downlink.name != "none"
    if not quantize or (pq is None and not has_dl):
        return x, {}
    per_client = bool(client_batch and x.shape[0] % client_batch == 0
                      and x.shape[0] > client_batch)
    stats = {}
    zt = x
    if pq is not None:
        if cut_state is not None:
            comp = PQCompressor(pq)
            if per_client:
                xs = x.reshape(x.shape[0] // client_batch, client_batch,
                               *x.shape[1:])
                # full-tensor EF memory follows the per-client split (and is
                # flattened back below, so callers see the input layout)
                if cut_state.ef_memory is not None and \
                        cut_state.ef_memory.shape == x.shape:
                    cut_state = cut_state._replace(
                        ef_memory=cut_state.ef_memory.reshape(xs.shape))
                zt, dist, new_state = jax.vmap(
                    lambda zi, si: compress_with_correction_carry(
                        zi, lam, si, comp))(xs, cut_state)
                zt, dist = zt.reshape(x.shape), jnp.mean(dist)
                if new_state.ef_memory is not None:
                    new_state = new_state._replace(
                        ef_memory=new_state.ef_memory.reshape(x.shape))
            else:
                zt, dist, new_state = compress_with_correction_carry(
                    x, lam, cut_state, comp)
            stats["cut_state"] = new_state
        elif per_client:
            xs = x.reshape(x.shape[0] // client_batch, client_batch,
                           *x.shape[1:])
            zt, dist = jax.vmap(
                lambda zi: quantize_with_correction_stats(zi, lam, pq))(xs)
            zt, dist = zt.reshape(x.shape), jnp.mean(dist)
        else:
            zt, dist = quantize_with_correction_stats(x, lam, pq)
        n = x.size // x.shape[-1]
        stats.update({
            "pq_distortion": dist,
            "pq_compression_ratio": float(
                pq.compression_ratio(int(n), x.shape[-1])),
        })
    if has_dl:
        if per_client:
            zs = zt.reshape(zt.shape[0] // client_batch, client_batch,
                            *zt.shape[1:])
            if key is None:
                zs = jax.vmap(
                    lambda zi: compress_downlink(zi, downlink))(zs)
            else:
                dkeys = jax.random.split(key, zs.shape[0])
                zs = jax.vmap(
                    lambda zi, ki: compress_downlink_keyed(
                        zi, ki, downlink))(zs, dkeys)
            zt = zs.reshape(zt.shape)
        elif key is None:
            zt = compress_downlink(zt, downlink)
        else:
            zt = compress_downlink_keyed(zt, key, downlink)
    return zt, stats


# ---------------------------------------------------------------------------
# FEMNIST CNN
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FemnistCNN:
    """28x28x1 -> 62 classes; cut after flatten (d = 9216)."""
    num_classes: int = 62
    pq: Optional[PQConfig] = None
    lam: float = 0.0
    dropout: float = 0.0
    client_batch: int = 0   # examples per client for per-client PQ codebooks
    downlink_compressor: Optional[CutCompressor] = None

    cut_dim: int = 9216  # 12*12*64

    def init(self, key) -> Params:
        k1, k2, k3, k4 = jax.random.split(key, 4)
        he = lambda k, shp, fan: jax.random.normal(k, shp) * jnp.sqrt(2.0 / fan)
        return {
            "client": {
                "conv1_w": he(k1, (3, 3, 1, 32), 9), "conv1_b": jnp.zeros(32),
                "conv2_w": he(k2, (3, 3, 32, 64), 9 * 32), "conv2_b": jnp.zeros(64),
            },
            "server": {
                "dense1_w": he(k3, (9216, 128), 9216), "dense1_b": jnp.zeros(128),
                "dense2_w": he(k4, (128, self.num_classes), 128),
                "dense2_b": jnp.zeros(self.num_classes),
            },
        }

    def client_forward(self, cp: Params, batch) -> jax.Array:
        x = batch["image"]  # (B, 28, 28, 1)
        x = jax.lax.conv_general_dilated(
            x, cp["conv1_w"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + row(cp["conv1_b"], 4)
        x = jax.nn.relu(x)
        x = jax.lax.conv_general_dilated(
            x, cp["conv2_w"], (1, 1), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + row(cp["conv2_b"], 4)
        x = jax.nn.relu(x)
        x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1),
                                  (1, 2, 2, 1), "VALID")
        return x.reshape(x.shape[0], -1)  # (B, 9216)

    def server_logits(self, sp: Params, acts) -> jax.Array:
        h = jax.nn.relu(acts @ sp["dense1_w"] + row(sp["dense1_b"], 2))
        return h @ sp["dense2_w"] + row(sp["dense2_b"], 2)

    def loss(self, params: Params, batch, *, quantize: bool = True,
             lam_override=None, key=None, cut_state=None):
        acts = self.client_forward(params["client"], batch)
        acts, stats = _maybe_quantize(acts, self.pq, self.lam, quantize,
                                       self.client_batch, lam_override,
                                       self.downlink_compressor,
                                       key=key, cut_state=cut_state)
        logits = self.server_logits(params["server"], acts)
        labels = batch["label"]
        ce = -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(labels.shape[0]),
                                                  labels])
        return ce, dict(stats, ce=ce)

    def accuracy(self, params: Params, batch) -> jax.Array:
        acts = self.client_forward(params["client"], batch)
        logits = self.server_logits(params["server"], acts)
        return jnp.mean(jnp.argmax(logits, -1) == batch["label"])


# ---------------------------------------------------------------------------
# SO Tag MLP (multi-label)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SOTagMLP:
    bow_dim: int = 5000
    cut_dim: int = 2000
    num_tags: int = 1000
    pq: Optional[PQConfig] = None
    lam: float = 0.0
    client_batch: int = 0
    downlink_compressor: Optional[CutCompressor] = None

    def init(self, key) -> Params:
        k1, k2 = jax.random.split(key)
        glorot = lambda k, i, o: jax.random.normal(k, (i, o)) * jnp.sqrt(1.0 / i)
        return {
            "client": {"dense1_w": glorot(k1, self.bow_dim, self.cut_dim),
                       "dense1_b": jnp.zeros(self.cut_dim)},
            "server": {"dense2_w": glorot(k2, self.cut_dim, self.num_tags),
                       "dense2_b": jnp.zeros(self.num_tags)},
        }

    def client_forward(self, cp, batch):
        return jax.nn.relu(batch["bow"] @ cp["dense1_w"] + row(cp["dense1_b"], 2))

    def server_logits(self, sp, acts):
        return acts @ sp["dense2_w"] + row(sp["dense2_b"], acts.ndim)

    def loss(self, params, batch, *, quantize: bool = True,
             lam_override=None, key=None, cut_state=None):
        acts = self.client_forward(params["client"], batch)
        acts, stats = _maybe_quantize(acts, self.pq, self.lam, quantize,
                                       self.client_batch, lam_override,
                                       self.downlink_compressor,
                                       key=key, cut_state=cut_state)
        logits = self.server_logits(params["server"], acts)
        y = batch["tags"].astype(jnp.float32)  # (B, num_tags) multi-hot
        bce = jnp.mean(jnp.maximum(logits, 0) - logits * y +
                       jnp.log1p(jnp.exp(-jnp.abs(logits))))
        return bce, dict(stats, bce=bce)

    def recall_at_5(self, params, batch):
        acts = self.client_forward(params["client"], batch)
        logits = self.server_logits(params["server"], acts)
        _, top5 = jax.lax.top_k(logits, 5)
        hits = jnp.take_along_axis(batch["tags"], top5, axis=-1).sum(-1)
        denom = jnp.minimum(batch["tags"].sum(-1), 5)
        return jnp.mean(hits / jnp.maximum(denom, 1))


# ---------------------------------------------------------------------------
# SO NWP LSTM
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SONwpLSTM:
    vocab: int = 10_000
    embed_dim: int = 96
    hidden: int = 670
    cut_dim: int = 96
    pq: Optional[PQConfig] = None
    lam: float = 0.0
    client_batch: int = 0
    downlink_compressor: Optional[CutCompressor] = None

    def init(self, key) -> Params:
        ks = jax.random.split(key, 5)
        g = lambda k, i, o: jax.random.normal(k, (i, o)) * jnp.sqrt(1.0 / i)
        return {
            "client": {
                "emb_w": jax.random.normal(ks[0], (self.vocab, self.embed_dim)) * 0.02,
                "lstm_wx": g(ks[1], self.embed_dim, 4 * self.hidden),
                "lstm_wh": g(ks[2], self.hidden, 4 * self.hidden),
                "lstm_b": jnp.zeros(4 * self.hidden),
                "dense1_w": g(ks[3], self.hidden, self.cut_dim),
                "dense1_b": jnp.zeros(self.cut_dim),
            },
            "server": {"dense2_w": g(ks[4], self.cut_dim, self.vocab),
                       "dense2_b": jnp.zeros(self.vocab)},
        }

    def client_forward(self, cp, batch):
        toks = batch["tokens"]  # (B, S)
        x = cp["emb_w"][toks]   # (B, S, E)
        B, S, _ = x.shape
        Hn = self.hidden

        def step(carry, xt):
            h, c = carry
            z = xt @ cp["lstm_wx"] + h @ cp["lstm_wh"] + row(cp["lstm_b"], 2)
            i, f, g_, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g_)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), h

        (h, c), hs = jax.lax.scan(step, (jnp.zeros((B, Hn)), jnp.zeros((B, Hn))),
                                  jnp.swapaxes(x, 0, 1))
        hs = jnp.swapaxes(hs, 0, 1)  # (B, S, H)
        return hs @ cp["dense1_w"] + row(cp["dense1_b"], 3)  # (B, S, 96)

    def server_logits(self, sp, acts):
        return acts @ sp["dense2_w"] + row(sp["dense2_b"], acts.ndim)

    def loss(self, params, batch, *, quantize: bool = True,
             lam_override=None, key=None, cut_state=None):
        acts = self.client_forward(params["client"], batch)
        acts, stats = _maybe_quantize(acts, self.pq, self.lam, quantize,
                                       self.client_batch, lam_override,
                                       self.downlink_compressor,
                                       key=key, cut_state=cut_state)
        logits = self.server_logits(params["server"], acts)
        labels = batch["labels"]  # (B, S), -1 = ignore
        mask = labels >= 0
        safe = jnp.maximum(labels, 0)
        lp = jax.nn.log_softmax(logits)
        ce = -jnp.sum(jnp.take_along_axis(lp, safe[..., None], -1)[..., 0] * mask)
        ce = ce / jnp.maximum(mask.sum(), 1)
        return ce, dict(stats, ce=ce)

    def accuracy(self, params, batch):
        acts = self.client_forward(params["client"], batch)
        logits = self.server_logits(params["server"], acts)
        labels = batch["labels"]
        mask = labels >= 0
        ok = (jnp.argmax(logits, -1) == labels) * mask
        return ok.sum() / jnp.maximum(mask.sum(), 1)
