from repro.optim.optimizers import (
    Optimizer,
    adafactor,
    adagrad,
    adam,
    get_optimizer,
    momentum,
    sgd,
)
from repro.optim.schedules import constant, cosine_decay, warmup_cosine

__all__ = [
    "Optimizer", "sgd", "momentum", "adam", "adagrad", "adafactor",
    "get_optimizer", "constant", "cosine_decay", "warmup_cosine",
]
