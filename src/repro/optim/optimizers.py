"""Functional optimizers (optax-style, dependency-free).

The paper's three tasks use SGD (FEMNIST), Adam (SO NWP) and AdaGrad (SO
Tag) — all implemented here. Adafactor (factored second moments, no
momentum) is provided for the giant assigned archs (mixtral-8x22b,
llama4-maverick) whose Adam state would not fit 256×16 GB HBM.

``Optimizer.update`` returns *updates to add to params*; optimizer states
are plain pytrees mirroring params so the sharding rules shard them exactly
like the weights they belong to.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)
    name: str = "opt"


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        del params
        step = state["step"]
        upd = jax.tree.map(lambda g: (-sched(step) * g.astype(jnp.float32)
                                      ).astype(g.dtype), grads)
        return upd, {"step": step + 1}

    return Optimizer(init, update, "sgd")


def momentum(lr, beta: float = 0.9) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        del params
        step = state["step"]
        m = jax.tree.map(lambda mv, g: beta * mv + g.astype(jnp.float32),
                         state["m"], grads)
        upd = jax.tree.map(lambda mv, g: (-sched(step) * mv).astype(g.dtype),
                           m, grads)
        return upd, {"step": step + 1, "m": m}

    return Optimizer(init, update, "momentum")


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"step": jnp.zeros((), jnp.int32),
                "m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        m = jax.tree.map(lambda mv, g: b1 * mv + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) *
                         jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def u(mv, vv, g):
            mh, vh = mv / bc1, vv / bc2
            return (-sched(step - 1) * mh / (jnp.sqrt(vh) + eps)).astype(g.dtype)

        return jax.tree.map(u, m, v, grads), {"step": step, "m": m, "v": v}

    return Optimizer(init, update, "adam")


def adagrad(lr, eps: float = 1e-7) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"step": jnp.zeros((), jnp.int32),
                "acc": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params):
        del params
        step = state["step"]
        acc = jax.tree.map(lambda a, g: a + jnp.square(g.astype(jnp.float32)),
                           state["acc"], grads)
        upd = jax.tree.map(
            lambda a, g: (-sched(step) * g.astype(jnp.float32) /
                          (jnp.sqrt(a) + eps)).astype(g.dtype), acc, grads)
        return upd, {"step": step + 1, "acc": acc}

    return Optimizer(init, update, "adagrad")


def adafactor(lr, eps: float = 1e-30, clip_threshold: float = 1.0) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern, 2018), no momentum.

    For rank>=2 params the (fp32) second moment is stored as a row vector +
    column vector over the last two dims — O(n+m) instead of O(n·m) state,
    which is what lets the 400B-param archs train on a 256-chip pod.
    """
    sched = _as_schedule(lr)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def zs(p):
            if _factored(p):
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"full": jnp.zeros_like(p, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(zs, params, is_leaf=lambda x: not isinstance(x, dict))}

    def update(grads, state, params):
        del params
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) ** -0.8)

        def upd_one(g, v):
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if "full" in v:
                vn = beta * v["full"] + (1 - beta) * g2
                rms = jnp.sqrt(vn)
                new_v = {"full": vn}
            else:
                row = beta * v["row"] + (1 - beta) * g2.mean(axis=-1)
                col = beta * v["col"] + (1 - beta) * g2.mean(axis=-2)
                mean = row.mean(axis=-1, keepdims=True)[..., None]
                rms = jnp.sqrt(row[..., None] * col[..., None, :] /
                               jnp.maximum(mean, eps))
                new_v = {"row": row, "col": col}
            u = g32 / jnp.maximum(rms, eps)
            # update clipping (RMS(u) <= clip_threshold)
            urms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, urms / clip_threshold)
            return (-sched(step - 1) * u).astype(g.dtype), new_v

        flat_g, tdef = jax.tree.flatten(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd_one(g, v) for g, v in zip(flat_g, flat_v)]
        upd = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return upd, {"step": step, "v": new_v}

    return Optimizer(init, update, "adafactor")


def get_optimizer(name: str, lr, **kw) -> Optimizer:
    table = {"sgd": sgd, "momentum": momentum, "adam": adam,
             "adagrad": adagrad, "adafactor": adafactor}
    return table[name](lr, **kw)
