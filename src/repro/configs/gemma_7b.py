"""Gemma-7B [arXiv:2403.08295]: dense MHA (kv=16 = heads), head_dim=256,
GeGLU, RMSNorm, tied + sqrt(d)-scaled embeddings, 256k vocab — the LM head
alone is ~0.79B params, the paper's motivating 'classification layer
dominates client memory' regime."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma_7b", family="dense",
    num_layers=28, d_model=3072, vocab_size=256_000,
    num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24576, mlp_type="geglu",
    tie_embeddings=True, scale_embed=True,
    cut_periods=3, dtype="bfloat16", param_dtype="bfloat16", optimizer="adam",
    source="arXiv:2403.08295",
)

SMOKE_CONFIG = ArchConfig(
    name="gemma_7b_smoke", family="dense",
    num_layers=2, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, mlp_type="geglu",
    tie_embeddings=True, scale_embed=True,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2403.08295",
)
