"""Mixtral-8x22B [arXiv:2401.04088]: 56-layer MoE, 8 experts top-2 on every
layer, GQA kv=8, SWA (per assignment), SwiGLU, RMSNorm. ~141B params ->
Adafactor + bf16 so optimizer state fits the 256-chip pod."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral_8x22b", family="moe",
    num_layers=56, d_model=6144, vocab_size=32768,
    num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=16384, mlp_type="swiglu",
    num_experts=8, experts_per_token=2, moe_period=1, capacity_factor=1.25,
    rope_theta=1_000_000.0, sliding_window=4096,
    cut_periods=7, train_microbatches=2,
    dtype="bfloat16", param_dtype="bfloat16",
    optimizer="adafactor",
    source="arXiv:2401.04088",
)

SMOKE_CONFIG = ArchConfig(
    name="mixtral_8x22b_smoke", family="moe",
    num_layers=2, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, mlp_type="swiglu",
    num_experts=4, experts_per_token=2, moe_period=1, capacity_factor=1.25,
    sliding_window=64,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2401.04088",
)
