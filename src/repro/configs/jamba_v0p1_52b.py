"""Jamba-v0.1 52B [arXiv:2403.19887]: hybrid Mamba/attention 7:1 interleave
(one attention block per 8 layers), MoE (16 experts top-2) on every other
layer. SSM blocks implemented as Mamba-2/SSD (see DESIGN.md §5 —
paper-Jamba uses Mamba-1; SSD is our TPU-native equivalent)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba_v0p1_52b", family="hybrid",
    num_layers=32, d_model=4096, vocab_size=65536,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, mlp_type="swiglu",
    num_experts=16, experts_per_token=2, moe_period=2, moe_offset=1,
    capacity_factor=1.25,
    layer_pattern=("ssm", "ssm", "ssm", "ssm", "attn", "ssm", "ssm", "ssm"),
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=256,
    cut_periods=1,  # 8 of 32 layers on clients
    train_microbatches=8,   # grad accumulation: SSD + MoE activations are
                            # the largest in the fleet (see EXPERIMENTS §Perf)
    dtype="bfloat16", param_dtype="bfloat16", optimizer="adafactor",
    source="arXiv:2403.19887",
)

SMOKE_CONFIG = ArchConfig(
    name="jamba_v0p1_52b_smoke", family="hybrid",
    num_layers=4, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, mlp_type="swiglu",
    num_experts=4, experts_per_token=2, moe_period=2, moe_offset=1,
    layer_pattern=("ssm", "attn"),
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=32,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2403.19887",
)
