"""Qwen2-VL-2B [arXiv:2409.12191]: VLM decoder with M-RoPE (t/h/w frequency
sections of head_dim/2 = 64 -> (16, 24, 24)), GQA kv=2, tied embeddings.
Vision tower is a STUB per the assignment carve-out: batches carry
precomputed patch embeddings (dim 1280) which the in-model projector maps
to d_model."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2_vl_2b", family="vlm",
    num_layers=28, d_model=1536, vocab_size=151_936,
    num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, mlp_type="swiglu",
    rope_theta=1_000_000.0, mrope_sections=(16, 24, 24),
    vision_embed_dim=1280, vision_tokens_frac=0.25,
    tie_embeddings=True,
    cut_periods=4, dtype="bfloat16", param_dtype="bfloat16", optimizer="adam",
    source="arXiv:2409.12191",
)

SMOKE_CONFIG = ArchConfig(
    name="qwen2_vl_2b_smoke", family="vlm",
    num_layers=2, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, mlp_type="swiglu",
    mrope_sections=(8, 12, 12),
    vision_embed_dim=96, vision_tokens_frac=0.25,
    tie_embeddings=True,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2409.12191",
)
