"""Llama-4 Maverick 400B-A17B [hf:meta-llama/Llama-4 family]: 48 layers,
128-expert top-1 MoE interleaved with dense FFN every other layer,
GQA kv=8, 202k vocab. ~400B total / ~17B active params -> Adafactor + bf16
(Adam fp32 state would need >4.8 TB; see DESIGN.md §7)."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4_maverick_400b", family="moe",
    num_layers=48, d_model=5120, vocab_size=202_048,
    num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, mlp_type="swiglu",
    num_experts=128, experts_per_token=1, moe_period=2, moe_offset=1,
    layer_pattern=("attn", "attn"),   # period 2: dense FFN / MoE alternation
    capacity_factor=1.0,
    rope_theta=500_000.0,
    cut_periods=6, dtype="bfloat16", param_dtype="bfloat16",
    optimizer="adafactor",
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family card)",
)

SMOKE_CONFIG = ArchConfig(
    name="llama4_maverick_400b_smoke", family="moe",
    num_layers=2, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, mlp_type="swiglu",
    num_experts=4, experts_per_token=1, moe_period=2, moe_offset=1,
    layer_pattern=("attn", "attn"),
    cut_periods=0, vocab_pad_to=64, remat=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family card)",
)
