"""StarCoder2-3B [arXiv:2402.19173]: dense GQA decoder, 4k sliding window,
learned-free RoPE (theta ~1e5), GELU MLP with biases, LayerNorm."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2_3b", family="dense",
    num_layers=30, d_model=3072, vocab_size=49152,
    num_heads=24, num_kv_heads=2, head_dim=128,
    d_ff=12288, mlp_type="gelu", use_bias=True, norm_type="layernorm",
    rope_theta=999_999.0, sliding_window=4096,
    cut_periods=4, pq_backend="auto",  # fused Pallas PQ encode on TPU
    dtype="bfloat16", param_dtype="bfloat16", optimizer="adam",
    source="arXiv:2402.19173",
)

SMOKE_CONFIG = ArchConfig(
    name="starcoder2_3b_smoke", family="dense",
    num_layers=2, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, mlp_type="gelu", use_bias=True, norm_type="layernorm",
    rope_theta=999_999.0, sliding_window=64,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2402.19173",
)
