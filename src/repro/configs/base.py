"""Architecture + input-shape configuration schema and registry.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(the exact published shape) and ``SMOKE_CONFIG`` (a reduced variant of the
same family: <=2 periods of layers, d_model<=512, <=4 experts) used by the
CPU smoke tests. The full configs are exercised only through the dry-run
(ShapeDtypeStruct, no allocation).
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from typing import Optional, Tuple

import jax.numpy as jnp

# ----------------------------------------------------------------------------
# architecture config
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | ssm | moe | hybrid | vlm | audio
    num_layers: int
    d_model: int
    vocab_size: int
    # attention ----------------------------------------------------------
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None    # tokens; None = full attention
    mrope_sections: Optional[Tuple[int, int, int]] = None  # VLM M-RoPE (t,h,w)
    # mlp ------------------------------------------------------------------
    d_ff: int = 0
    mlp_type: str = "swiglu"          # swiglu | geglu | gelu
    use_bias: bool = False
    norm_type: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    # MoE --------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1               # layer i is MoE iff i % moe_period == moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    # SSM / hybrid -------------------------------------------------------
    layer_pattern: Tuple[str, ...] = ("attn",)   # repeated block pattern
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # modality frontends (stubs per the carve-out) -------------------------
    vision_embed_dim: int = 0         # >0: model has a vision projector input
    vision_tokens_frac: float = 0.25  # fraction of seq that is vision tokens
    num_codebooks: int = 1            # musicgen: 4 parallel EnCodec streams
    # embeddings -------------------------------------------------------------
    tie_embeddings: bool = False
    scale_embed: bool = False         # gemma-style sqrt(d) embedding scale
    vocab_pad_to: int = 256
    # FedLite split --------------------------------------------------------
    cut_periods: int = 1              # client keeps embed + this many periods
    pq_backend: str = "auto"          # quantizer backend: jnp | pallas | auto
    # per-direction cut-layer codecs (core/compressors.py spec strings):
    # uplink "pq" = the paper's grouped PQ (built by launch/specs.default_pq),
    # "none" = raw activations (SplitFed). Downlink compresses the
    # server->client gradient message, e.g. "chain:topk(k=0.1)+scalarq(bits=8)"
    uplink_compressor: str = "pq"
    downlink_compressor: str = "none"
    # cross-round PQ codebook reuse (core/quantizer.QuantizerState):
    # warm-started rounds run pq_warm_iters Lloyd iterations (None =
    # kmeans_iters // 2); pq_delta_bits > 0 ships codebooks as `pq-delta`
    # wire payloads (b-bit deltas vs the acked reference, federated/wire.py)
    pq_warm_iters: Optional[int] = None
    pq_delta_bits: int = 0            # 0 = fresh fp16 codebooks every round
    # numerics / memory -----------------------------------------------------
    dtype: str = "float32"            # activation/compute dtype
    param_dtype: str = "float32"
    remat: bool = True
    remat_policy: str = "full"        # "full" | "dots" (save matmul outputs)
    attn_q_chunk: int = 512           # row-block size for chunked attention
    train_microbatches: int = 1       # in-step gradient accumulation
    optimizer: str = "adam"           # default training optimizer
    source: str = ""                  # citation

    # ---- derived -----------------------------------------------------------
    def __post_init__(self):
        period = len(self.layer_pattern)
        if self.num_layers % period != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern period {period}")
        if self.moe_period and period % self.moe_period != 0 and self.num_experts:
            raise ValueError(f"{self.name}: pattern period must contain whole moe periods")
        if self.num_periods <= self.cut_periods:
            raise ValueError(f"{self.name}: cut_periods must leave server layers")

    @property
    def period(self) -> int:
        return len(self.layer_pattern)

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def padded_vocab(self) -> int:
        return int(math.ceil(self.vocab_size / self.vocab_pad_to) * self.vocab_pad_to)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def block_kind(self, pos: int) -> str:
        return self.layer_pattern[pos % self.period]

    def is_moe_layer(self, layer_idx: int) -> bool:
        return bool(self.num_experts) and (layer_idx % self.moe_period == self.moe_offset)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    # parameter count (for MODEL_FLOPS = 6·N·D roofline term) --------------
    def param_count(self, active_only: bool = False) -> int:
        """Approximate parameter count; active_only counts top-k experts only."""
        D, F, V = self.d_model, self.d_ff, self.padded_vocab
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += D * V * self.num_codebooks
        if self.vision_embed_dim:
            n += self.vision_embed_dim * D
        for i in range(self.num_layers):
            kind = self.block_kind(i)
            if kind == "attn":
                n += D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
            else:  # ssm
                din, N, H = self.d_inner, self.ssm_state, self.ssm_heads
                proj_out = 2 * din + 2 * N + H
                n += D * proj_out + din * D + self.ssm_conv_width * (din + 2 * N)
            if self.is_moe_layer(i):
                e = self.experts_per_token if active_only else self.num_experts
                n += e * (3 if self.mlp_type in ("swiglu", "geglu") else 2) * D * F
                n += D * self.num_experts  # router
            elif F:
                n += (3 if self.mlp_type in ("swiglu", "geglu") else 2) * D * F
            n += 2 * D  # norms
        return n


# ----------------------------------------------------------------------------
# input shapes (assigned)
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "starcoder2_3b",
    "mamba2_1p3b",
    "mixtral_8x22b",
    "jamba_v0p1_52b",
    "gemma_7b",
    "llama4_maverick_400b",
    "qwen2_vl_2b",
    "musicgen_large",
    "llama3_8b",
    "command_r_35b",
]

# archs whose long_500k decode is skipped (pure full attention; see DESIGN.md)
LONG_CONTEXT_CAPABLE = {
    "starcoder2_3b",      # native 4k sliding window
    "mamba2_1p3b",        # SSM state decode
    "mixtral_8x22b",      # sliding-window attention
    "jamba_v0p1_52b",     # hybrid: mamba state + few attn layers
}


def supports_shape(arch_name: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_name in LONG_CONTEXT_CAPABLE
    return True


def get_arch(arch_id: str, smoke: bool = False) -> ArchConfig:
    """Load a registered architecture config by id (also accepts '-' for '_')."""
    mod = importlib.import_module(f"repro.configs.{arch_id.replace('-', '_')}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def all_archs(smoke: bool = False):
    return {a: get_arch(a, smoke=smoke) for a in ARCH_IDS}
