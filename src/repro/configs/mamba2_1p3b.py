"""Mamba2-1.3B [arXiv:2405.21060]: attention-free SSD (state-space duality),
48 layers, d_state=128, expand=2, head_dim=64, tied embeddings."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2_1p3b", family="ssm",
    num_layers=48, d_model=2048, vocab_size=50280,
    d_ff=0, layer_pattern=("ssm",),
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=256, tie_embeddings=True,
    cut_periods=6, dtype="bfloat16", param_dtype="bfloat16", optimizer="adam",
    source="arXiv:2405.21060",
)

SMOKE_CONFIG = ArchConfig(
    name="mamba2_1p3b_smoke", family="ssm",
    num_layers=2, d_model=256, vocab_size=512,
    d_ff=0, layer_pattern=("ssm",),
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=32, tie_embeddings=True,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2405.21060",
)
