"""MusicGen-large [arXiv:2306.05284]: decoder-only LM over EnCodec tokens —
4 parallel codebooks (vocab 2048 each) with summed embeddings and one LM
head per codebook (the delay-pattern interleave reduces to parallel
per-step prediction at the backbone level). EnCodec itself is a STUB per
the assignment carve-out: batches carry (B, K=4, S) token grids."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen_large", family="audio",
    num_layers=48, d_model=2048, vocab_size=2048,
    num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, mlp_type="gelu", use_bias=True, norm_type="layernorm",
    num_codebooks=4,
    cut_periods=6, dtype="bfloat16", param_dtype="bfloat16", optimizer="adam",
    source="arXiv:2306.05284",
)

SMOKE_CONFIG = ArchConfig(
    name="musicgen_large_smoke", family="audio",
    num_layers=2, d_model=256, vocab_size=256,
    num_heads=4, num_kv_heads=4, head_dim=64,
    d_ff=512, mlp_type="gelu", use_bias=True, norm_type="layernorm",
    num_codebooks=4,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2306.05284",
)
