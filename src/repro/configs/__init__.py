from repro.configs.base import (
    ARCH_IDS,
    INPUT_SHAPES,
    LONG_CONTEXT_CAPABLE,
    ArchConfig,
    InputShape,
    all_archs,
    get_arch,
    supports_shape,
)

__all__ = [
    "ARCH_IDS", "INPUT_SHAPES", "LONG_CONTEXT_CAPABLE", "ArchConfig",
    "InputShape", "all_archs", "get_arch", "supports_shape",
]
