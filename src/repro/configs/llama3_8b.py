"""Llama-3 8B [arXiv:2407.21783]: dense GQA kv=8, SwiGLU, RMSNorm,
128k vocab, rope theta 500k."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3_8b", family="dense",
    num_layers=32, d_model=4096, vocab_size=128_256,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, mlp_type="swiglu",
    rope_theta=500_000.0,
    cut_periods=4, pq_backend="auto",  # fused Pallas PQ encode on TPU
    dtype="bfloat16", param_dtype="bfloat16", optimizer="adam",
    source="arXiv:2407.21783",
)

SMOKE_CONFIG = ArchConfig(
    name="llama3_8b_smoke", family="dense",
    num_layers=2, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, mlp_type="swiglu",
    rope_theta=500_000.0,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="arXiv:2407.21783",
)
