"""Command-R 35B [hf:CohereForAI/c4ai-command-r-v01]: dense GQA kv=8,
no biases, LayerNorm, tied embeddings, 256k vocab."""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="command_r_35b", family="dense",
    num_layers=40, d_model=8192, vocab_size=256_000,
    num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22528, mlp_type="swiglu", norm_type="layernorm", use_bias=False,
    rope_theta=8_000_000.0, tie_embeddings=True,
    cut_periods=5, dtype="bfloat16", param_dtype="bfloat16", optimizer="adam",
    source="hf:CohereForAI/c4ai-command-r-v01",
)

SMOKE_CONFIG = ArchConfig(
    name="command_r_35b_smoke", family="dense",
    num_layers=2, d_model=256, vocab_size=512,
    num_heads=4, num_kv_heads=2, head_dim=64,
    d_ff=512, mlp_type="swiglu", norm_type="layernorm", use_bias=False,
    rope_theta=8_000_000.0, tie_embeddings=True,
    cut_periods=1, vocab_pad_to=64, remat=False,
    source="hf:CohereForAI/c4ai-command-r-v01",
)
