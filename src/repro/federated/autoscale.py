"""Trace-driven autoscaler: turn observed round traces into (cohort,
policy, compressor) moves.

The scheduler measures, the executor scales compute — this module closes
the control loop over the remaining knobs. A `TraceAutoscaler` watches the
windowed observations a `Trace` exposes (``tail_ratio`` — the p95/p50
straggler tail of round durations, ``drop_rate``, ``bytes_per_round``,
``loss_slope``) and recommends the next `AutoscalePlan`:

  * straggler-dominated rounds (heavy duration tail under a waiting
    policy) → stop waiting: move FullSync to a Deadline at a p50-derived
    budget (the Caldas-style bounded round);
  * an over-aggressive policy (drop rate past ``drop_hi``) → back off —
    loosen the deadline / shed a drop slot — before shrinking the cohort,
    so participation is sacrificed last;
  * a wire-bytes budget breach → first strengthen the downlink codec along
    ``DOWNLINK_LADDER`` (compression is cheaper than participation), then
    halve the cohort;
  * a healthy, still-improving run → grow the cohort toward ``max_cohort``
    (more parallel clients per round, which the mesh executor turns into
    wall-clock);
  * a plateaued run → halve the cohort: the marginal clients are buying
    no loss and their bytes are pure cost.

Rules are ordered, pure and deterministic: the same trace and current plan
always produce the same recommendation (asserted in
tests/test_executor.py), so autoscaled benchmark cells are reproducible.
``autoscale_run`` drives a full training run in plan-sized segments —
consult, rebuild the trainer, continue from the same `TrainState` — and is
what ``benchmarks/bench_network.py --autoscale`` and the femnist example's
``--autoscale`` flag execute end-to-end.

The plan's policy is a spec string (``"full_sync"``, ``"drop_slowest:k"``,
``"deadline:seconds"``, ``"async:buffer"``) so plans are hashable,
loggable rows; ``make_policy`` materializes the scheduler object.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro import obs
from repro.federated.scheduler import (AsyncBuffer, Deadline, DropSlowestK,
                                       FullSync)
from repro.federated.trace import Trace
from repro.obs import slo

# the codec escalation ladder for bytes-budget breaches: each entry is a
# `core/compressors.py` spec for the downlink gradient message (None =
# dense). Measured reductions: ~4x for scalarq(8), ~12x for the chain.
DOWNLINK_LADDER: Tuple[Optional[str], ...] = (
    None, "scalarq(bits=8)", "chain:topk(k=0.1)+scalarq(bits=8)")


@dataclasses.dataclass(frozen=True)
class AutoscalePlan:
    """One point in the (cohort, policy, downlink codec) control space."""
    cohort: int
    policy: str = "full_sync"            # policy spec (see make_policy)
    downlink: Optional[str] = None       # downlink compressor spec
    reason: str = "initial"              # which rule produced this plan

    def moved_from(self, other: "AutoscalePlan") -> bool:
        """True when this plan changes any knob vs ``other``."""
        return (self.cohort, self.policy, self.downlink) != \
            (other.cohort, other.policy, other.downlink)


def make_policy(spec: str):
    """Materialize a policy spec string into a scheduler policy object."""
    name, _, arg = spec.partition(":")
    if name == "full_sync":
        return FullSync()
    if name == "drop_slowest":
        return DropSlowestK(int(arg or 1))
    if name == "deadline":
        return Deadline(float(arg))
    if name == "async":
        return AsyncBuffer(int(arg or 4))
    raise ValueError(f"unknown policy spec {spec!r}")


@dataclasses.dataclass
class TraceAutoscaler:
    """Deterministic rule-based controller over `Trace` windows.

    Thresholds are explicit fields so benchmark rows can record the exact
    controller that produced a run. ``window`` rounds of observation feed
    every rule; rules are evaluated in the order documented in the module
    docstring, first hit wins, no hit returns the current plan unchanged
    (``reason="steady"``).
    """
    window: int = 8
    tail_hi: float = 1.8            # p95/p50 duration ratio: straggler tail
    drop_hi: float = 0.3            # lost fraction: policy too aggressive
    deadline_slack: float = 1.5     # deadline = slack * p50 duration
    bytes_budget_per_round: Optional[float] = None   # total bytes, both dirs
    plateau_slope: float = -1e-3    # loss slope above this = plateaued
    min_cohort: int = 2
    max_cohort: int = 64

    def observe(self, trace: Trace) -> Dict[str, float]:
        """The windowed signals every rule reads (also a benchmark row).

        The two tier signals are observational (0.0 on flat-star runs):
        under a two-tier topology ``bytes_per_round`` — which rule 3
        budgets against — already includes both tiers via
        ``RoundRecord.uplink_bytes``, and the split shows WHERE the bytes
        flow: a congested parameter server shows up as
        ``server_uplink_per_round`` growth, which more edges would
        dilute, while ``edge_uplink_per_round`` only responds to cohort
        size and codec moves.
        """
        w = self.window
        sig = {
            "rounds": float(len(trace)),
            "tail_ratio": trace.tail_ratio(w),
            "drop_rate": trace.drop_rate(w),
            "bytes_per_round": trace.bytes_per_round(w),
            "p50_duration": trace.duration_percentile(50.0, w),
            "p99_duration": trace.duration_percentile(99.0, w),
            "loss_slope": trace.loss_slope(w),
            "edge_uplink_per_round": trace.tier_bytes_per_round(
                "edge_uplink", w),
            "server_uplink_per_round": trace.tier_bytes_per_round(
                "server_uplink", w),
        }
        # chaos-health signals, shared with the SLO monitors
        # (repro.obs.slo): observational here — rules key off tail/drop —
        # but recorded so autoscale benchmark rows grade run health too
        slo_sig = slo.trace_signals(trace, w)
        sig["quarantine_rate"] = slo_sig["quarantine_rate"]
        sig["retry_byte_overhead"] = slo_sig["retry_byte_overhead"]
        return sig

    def recommend(self, trace: Trace,
                  current: AutoscalePlan) -> AutoscalePlan:
        """The next plan given the observed window (pure, deterministic)."""
        if not len(trace):
            return current
        obs = self.observe(trace)

        # 1. straggler tail under a waiting policy: bound the round instead
        if obs["tail_ratio"] > self.tail_hi \
                and current.policy.startswith("full_sync"):
            budget = self.deadline_slack * obs["p50_duration"]
            return dataclasses.replace(
                current, policy=f"deadline:{budget:g}",
                reason=f"straggler tail {obs['tail_ratio']:.2f} > "
                       f"{self.tail_hi:g}: bound rounds at {budget:g}s")

        # 2. policy too aggressive: back off before shrinking the cohort
        if obs["drop_rate"] > self.drop_hi:
            name, _, arg = current.policy.partition(":")
            if name == "deadline":
                return dataclasses.replace(
                    current, policy=f"deadline:{2 * float(arg):g}",
                    reason=f"drop rate {obs['drop_rate']:.2f} > "
                           f"{self.drop_hi:g}: loosen deadline")
            if name == "drop_slowest" and int(arg or 1) > 1:
                return dataclasses.replace(
                    current, policy=f"drop_slowest:{int(arg) - 1}",
                    reason=f"drop rate {obs['drop_rate']:.2f} > "
                           f"{self.drop_hi:g}: shed a drop slot")
            if current.cohort > self.min_cohort:
                return dataclasses.replace(
                    current, cohort=max(current.cohort // 2, self.min_cohort),
                    reason=f"drop rate {obs['drop_rate']:.2f} > "
                           f"{self.drop_hi:g}: shrink cohort")

        # 3. bytes budget: strengthen the codec first, then shed clients
        if self.bytes_budget_per_round is not None \
                and obs["bytes_per_round"] > self.bytes_budget_per_round:
            ladder = list(DOWNLINK_LADDER)
            if current.downlink in ladder \
                    and ladder.index(current.downlink) < len(ladder) - 1:
                nxt = ladder[ladder.index(current.downlink) + 1]
                return dataclasses.replace(
                    current, downlink=nxt,
                    reason=f"bytes/round {obs['bytes_per_round']:.3g} over "
                           f"budget: downlink -> {nxt}")
            if current.cohort > self.min_cohort:
                return dataclasses.replace(
                    current, cohort=max(current.cohort // 2, self.min_cohort),
                    reason=f"bytes/round {obs['bytes_per_round']:.3g} over "
                           f"budget: shrink cohort")

        # 4. healthy and improving: scale the cohort out
        if obs["loss_slope"] < self.plateau_slope \
                and obs["tail_ratio"] <= self.tail_hi \
                and obs["drop_rate"] <= self.drop_hi \
                and current.cohort < self.max_cohort:
            return dataclasses.replace(
                current, cohort=min(current.cohort * 2, self.max_cohort),
                reason=f"healthy (slope {obs['loss_slope']:.2g}): "
                       "grow cohort")

        # 5. plateaued: the marginal clients buy nothing
        if obs["loss_slope"] >= self.plateau_slope \
                and len(trace) >= self.window \
                and current.cohort > self.min_cohort:
            return dataclasses.replace(
                current, cohort=max(current.cohort // 2, self.min_cohort),
                reason=f"plateau (slope {obs['loss_slope']:.2g}): "
                       "shrink cohort")

        return dataclasses.replace(current, reason="steady")


def autoscale_run(make_trainer: Callable[[AutoscalePlan, int], Any],
                  plan: AutoscalePlan, rounds: int, key, *,
                  controller: Optional[TraceAutoscaler] = None,
                  interval: int = 8) -> Dict[str, Any]:
    """Drive one training run in autoscaled segments.

    ``make_trainer(plan, segment_index)`` builds a `FederatedTrainer` for
    the plan (cohort/policy/downlink applied); every ``interval`` rounds
    the controller reads the segment's trace and recommends the next plan.
    The `TrainState` carries across segments (``FederatedTrainer.run``'s
    ``state=``), and so do the trainer's cross-round cut-layer caches
    (per-client warm-start codebooks / EF memories / the cohort-global
    slot) — they are keyed by client id, so a plan move must not reset a
    client's lineage any more than a cohort reshuffle does. This is ONE
    training run under a moving configuration.

    Returns a dict with the final ``state``, the stitched per-round
    ``history`` (each entry additionally carrying its segment's plan
    index), the per-segment ``plans``/``traces``, and byte totals the
    benchmark compares against static cells.
    """
    import jax

    controller = controller or TraceAutoscaler(window=interval)
    state = None
    prev_trainer = None
    history: List[Dict] = []
    plans: List[AutoscalePlan] = [plan]
    traces: List[Trace] = []
    done = 0
    seg = 0
    while done < rounds:
        seg_rounds = min(interval, rounds - done)
        trainer = make_trainer(plan, seg)
        if prev_trainer is not None:
            # transplant the client-keyed cut-layer caches into the new
            # trainer (same model family across plans; cohort size and
            # policy do not change the per-client state layout)
            for attr in ("_client_q", "_seed_q", "_ef_memory",
                         "_global_q", "_global_q_nparts"):
                setattr(trainer, attr, getattr(prev_trainer, attr))
        state, hist = trainer.run(seg_rounds, jax.random.fold_in(key, seg),
                                  state=state)
        prev_trainer = trainer
        for h in hist:
            history.append(dict(h, plan=len(plans) - 1))
        traces.append(trainer.last_trace)
        done += seg_rounds
        seg += 1
        if done < rounds:
            nxt = controller.recommend(trainer.last_trace, plan)
            if nxt.moved_from(plan):
                plans.append(nxt)
                # plan moves are first-class events in the run's event log
                obs.event("autoscale.plan", cat="autoscale", segment=seg,
                          rounds_done=done, cohort=nxt.cohort,
                          policy=nxt.policy,
                          downlink=nxt.downlink or "model-default",
                          reason=nxt.reason)
            plan = nxt
    return {
        "state": state,
        "history": history,
        "plans": plans,
        "traces": traces,
        "uplink_bytes": sum(t.total_uplink_bytes for t in traces),
        "downlink_bytes": sum(t.total_downlink_bytes for t in traces),
        "simulated_seconds": sum(t.simulated_seconds for t in traces),
    }
