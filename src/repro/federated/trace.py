"""Per-round simulation traces: what the virtual clock and the wire saw.

A `Trace` is the measurement product of a scheduler run — one
``RoundRecord`` per *server update* (synchronous round or async buffer
flush) carrying simulated wall-clock, measured uplink/downlink bytes,
which clients participated, which were dropped (dropout or straggler
policy), and the staleness of each contribution. Benchmarks reduce a
trace to the paper-§5 trade-off curves: time-to-target-loss and
bytes-per-round under heterogeneous fleets.

Everything here is plain Python/numpy — records are host-side bookkeeping
written by the scheduler's event loop, never traced by jit.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class RoundRecord:
    """One server update as observed by the virtual clock."""
    round: int                       # server update index
    t_start: float                   # sim seconds when the round was dispatched
    t_end: float                     # sim seconds when the server updated
    participants: Tuple[int, ...]    # client ids whose uploads were aggregated
    dropped: Tuple[int, ...]         # sampled but lost: dropout or straggler cut
    uplink_bytes: int                # measured bytes that crossed client->server
    downlink_bytes: int              # server->client bytes (broadcast + cut grads)
    staleness: Tuple[int, ...] = ()  # per-participant model-version lag (async)
    shards: Tuple[int, ...] = ()     # per-participant executor shard placement
    metrics: Dict[str, float] = dataclasses.field(default_factory=dict)
    # the byte ledger: "<direction>/<wire-kind>" -> bytes this round, e.g.
    # {"uplink/pq": 81920, "downlink/dense": 262144}; empty when the caller
    # did not tell the scheduler which wire kinds crossed (legacy callers)
    ledger: Dict[str, int] = dataclasses.field(default_factory=dict)
    # fault/recovery counters for this round: "<event>" -> count, e.g.
    # {"crashes": 3, "crash_dropped": 1, "retries": 2, "quarantined": 1,
    #  "rehomed": 4, "edges_down": 1, "jittered": 2, "round_voided": 1};
    # empty when no fault injection was active (the common case)
    faults: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def duration(self) -> float:
        return self.t_end - self.t_start


@dataclasses.dataclass
class Trace:
    """Ordered round records plus whole-run reductions.

    ``meta`` carries run-level context the records do not repeat per row:
    which codec each direction ran (`core/compressors.py` spec names), the
    measured per-client payload bytes behind the per-round totals, the
    cross-round state flags (``warm_start`` / ``error_feedback`` /
    ``stochastic_downlink``), and — when ``pq-delta`` codebook encoding is
    on — the measured codebook-bytes breakdown
    (``codebook_bytes_full`` / ``codebook_bytes_delta`` / ``_reduction``).
    """
    records: List[RoundRecord] = dataclasses.field(default_factory=list)
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)
    # the scheduler's resume point after the last completed round — set by
    # synchronous runners ({"round", "t", "rng"}); what checkpointing saves
    # so a restored run continues the identical virtual clock + RNG stream
    cursor: Optional[Dict[str, object]] = None
    # one `repro.obs.flight.FlightFrame` per server update (column arrays,
    # O(cohort) each) — the per-contribution causal lifecycle behind the
    # aggregate counters above; appended by the scheduler when flight
    # recording is on, patched with screening verdicts by the runtime,
    # snapshotted/restored by federated/recovery.py
    flights: List[object] = dataclasses.field(default_factory=list)

    def append(self, rec: RoundRecord) -> None:
        self.records.append(rec)

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self):
        return iter(self.records)

    # ---- reductions --------------------------------------------------------
    @property
    def simulated_seconds(self) -> float:
        return self.records[-1].t_end if self.records else 0.0

    @property
    def total_uplink_bytes(self) -> int:
        return sum(r.uplink_bytes for r in self.records)

    @property
    def total_downlink_bytes(self) -> int:
        return sum(r.downlink_bytes for r in self.records)

    @property
    def total_dropped(self) -> int:
        return sum(len(r.dropped) for r in self.records)

    @property
    def mean_staleness(self) -> float:
        s = [x for r in self.records for x in r.staleness]
        return sum(s) / len(s) if s else 0.0

    def ledger_totals(self) -> Dict[str, int]:
        """Whole-run byte totals per "<direction>/<wire-kind>" ledger key."""
        out: Dict[str, int] = {}
        for r in self.records:
            for k, v in r.ledger.items():
                out[k] = out.get(k, 0) + v
        return out

    def fault_totals(self) -> Dict[str, int]:
        """Whole-run fault/recovery event counts (empty without chaos)."""
        out: Dict[str, int] = {}
        for r in self.records:
            for k, v in r.faults.items():
                out[k] = out.get(k, 0) + v
        return out

    def tier_totals(self) -> Dict[str, int]:
        """Whole-run bytes per aggregation tier (the ledger-key direction).

        Flat star runs report ``{"uplink": ..., "downlink": ...}``; under
        a two-tier topology the uplink splits into ``edge_uplink``
        (client->edge last mile) and ``server_uplink`` (edge->server
        backhaul — the PS-link traffic hierarchical aggregation shrinks).
        """
        out: Dict[str, int] = {}
        for r in self.records:
            for k, v in r.ledger.items():
                tier = k.split("/", 1)[0]
                out[tier] = out.get(tier, 0) + v
        return out

    def tier_bytes_per_round(self, tier: str,
                             window: Optional[int] = None) -> float:
        """Mean bytes/round on one tier over the window (0.0 when the run
        recorded no such tier — e.g. ``server_uplink`` without a topology);
        a windowed controller signal for `federated/autoscale.py`."""
        recs = self.window(window)
        if not recs:
            return 0.0
        prefix = tier + "/"
        total = sum(v for r in recs for k, v in r.ledger.items()
                    if k.startswith(prefix))
        return total / len(recs)

    # ---- windowed observations (consumed by federated/autoscale.py) -------
    def window(self, n: Optional[int] = None) -> Sequence[RoundRecord]:
        """The last ``n`` records (all of them for ``None``)."""
        return self.records if n is None else self.records[-n:]

    def duration_percentile(self, q: float,
                            window: Optional[int] = None) -> float:
        """The q-th percentile (0..100, linear interpolation) of per-round
        durations over the window — the straggler-tail signal."""
        recs = self.window(window)
        if not recs:
            return 0.0
        xs = sorted(r.duration for r in recs)
        pos = (len(xs) - 1) * min(max(q, 0.0), 100.0) / 100.0
        lo = int(pos)
        hi = min(lo + 1, len(xs) - 1)
        return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)

    def tail_ratio(self, window: Optional[int] = None) -> float:
        """p95/p50 of round durations — >~2 means a straggler-dominated
        round time (the autoscaler's primary trigger)."""
        p50 = self.duration_percentile(50.0, window)
        return self.duration_percentile(95.0, window) / p50 if p50 > 0 else 1.0

    def drop_rate(self, window: Optional[int] = None) -> float:
        """Fraction of sampled uploads that were lost (dropout or straggler
        cut) over the window."""
        recs = self.window(window)
        lost = sum(len(r.dropped) for r in recs)
        total = lost + sum(len(r.participants) for r in recs)
        return lost / total if total else 0.0

    def bytes_per_round(self, window: Optional[int] = None,
                        direction: str = "total") -> float:
        recs = self.window(window)
        if not recs:
            return 0.0
        up = sum(r.uplink_bytes for r in recs)
        down = sum(r.downlink_bytes for r in recs)
        total = {"uplink": up, "downlink": down, "total": up + down}[direction]
        return total / len(recs)

    def loss_slope(self, window: Optional[int] = None,
                   key: str = "loss") -> float:
        """Mean per-round change of ``metrics[key]`` over the window
        (negative = still improving; ~0 = plateaued)."""
        xs = [r.metrics[key] for r in self.window(window) if key in r.metrics]
        if len(xs) < 2:
            return 0.0
        return (xs[-1] - xs[0]) / (len(xs) - 1)

    def time_to_target(self, target: float, key: str = "loss") -> Optional[float]:
        """Sim seconds until ``metrics[key]`` first reaches <= target."""
        for r in self.records:
            if key in r.metrics and r.metrics[key] <= target:
                return r.t_end
        return None

    def bytes_to_target(self, target: float, key: str = "loss",
                        direction: str = "uplink") -> Optional[int]:
        """Cumulative wire bytes until ``metrics[key]`` first <= target.

        ``direction``: "uplink" (the paper's axis), "downlink", or "total"
        (both directions — the whole WAN bill)."""
        if direction not in ("uplink", "downlink", "total"):
            raise ValueError(f"unknown direction {direction!r}")
        total = 0
        for r in self.records:
            if direction in ("uplink", "total"):
                total += r.uplink_bytes
            if direction in ("downlink", "total"):
                total += r.downlink_bytes
            if key in r.metrics and r.metrics[key] <= target:
                return total
        return None

    def summary(self) -> Dict[str, float]:
        n = max(len(self.records), 1)
        out = {
            "rounds": len(self.records),
            "simulated_seconds": self.simulated_seconds,
            "uplink_bytes": self.total_uplink_bytes,
            "downlink_bytes": self.total_downlink_bytes,
            "uplink_bytes_per_round": self.total_uplink_bytes / n,
            "downlink_bytes_per_round": self.total_downlink_bytes / n,
            "stragglers_dropped": self.total_dropped,
            "mean_staleness": self.mean_staleness,
        }
        for k in ("uplink_compressor", "downlink_compressor"):
            if k in self.meta:
                out[k] = self.meta[k]
        faults = self.fault_totals()
        if faults:
            out["faults"] = faults
        return out
