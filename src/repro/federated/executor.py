"""Cohort execution engine: who runs the round's client math, and where.

The virtual-clock `Scheduler` decides WHO participates in a server update;
the train steps in ``core/fedlite.py`` define WHAT one update computes.
This module owns the layer between them — HOW a cohort's per-client
forward/backward work is mapped onto devices. `FederatedTrainer` routes
``round`` / ``run``'s execute hook / ``measure_round_bytes`` through a
`CohortExecutor`, selected by spec string (``executor="stacked"`` /
``"mesh"`` / ``"mesh(shards=4)"``) or instance:

  * ``stacked`` — the historical single-device path, extracted verbatim:
    synchronous policies concatenate the cohort's client batches into one
    fused batch for ``make_train_step``; `AsyncBuffer` flushes go through
    ``make_weighted_step``'s per-contribution staleness weighting. The
    default — bitwise-identical to the pre-executor trainer (asserted in
    tests/test_executor.py).
  * ``mesh``    — cohort-parallel execution over the ``clients`` axis of a
    1-D device mesh (``launch/mesh.make_clients_mesh``, host-count-aware:
    a CPU CI runner forces 2-4 host devices via
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``). Client-major
    arrays — batches, per-client PRNG keys, error-feedback memories,
    `CutState`s — are placed with ``NamedSharding(mesh, P("clients"))``;
    each shard computes its local clients' gradients and the weighted
    combine crosses shards once, as an explicit psum
    (``core/fedlite.make_mesh_step``). Cohorts that do not divide the
    shard count are padded with zero-masked duplicate slots.

Every scheduler policy (FullSync / DropSlowestK / Deadline / AsyncBuffer)
executes unchanged on either backend: policies see cohorts and arrival
times, never devices. The executor also assigns each surviving participant
its shard (``place``) — the scheduler threads the placement into the
round's `Arrival`s so traces record where every client ran.

Semantics: the mesh backend reproduces the stacked backend's round metrics
and gradients (allclose; float reassociation only) whenever the model
quantizes per client (``model.client_batch == trainer.client_batch``) or
runs unquantized. A cohort-GLOBAL codebook (``model.client_batch == 0``
with PQ on) is not shard-local — the mesh executor then clusters per
client instead, which is the federated-realistic granularity; a warning is
logged for the divergence. The λ-correction scale difference between the
fused synchronous step and per-client gradients is reconciled by
``make_mesh_step``'s ``correction_scope`` (see its docstring).

New backends register through ``register_executor`` — e.g. a multi-host
pod backend mapping cohorts onto ``("pod", "clients")`` — without touching
the trainer.
"""

from __future__ import annotations

import dataclasses
import logging
import re
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.fedlite import (TrainState, make_mesh_step, make_train_step,
                                make_weighted_step)
from repro.sharding.ctx import (CLIENTS_AXIS, clients_sharding,
                                replicated_sharding)

logger = logging.getLogger(__name__)


class CohortExecutor:
    """Base class: maps one server update's cohort onto devices.

    Lifecycle: `FederatedTrainer.__post_init__` resolves the spec via
    ``make_executor`` and calls ``bind(trainer)`` exactly once — after the
    trainer has installed the cut-layer codecs into the model — so the
    executor builds its jitted steps against the final model. All entry
    points take/return the trainer's `TrainState`; metrics may stay on
    device (the trainer host-syncs once per run).
    """
    name: str = "base"

    def bind(self, trainer) -> None:
        raise NotImplementedError

    def _claim(self, trainer) -> None:
        """Attach to ``trainer``, refusing silent re-targeting: one executor
        instance holds one trainer's jitted steps, and sharing it across
        trainers would cross-wire the first trainer to the second's
        model/optimizer."""
        bound = getattr(self, "trainer", None)
        if bound is not None and bound is not trainer:
            raise ValueError(
                f"{type(self).__name__} is already bound to another trainer;"
                " construct one executor per FederatedTrainer")
        self.trainer = trainer

    # ---- cohort layout -----------------------------------------------------
    def per_client_layout(self, is_async: bool) -> bool:
        """Whether cut-layer state must be client-major for this path
        (vs the stacked synchronous layout: concatenated EF rows +
        cohort-level codebook state)."""
        raise NotImplementedError

    def place(self, participants: Sequence[Any]) -> List[Any]:
        """Annotate each `Arrival` with the shard that will execute it."""
        with obs.span("executor.place", cat="executor", backend=self.name,
                      clients=len(participants)):
            return [dataclasses.replace(a, shard=0) for a in participants]

    # ---- topology awareness ------------------------------------------------
    def set_topology(self, topology: Any) -> None:
        """Make placement cluster-aware under hierarchical aggregation.

        The trainer calls this (after ``topology.ensure``) so ``place``
        can co-locate clients of the same edge cluster on the same shard
        — the shard-local partial sums then mirror the edges' partial
        sums, keeping the pre-combination communication pattern aligned
        between the simulation's tiers and the device mesh. The stacked
        single-device path stores but ignores it.
        """
        self._cluster_of = None if topology is None \
            else getattr(topology, "cluster_of", None)

    # ---- execution ---------------------------------------------------------
    def execute(self, state: TrainState, parts: Sequence[Dict],
                weights: Optional[Sequence[float]] = None,
                cut_state: Any = None) -> Tuple[TrainState, Dict]:
        """Run one server update over ``parts`` (one batch per client, in
        participant order). ``weights=None`` selects synchronous semantics;
        a weight vector selects the per-contribution (FedBuff) semantics
        with ``cut_state`` in client-major layout."""
        raise NotImplementedError

    # ---- measurement routing ----------------------------------------------
    def client_forward(self, client_params, batch):
        """One client's cut activations for the wire measurement."""
        return self.trainer.model.client_forward(client_params, batch)


def _stack_parts(parts: Sequence[Dict]) -> Dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *parts)


@dataclasses.dataclass
class StackedExecutor(CohortExecutor):
    """The historical single-device path (bitwise-preserving default)."""
    name: str = dataclasses.field(default="stacked", init=False)

    def bind(self, trainer) -> None:
        self._claim(trainer)
        step_key = jax.random.PRNGKey(trainer.seed) \
            if trainer.stochastic_downlink else None
        # round() is public API whose callers may reuse the input state:
        # the fused step must not donate; the weighted step is only called
        # inside run()'s execute, which rebinds the state — donate it
        self._step = make_train_step(trainer.model, trainer.optimizer,
                                     quantize=trainer.quantize, donate=False,
                                     step_key=step_key)
        self._weighted_step = make_weighted_step(
            trainer.model, trainer.optimizer, quantize=trainer.quantize,
            donate=True, step_key=step_key)

    def per_client_layout(self, is_async: bool) -> bool:
        return is_async

    def execute(self, state, parts, weights=None, cut_state=None):
        # the span measures host dispatch time (the step is async on
        # device); blocking for device completion here would add the very
        # host sync the metrics buffer exists to avoid
        with obs.span("executor.execute", cat="executor", backend=self.name,
                      clients=len(parts),
                      mode="sync" if weights is None else "weighted"):
            if weights is None:
                # one definition of the bitwise-critical batch fusing
                batch = self.trainer.stack_batches(parts)
                if cut_state is None:
                    return self._step(state, batch)
                return self._step(state, batch, cut_state)
            batches = _stack_parts(parts)
            w = jnp.asarray(weights, jnp.float32)
            if cut_state is None:
                return self._weighted_step(state, batches, w)
            return self._weighted_step(state, batches, w, cut_state)


@dataclasses.dataclass
class MeshExecutor(CohortExecutor):
    """Cohort-parallel execution over the ``clients`` mesh axis.

    ``shards=0`` builds a host-count-aware mesh over every visible device;
    pass ``shards=n`` or an explicit ``mesh`` (any mesh with a ``clients``
    axis) to pin the width. Jitted steps are built lazily per semantics
    (synchronous vs weighted) on first use; one compile per distinct padded
    cohort size, like the stacked path's one-per-survivor-count.
    """
    shards: int = 0
    mesh: Any = None
    name: str = dataclasses.field(default="mesh", init=False)

    def bind(self, trainer) -> None:
        from repro.launch.mesh import make_clients_mesh
        self._claim(trainer)
        if self.mesh is None:
            self.mesh = make_clients_mesh(self.shards)
        if CLIENTS_AXIS not in self.mesh.axis_names:
            raise ValueError(f"mesh {self.mesh.axis_names} has no "
                             f"{CLIENTS_AXIS!r} axis")
        self.num_shards = int(self.mesh.shape[CLIENTS_AXIS])
        self._steps: Dict[str, Callable] = {}
        self._step_key = jax.random.PRNGKey(trainer.seed) \
            if trainer.stochastic_downlink else None
        if trainer.quantize and getattr(trainer.model, "pq", None) is not None \
                and getattr(trainer.model, "client_batch", 0) == 0:
            logger.warning(
                "mesh executor with a cohort-global PQ codebook "
                "(model.client_batch=0): clustering runs per client on the "
                "mesh — set model.client_batch=trainer.client_batch for "
                "stacked-parity quantization granularity")

    def per_client_layout(self, is_async: bool) -> bool:
        return True

    # ---- placement ---------------------------------------------------------
    def _slot_count(self, n: int) -> int:
        """Padded client-slot count: the smallest multiple of the shard
        width that fits the cohort."""
        return max(-(-n // self.num_shards) * self.num_shards,
                   self.num_shards)

    def place(self, participants):
        """Contiguous-block shard assignment; cluster-major when a
        topology is installed.

        With ``set_topology``, participants are stably sorted by edge
        cluster before the block split, so one shard's slice holds whole
        clusters wherever sizes allow — the scheduler records ``place``'s
        output order, so the trace, the executed cohort and the staleness
        weights all follow the reordering consistently.
        """
        with obs.span("executor.place", cat="executor", backend=self.name,
                      clients=len(participants)):
            parts = list(participants)
            cluster_of = getattr(self, "_cluster_of", None)
            if cluster_of is not None and parts:
                order = np.argsort(
                    np.asarray([int(cluster_of[a.client]) for a in parts]),
                    kind="stable")
                parts = [parts[i] for i in order]
            local = self._slot_count(len(parts)) // self.num_shards
            return [dataclasses.replace(a, shard=i // local)
                    for i, a in enumerate(parts)]

    # ---- execution ---------------------------------------------------------
    def _get_step(self, scope: str) -> Callable:
        if scope not in self._steps:
            self._steps[scope] = make_mesh_step(
                self.trainer.model, self.trainer.optimizer, self.mesh,
                quantize=self.trainer.quantize,
                # mirror the stacked split: the synchronous step backs the
                # public round() (callers may reuse the input state), the
                # weighted step only ever runs inside run()'s execute
                donate=scope == "client",
                step_key=self._step_key, correction_scope=scope)
        return self._steps[scope]

    def _pad(self, tree, pad: int):
        """Grow every leaf's client axis by ``pad`` duplicate (masked)
        slots — duplicating the last real client keeps the padded compute
        numerically tame (no all-zero batches through PQ seeding)."""
        if pad == 0:
            return tree
        return jax.tree.map(
            lambda x: jnp.concatenate(
                [x, jnp.repeat(x[-1:], pad, axis=0)], axis=0), tree)

    def execute(self, state, parts, weights=None, cut_state=None):
        sync = weights is None
        n = len(parts)
        slots = self._slot_count(n)
        pad = slots - n
        with obs.span("executor.execute", cat="executor", backend=self.name,
                      clients=n, slots=slots, shards=self.num_shards,
                      mode="sync" if sync else "weighted"):
            w = jnp.asarray(list(weights) if not sync else [1.0] * n,
                            jnp.float32)
            w = jnp.concatenate([w, jnp.ones((pad,), jnp.float32)]) \
                if pad else w
            mask = jnp.concatenate([jnp.ones((n,), jnp.float32),
                                    jnp.zeros((pad,), jnp.float32)]) \
                if pad else jnp.ones((n,), jnp.float32)
            sh_clients = clients_sharding(self.mesh)
            batches = jax.device_put(self._pad(_stack_parts(parts), pad),
                                     sh_clients)
            w = jax.device_put(w, sh_clients)
            mask = jax.device_put(mask, sh_clients)
            if cut_state is not None:
                cut_state = jax.device_put(self._pad(cut_state, pad),
                                           sh_clients)
            state = jax.device_put(state, replicated_sharding(self.mesh))
            step = self._get_step("cohort" if sync else "client")
            state, metrics = step(state, batches, w, mask, cut_state)
            if sync:
                # keep synchronous metrics key-compatible with the stacked
                # path
                metrics.pop("mean_staleness_weight", None)
            return state, metrics


# ---------------------------------------------------------------------------
# registry + spec parsing
# ---------------------------------------------------------------------------

_EXECUTORS: Dict[str, Callable[..., CohortExecutor]] = {}


def register_executor(name: str,
                      factory: Callable[..., CohortExecutor]) -> None:
    """Register (or replace) a named executor factory."""
    _EXECUTORS[name] = factory


register_executor("stacked", lambda **kw: StackedExecutor(**kw))
register_executor("mesh", lambda **kw: MeshExecutor(**kw))


def available_executors() -> Tuple[str, ...]:
    return tuple(sorted(_EXECUTORS))


_SPEC_RE = re.compile(r"^(?P<name>[a-zA-Z_]\w*)(?:\((?P<args>.*)\))?$")


def make_executor(spec) -> CohortExecutor:
    """Build an executor from a spec string (``"stacked"``, ``"mesh"``,
    ``"mesh(shards=4)"``) or pass an instance through unchanged. ``None``
    resolves to the stacked default."""
    if spec is None:
        return StackedExecutor()
    if isinstance(spec, CohortExecutor):
        return spec
    m = _SPEC_RE.match(spec.strip())
    if not m or m.group("name") not in _EXECUTORS:
        raise ValueError(f"unknown executor spec {spec!r}; registered: "
                         f"{available_executors()}")
    kwargs: Dict[str, Any] = {}
    for part in (m.group("args") or "").split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"executor arg {part!r} must be key=value")
        k, v = part.split("=", 1)
        kwargs[k.strip()] = int(v.strip()) if v.strip().isdigit() \
            else v.strip()
    return _EXECUTORS[m.group("name")](**kwargs)
