"""Virtual-clock event scheduler for heterogeneous federated rounds.

The scheduler owns *time and participation*; it never touches model math.
Each round it asks the caller for a cohort, simulates every client's
round trip on the virtual clock —

    downlink(broadcast) -> local compute x multiplier -> uplink(payload)

— draws dropouts, applies a participation ``Policy`` to decide which
uploads the server aggregates and when the round ends, and then invokes
the caller's ``execute`` hook with the surviving participants. The hook
runs the actual (jitted) training update; the scheduler records what the
wire and the clock saw into a `Trace`.

Policies
--------
  * ``FullSync``       — wait for every non-dropped upload (the classic
                         synchronous round; the pre-subsystem behavior
                         under the IDEAL profile).
  * ``DropSlowestK``   — over-provision and cut the k slowest uploads
                         (bounded-straggler synchronous FL).
  * ``Deadline``       — hard per-round wall-clock budget; whatever
                         missed it is dropped.
  * ``AsyncBuffer``    — FedBuff-style asynchrony: clients run
                         continuously, the server updates whenever
                         ``buffer_size`` uploads have accumulated. The
                         scheduler hands ``execute`` one staleness weight
                         PER CONTRIBUTION; `FederatedTrainer` applies them
                         per client gradient split (exact FedBuff — see
                         ``core/fedlite.make_weighted_step``), not as a
                         cohort-mean scale on the fused update.

Determinism: given the same seed, fleet, policy and cohort stream, the
event loop (a heapq keyed on (time, sequence number)) produces an
identical trace — asserted by tests/test_scheduler.py.
"""

from __future__ import annotations

import dataclasses
import heapq
import logging
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

logger = logging.getLogger(__name__)

from repro import obs
from repro.federated.network import ClientProfile
from repro.federated.trace import RoundRecord, Trace


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One completed client upload as seen by the server.

    ``shard`` is the execution placement — which slice of the cohort
    executor's ``clients`` device axis ran this participant's math
    (``federated/executor.py``; 0 for the single-device stacked path).
    Assigned by the executor's ``place`` hook just before ``execute`` and
    recorded per round in ``RoundRecord.shards``.
    """
    client: int
    version: int        # server model version the client computed against
    t_arrival: float    # sim seconds when the upload finished
    shard: int = 0      # executor shard the participant was placed on


# ---------------------------------------------------------------------------
# participation policies
# ---------------------------------------------------------------------------

class FullSync:
    """Aggregate every upload that was not lost to dropout."""
    name = "full_sync"

    def split(self, arrivals: List[Arrival], t_start: float):
        t_end = max((a.t_arrival for a in arrivals), default=t_start)
        return list(arrivals), [], t_end


class DropSlowestK:
    """Cut the k slowest uploads; the round closes with the survivors."""

    def __init__(self, k: int):
        if k < 0:
            raise ValueError("k must be >= 0")
        self.k = k
        self.name = f"drop_slowest_{k}"

    def split(self, arrivals: List[Arrival], t_start: float):
        ordered = sorted(arrivals, key=lambda a: a.t_arrival)
        keep = max(len(ordered) - self.k, 1) if ordered else 0
        survivors, cut = ordered[:keep], ordered[keep:]
        t_end = survivors[-1].t_arrival if survivors else t_start
        return survivors, cut, t_end


class Deadline:
    """Hard wall-clock budget per round; late uploads are dropped."""

    def __init__(self, seconds: float):
        if seconds <= 0:
            raise ValueError("deadline must be positive")
        self.seconds = seconds
        self.name = f"deadline_{seconds:g}s"

    def split(self, arrivals: List[Arrival], t_start: float):
        cutoff = t_start + self.seconds
        survivors = [a for a in arrivals if a.t_arrival <= cutoff]
        cut = [a for a in arrivals if a.t_arrival > cutoff]
        if cut:
            t_end = cutoff
        else:
            t_end = max((a.t_arrival for a in survivors), default=cutoff)
        return survivors, cut, t_end


class AsyncBuffer:
    """FedBuff-style async aggregation (Nguyen et al. 2022).

    The server updates every ``buffer_size`` arrivals; each contribution
    is discounted by ``staleness_weight(staleness)`` where staleness is
    the number of server updates that happened since the client pulled
    its model. The default ``1/sqrt(1+s)`` is FedBuff's polynomial decay.
    The weights are delivered per contribution (aligned with the buffer
    order) so the executor can discount each client's gradient split by
    its own staleness.
    """

    def __init__(self, buffer_size: int = 4,
                 staleness_weight: Optional[Callable[[int], float]] = None):
        if buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        self.buffer_size = buffer_size
        self.staleness_weight = staleness_weight or \
            (lambda s: 1.0 / math.sqrt(1.0 + s))
        self.name = f"async_buffer_{buffer_size}"


Policy = Any  # FullSync | DropSlowestK | Deadline | AsyncBuffer

# execute(update_idx, participants, staleness_weights) -> metrics (may stay
# on device; the caller converts at end of run)
ExecuteFn = Callable[[int, Sequence[Arrival], Sequence[float]], Dict]


@dataclasses.dataclass
class Scheduler:
    """Event-driven round driver over a fixed fleet of `ClientProfile`s.

    ``uplink_bytes`` / ``downlink_bytes`` are the measured per-client
    payload sizes (wire-codec bytes for FedLite, raw activation bytes for
    SplitFed, parameter bytes for FedAvg) — static per run because the
    payload layout is shape-determined.
    """
    fleet: Sequence[ClientProfile]
    policy: Policy = dataclasses.field(default_factory=FullSync)
    client_step_seconds: float = 1.0
    server_step_seconds: float = 0.0
    seed: int = 0

    def run(self, rounds: int, *,
            sample_cohort: Callable[[int], Sequence[int]],
            uplink_bytes: int,
            downlink_bytes: int,
            execute: ExecuteFn,
            placement: Optional[Callable[[Sequence[Arrival]],
                                         Sequence[Arrival]]] = None,
            wire_kinds: Optional[Tuple[str, str]] = None) -> Trace:
        """Drive ``rounds`` server updates.

        ``placement`` (optional) maps each update's surviving participants
        to shard-annotated `Arrival`s just before ``execute`` — the cohort
        executor's ``place`` hook — so the cohort the executor runs and
        the cohort the trace records carry the same device placement.

        ``wire_kinds`` (optional) is the ``(uplink, downlink)`` wire-kind
        pair behind the per-client payload bytes ("pq", "dense",
        "sparse", "scalar", "pq-delta"); when given, every `RoundRecord`
        carries a ``ledger`` of per-direction, per-kind byte totals.
        """
        place = placement or (lambda parts: list(parts))
        if isinstance(self.policy, AsyncBuffer):
            return self._run_async(rounds, sample_cohort, uplink_bytes,
                                   downlink_bytes, execute, place, wire_kinds)
        return self._run_sync(rounds, sample_cohort, uplink_bytes,
                              downlink_bytes, execute, place, wire_kinds)

    # ---- shared -----------------------------------------------------------
    def _round_trip(self, p: ClientProfile, uplink_bytes: int,
                    downlink_bytes: int) -> float:
        return (p.downlink_seconds(downlink_bytes)
                + p.compute_seconds(self.client_step_seconds)
                + p.uplink_seconds(uplink_bytes))

    @staticmethod
    def _ledger(wire_kinds: Optional[Tuple[str, str]],
                uplink_total: int, downlink_total: int) -> Dict[str, int]:
        if wire_kinds is None:
            return {}
        up_kind, down_kind = wire_kinds
        return {f"uplink/{up_kind}": uplink_total,
                f"downlink/{down_kind}": downlink_total}

    # ---- synchronous policies ---------------------------------------------
    def _run_sync(self, rounds, sample_cohort, uplink_bytes, downlink_bytes,
                  execute, place, wire_kinds=None) -> Trace:
        rng = np.random.default_rng(self.seed)
        trace = Trace()
        t = 0.0
        for rd in range(rounds):
            with obs.span("scheduler.round", cat="scheduler", round=rd):
                ids = [int(c) for c in sample_cohort(rd)]
                dropouts: List[int] = []
                heap: List[Tuple[float, int, int]] = []
                for seq, cid in enumerate(ids):
                    p = self.fleet[cid]
                    if rng.random() < p.dropout_prob:
                        dropouts.append(cid)
                        continue
                    dt = self._round_trip(p, uplink_bytes, downlink_bytes)
                    heapq.heappush(heap, (t + dt, seq, cid))
                arrivals: List[Arrival] = []
                while heap:
                    t_arr, _, cid = heapq.heappop(heap)
                    arrivals.append(Arrival(cid, rd, t_arr))
                survivors, cut, t_end = self.policy.split(arrivals, t)
                t_end += self.server_step_seconds
                survivors = place(survivors)
                metrics = execute(rd, survivors, [1.0] * len(survivors)) \
                    if survivors else {}
            obs.virtual_span("scheduler.round", t, t_end, round=rd,
                             participants=len(survivors),
                             dropped=len(dropouts) + len(cut))
            if cut:
                obs.event("policy.cut", cat="scheduler", lane="virtual",
                          t=t_end, round=rd,
                          policy=getattr(self.policy, "name", "?"),
                          cut=[a.client for a in cut])
            trace.append(RoundRecord(
                round=rd, t_start=t, t_end=t_end,
                participants=tuple(a.client for a in survivors),
                dropped=tuple(dropouts) + tuple(a.client for a in cut),
                # every completed upload crossed the wire, aggregated or not
                uplink_bytes=len(arrivals) * uplink_bytes,
                downlink_bytes=len(ids) * downlink_bytes,
                staleness=(0,) * len(survivors),
                shards=tuple(a.shard for a in survivors),
                metrics=metrics,
                ledger=self._ledger(wire_kinds,
                                    len(arrivals) * uplink_bytes,
                                    len(ids) * downlink_bytes)))
            t = t_end
        return trace

    # ---- async buffer ------------------------------------------------------
    def _run_async(self, rounds, sample_cohort, uplink_bytes, downlink_bytes,
                   execute, place, wire_kinds=None) -> Trace:
        """FedBuff loop: the initial cohort sets the concurrency; every
        completed (or dropped) slot is refilled with the next client from a
        fresh-cohort stream, so the whole population keeps rotating through
        the in-flight set just as sync rounds resample each round."""
        policy: AsyncBuffer = self.policy
        rng = np.random.default_rng(self.seed)
        trace = Trace()
        # heap entries: (t_arrival, seq, client, version, was_dropped)
        heap: List[Tuple[float, int, int, int, bool]] = []
        seq = 0
        version = 0
        wave = 0
        queue: List[int] = []

        def next_client() -> int:
            nonlocal wave
            if not queue:
                queue.extend(int(c) for c in sample_cohort(wave))
                wave += 1
            return queue.pop(0)

        def dispatch(cid: int, t: float, ver: int):
            nonlocal seq
            p = self.fleet[cid]
            dropped = bool(rng.random() < p.dropout_prob)
            dt = self._round_trip(p, uplink_bytes, downlink_bytes)
            heapq.heappush(heap, (t + dt, seq, cid, ver, dropped))
            seq += 1

        for cid in sample_cohort(wave):
            dispatch(int(cid), 0.0, version)
        wave += 1

        buffer: List[Arrival] = []
        dropped_accum: List[int] = []
        dispatches = len(heap)   # downlink pushes since last flush
        t_round_start = 0.0
        updates = 0
        # termination guard: a fleet that only ever drops out would otherwise
        # spin the virtual clock forever without filling the buffer
        consecutive_drops = 0
        max_consecutive_drops = max(1000, 10 * len(self.fleet))
        while updates < rounds and heap:
            t_arr, _, cid, ver, was_dropped = heapq.heappop(heap)
            if was_dropped:
                dropped_accum.append(cid)
                dispatch(next_client(), t_arr, version)
                dispatches += 1
                consecutive_drops += 1
                if consecutive_drops >= max_consecutive_drops:
                    logger.warning(
                        "async scheduler: %d consecutive dropouts with no "
                        "progress after %d updates; stopping early",
                        consecutive_drops, updates)
                    break
                continue
            consecutive_drops = 0
            buffer.append(Arrival(cid, ver, t_arr))
            if len(buffer) >= policy.buffer_size:
                t_end = t_arr + self.server_step_seconds
                staleness = [version - a.version for a in buffer]
                weights = [policy.staleness_weight(s) for s in staleness]
                buffer = place(buffer)
                with obs.span("scheduler.flush", cat="scheduler",
                              update=updates, buffered=len(buffer)):
                    metrics = execute(updates, buffer, weights)
                obs.virtual_span("scheduler.flush", t_round_start, t_end,
                                 update=updates, buffered=len(buffer),
                                 staleness_max=max(staleness))
                version += 1
                dispatch(next_client(), t_arr, version)  # slot sees new model
                dispatches += 1
                trace.append(RoundRecord(
                    round=updates, t_start=t_round_start, t_end=t_end,
                    participants=tuple(a.client for a in buffer),
                    dropped=tuple(dropped_accum),
                    uplink_bytes=len(buffer) * uplink_bytes,
                    downlink_bytes=dispatches * downlink_bytes,
                    staleness=tuple(staleness),
                    shards=tuple(a.shard for a in buffer),
                    metrics=metrics,
                    ledger=self._ledger(wire_kinds,
                                        len(buffer) * uplink_bytes,
                                        dispatches * downlink_bytes)))
                buffer, dropped_accum, dispatches = [], [], 0
                t_round_start = t_end
                updates += 1
            else:
                dispatch(next_client(), t_arr, version)
                dispatches += 1
        return trace


def ideal_scheduler(num_clients: int, *, seed: int = 0) -> Scheduler:
    """The pre-subsystem simulation: identical infinitely-fast clients,
    no dropout, full synchronization — bitwise-preserves the original
    `FederatedTrainer` trajectory (tests/test_scheduler.py)."""
    from repro.federated.network import uniform_fleet
    return Scheduler(fleet=uniform_fleet(num_clients), policy=FullSync(),
                     client_step_seconds=1.0, seed=seed)
