"""Virtual-clock round scheduler for heterogeneous federated fleets.

The scheduler owns *time and participation*; it never touches model math.
Each round it asks the caller for a cohort, simulates every client's
round trip on the virtual clock —

    downlink(broadcast) -> local compute x multiplier -> uplink(payload)

— draws dropouts, applies a participation ``Policy`` to decide which
uploads the server aggregates and when the round ends, and then invokes
the caller's ``execute`` hook with the surviving participants. The hook
runs the actual (jitted) training update; the scheduler records what the
wire and the clock saw into a `Trace`.

Policies
--------
  * ``FullSync``       — wait for every non-dropped upload (the classic
                         synchronous round; the pre-subsystem behavior
                         under the IDEAL profile).
  * ``DropSlowestK``   — over-provision and cut the k slowest uploads
                         (bounded-straggler synchronous FL).
  * ``Deadline``       — hard per-round wall-clock budget; whatever
                         missed it is dropped.
  * ``AsyncBuffer``    — FedBuff-style asynchrony: clients run
                         continuously, the server updates whenever
                         ``buffer_size`` uploads have accumulated. The
                         scheduler hands ``execute`` one staleness weight
                         PER CONTRIBUTION; `FederatedTrainer` applies them
                         per client gradient split (exact FedBuff — see
                         ``core/fedlite.make_weighted_step``), not as a
                         cohort-mean scale on the fused update.

Backends
--------
Two interchangeable event cores produce **bitwise-identical traces**
(tests/test_fleet_scale.py sweeps fleet x policy x cohort asserting it):

  * ``backend="heapq"``  — the original per-arrival Python event loop.
    Every cohort member becomes heap entries and `ClientProfile` method
    calls; O(cohort) Python objects per round. Kept as the reference
    implementation and parity oracle — it is the executable spec.
  * ``backend="vector"`` — the fleet-scale core. The fleet is a
    struct-of-arrays `ClientFleet`; per-round dropout draws, the whole
    cohort's downlink/compute/uplink times, and the policy cut run as
    array ops (one stable argsort + an O(1) prefix cut — the sort
    subsumes the ``np.partition`` selection DropSlowestK alone would
    need, because the trace records participants in arrival order).
    Python appears only at round boundaries: ~10k-client rounds over a
    10^6-client fleet cost milliseconds, not seconds
    (``benchmarks/bench_network.py --fleet-scale`` tracks it).
    `AsyncBuffer` cannot be a pure per-round array op — each completion
    triggers a refill whose dispatch time depends on completion order —
    so its vector core keeps a *lean* heap of ``(time, seq)`` scalar
    tuples while everything per-dispatch (client stream, dropout draws,
    round-trip times, staleness at flush) is precomputed in vectorized
    waves; seq order == stream order == RNG draw order makes that exact.

Parity rests on three invariants, pinned by tests: numpy float64
elementwise ops are the same IEEE doubles as Python's scalar float ops
when associated identically (`ClientFleet.round_trip_seconds` keeps the
``(downlink + compute) + uplink`` order); one ``Generator.random(n)``
call consumes the identical PCG64 stream as ``n`` scalar draws; and a
stable argsort on arrival times reproduces heap pop order because heap
ties break on the cohort sequence number.

Topology: with ``topology=TwoTierTopology(...)`` uploads terminate on
location-clustered edge aggregators that pre-combine their cluster's
payloads before one edge->server backhaul hop (async: store-and-forward
relay) — per-tier times on the virtual clock, per-tier
``edge_uplink/server_uplink`` ledger entries. Both backends call the
same `TwoTierTopology` array helpers, so parity is preserved under a
topology by construction (see ``federated/topology.py``).

Determinism: given the same seed, fleet, policy, cohort stream and
backend, the trace is identical — asserted by tests/test_scheduler.py.
"""

from __future__ import annotations

import dataclasses
import heapq
import logging
import math
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

logger = logging.getLogger(__name__)

from repro import obs
from repro.obs import flight
from repro.federated.faults import FaultPlan, ServerKilled, make_injector
from repro.federated.network import ClientFleet, ClientProfile
from repro.federated.trace import RoundRecord, Trace


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One completed client upload as seen by the server.

    ``shard`` is the execution placement — which slice of the cohort
    executor's ``clients`` device axis ran this participant's math
    (``federated/executor.py``; 0 for the single-device stacked path).
    Assigned by the executor's ``place`` hook just before ``execute`` and
    recorded per round in ``RoundRecord.shards``.
    """
    client: int
    version: int        # server model version the client computed against
    t_arrival: float    # sim seconds when the upload finished
    shard: int = 0      # executor shard the participant was placed on


# ---------------------------------------------------------------------------
# participation policies
# ---------------------------------------------------------------------------
#
# Each sync policy implements two equivalent cuts:
#   split(arrivals, t_start)          — reference: list of Arrival objects,
#                                       already sorted by (t_arrival, seq).
#   split_vector(t_sorted, t_start)   — vector core: the sorted arrival-time
#                                       array; returns (keep_count, t_end)
#                                       where survivors are the first
#                                       ``keep_count`` sorted entries.
# The prefix-cut form exists because the scheduler hands every policy the
# *stably sorted* arrival vector (the trace needs arrival order anyway), so
# all three cuts are O(1)/O(log n) index arithmetic on it.

class FullSync:
    """Aggregate every upload that was not lost to dropout."""
    name = "full_sync"

    def split(self, arrivals: List[Arrival], t_start: float):
        t_end = max((a.t_arrival for a in arrivals), default=t_start)  # fedlint: disable=python-loop-over-fleet
        return list(arrivals), [], t_end

    def split_vector(self, t_sorted: np.ndarray, t_start: float):
        n = int(t_sorted.shape[0])
        return n, (float(t_sorted[-1]) if n else t_start)


class DropSlowestK:
    """Cut the k slowest uploads; the round closes with the survivors.

    Edge semantics (pinned by tests in BOTH backends):

      * ``k >= len(arrivals)`` keeps exactly ONE survivor — the fastest
        upload — never zero: ``keep = max(len - k, 1)``. Cutting the
        whole cohort would leave the server aggregating nothing while
        still paying the round, so over-provisioned ``k`` degrades to
        "fastest client wins" rather than a silent no-op round.
      * Empty arrivals (the entire cohort dropped out before uploading)
        keep zero and the round ends at ``t_start`` — there was never an
        upload to wait for.
    """

    def __init__(self, k: int):
        if k < 0:
            raise ValueError("k must be >= 0")
        self.k = k
        self.name = f"drop_slowest_{k}"

    def split(self, arrivals: List[Arrival], t_start: float):
        ordered = sorted(arrivals, key=lambda a: a.t_arrival)
        keep = max(len(ordered) - self.k, 1) if ordered else 0
        survivors, cut = ordered[:keep], ordered[keep:]
        t_end = survivors[-1].t_arrival if survivors else t_start
        return survivors, cut, t_end

    def split_vector(self, t_sorted: np.ndarray, t_start: float):
        # selection needs no np.partition: t_sorted arrives fully sorted
        n = int(t_sorted.shape[0])
        keep = max(n - self.k, 1) if n else 0
        return keep, (float(t_sorted[keep - 1]) if keep else t_start)


class Deadline:
    """Hard wall-clock budget per round; late uploads are dropped.

    An upload landing exactly on the cutoff survives (``<=``). With no
    arrivals at all the round still ends at the cutoff — the server
    waits out its budget before deciding nobody came.
    """

    def __init__(self, seconds: float):
        if seconds <= 0:
            raise ValueError("deadline must be positive")
        self.seconds = seconds
        self.name = f"deadline_{seconds:g}s"

    def split(self, arrivals: List[Arrival], t_start: float):
        cutoff = t_start + self.seconds
        survivors = [a for a in arrivals if a.t_arrival <= cutoff]  # fedlint: disable=python-loop-over-fleet
        cut = [a for a in arrivals if a.t_arrival > cutoff]  # fedlint: disable=python-loop-over-fleet
        if cut:
            t_end = cutoff
        else:
            t_end = max((a.t_arrival for a in survivors), default=cutoff)
        return survivors, cut, t_end

    def split_vector(self, t_sorted: np.ndarray, t_start: float):
        n = int(t_sorted.shape[0])
        cutoff = t_start + self.seconds
        keep = int(np.searchsorted(t_sorted, cutoff, side="right"))
        if keep < n:
            return keep, cutoff
        return keep, (float(t_sorted[-1]) if n else cutoff)


class AsyncBuffer:
    """FedBuff-style async aggregation (Nguyen et al. 2022).

    The server updates every ``buffer_size`` arrivals; each contribution
    is discounted by ``staleness_weight(staleness)`` where staleness is
    the number of server updates that happened since the client pulled
    its model. The default ``1/sqrt(1+s)`` is FedBuff's polynomial decay.
    The weights are delivered per contribution (aligned with the buffer
    order) so the executor can discount each client's gradient split by
    its own staleness.
    """

    def __init__(self, buffer_size: int = 4,
                 staleness_weight: Optional[Callable[[int], float]] = None):
        if buffer_size < 1:
            raise ValueError("buffer_size must be >= 1")
        self.buffer_size = buffer_size
        self.staleness_weight = staleness_weight or \
            (lambda s: 1.0 / math.sqrt(1.0 + s))
        self.name = f"async_buffer_{buffer_size}"


Policy = Any  # FullSync | DropSlowestK | Deadline | AsyncBuffer

# execute(update_idx, participants, staleness_weights) -> metrics (may stay
# on device; the caller converts at end of run)
ExecuteFn = Callable[[int, Sequence[Arrival], Sequence[float]], Dict]

_BACKENDS = ("auto", "vector", "heapq")


@dataclasses.dataclass
class Scheduler:
    """Round driver over a fixed fleet (`ClientFleet` or profile list).

    ``uplink_bytes`` / ``downlink_bytes`` are the measured per-client
    payload sizes (wire-codec bytes for FedLite, raw activation bytes for
    SplitFed, parameter bytes for FedAvg) — static per run because the
    payload layout is shape-determined.

    ``backend`` selects the event core: ``"vector"`` (fleet-scale array
    core), ``"heapq"`` (per-arrival reference), or ``"auto"`` (vector
    whenever the policy provides ``split_vector`` or is `AsyncBuffer`;
    custom policies exposing only ``split`` fall back to the reference
    loop). Both produce bitwise-identical traces.

    ``topology`` (optional, e.g. `TwoTierTopology`) inserts an edge
    aggregation tier between clients and server — see the module
    docstring and ``federated/topology.py``.

    ``faults`` (optional `FaultPlan`) arms deterministic fault injection:
    mid-round client crashes with bounded retry-and-backoff in virtual
    time (retry downlinks hit the ledger under ``retry_downlink/<kind>``,
    budget-exhausted clients are dropped for the round), async arrival
    jitter, edge outage windows (clients re-home), and a `ServerKilled`
    raise at configured rounds. Fault decisions are stateless hashes —
    they never consume the scheduler RNG — so an all-quiet plan is
    bitwise-identical to no plan, and both backends stay parity-exact
    under any plan (``federated/faults.py``).
    """
    fleet: Sequence[ClientProfile]
    policy: Policy = dataclasses.field(default_factory=FullSync)
    client_step_seconds: float = 1.0
    server_step_seconds: float = 0.0
    seed: int = 0
    backend: str = "auto"
    topology: Optional[Any] = None
    faults: Optional[FaultPlan] = None

    def run(self, rounds: int, *,
            sample_cohort: Callable[[int], Sequence[int]],
            uplink_bytes: int,
            downlink_bytes: int,
            execute: ExecuteFn,
            placement: Optional[Callable[[Sequence[Arrival]],
                                         Sequence[Arrival]]] = None,
            wire_kinds: Optional[Tuple[str, str]] = None,
            cursor: Optional[Dict[str, Any]] = None,
            on_round: Optional[Callable[[int, Dict[str, Any]], None]] = None,
            ) -> Trace:
        """Drive ``rounds`` server updates.

        ``placement`` (optional) maps each update's surviving participants
        to shard-annotated `Arrival`s just before ``execute`` — the cohort
        executor's ``place`` hook — so the cohort the executor runs and
        the cohort the trace records carry the same device placement.

        ``wire_kinds`` (optional) is the ``(uplink, downlink)`` wire-kind
        pair behind the per-client payload bytes ("pq", "dense",
        "sparse", "scalar", "pq-delta"); when given, every `RoundRecord`
        carries a ``ledger`` of per-direction, per-kind byte totals —
        split into ``edge_uplink``/``server_uplink`` tiers when a
        topology is installed.

        ``cursor`` / ``on_round`` are the crash-recovery hooks (sync
        policies only — async in-flight heaps are not checkpointable).
        A cursor ``{"round", "t", "rng"}`` resumes the virtual clock and
        RNG stream exactly where a previous run's cursor left them;
        ``rounds`` stays the absolute end index. ``on_round(rd, cursor)``
        fires after each completed round with the cursor that would
        resume AFTER it — what a checkpoint must save. The returned
        trace's ``cursor`` field holds the final resume point.
        """
        place = placement or (lambda parts: list(parts))
        # flight shard attribution only happens under a real placement
        # hook: without one every Arrival carries the default shard and
        # the per-flight column keeps its -1 "unplaced" marker — skipping
        # the id-matching scatter entirely on placement-free runs
        self._attribute_shards = placement is not None
        if self.topology is not None:
            self.topology.ensure(len(self.fleet))
        backend = self._resolve_backend()
        is_async = isinstance(self.policy, AsyncBuffer)
        inj = make_injector(self.faults)
        if is_async:
            if cursor is not None or on_round is not None:
                raise ValueError(
                    "cursor/on_round checkpoint-resume is only supported "
                    "for synchronous policies: the async in-flight heap "
                    "is not part of the checkpointable state")
            runner = self._run_async_vector if backend == "vector" \
                else self._run_async
            return runner(rounds, sample_cohort, uplink_bytes,
                          downlink_bytes, execute, place, wire_kinds, inj)
        runner = self._run_sync_vector if backend == "vector" \
            else self._run_sync
        return runner(rounds, sample_cohort, uplink_bytes, downlink_bytes,
                      execute, place, wire_kinds, inj, cursor, on_round)

    def _resolve_backend(self) -> str:
        if self.backend not in _BACKENDS:
            raise ValueError(
                f"unknown scheduler backend {self.backend!r}; "
                f"expected one of {_BACKENDS}")
        vectorizable = isinstance(self.policy, AsyncBuffer) or \
            hasattr(self.policy, "split_vector")
        if self.backend == "vector" and not vectorizable:
            raise ValueError(
                f"policy {getattr(self.policy, 'name', self.policy)!r} has "
                "no split_vector; use backend='heapq' or 'auto'")
        if self.backend == "auto":
            return "vector" if vectorizable else "heapq"
        return self.backend

    # ---- shared -----------------------------------------------------------
    def _round_trip(self, p: ClientProfile, uplink_bytes: int,
                    downlink_bytes: int) -> float:
        return (p.downlink_seconds(downlink_bytes)
                + p.compute_seconds(self.client_step_seconds)
                + p.uplink_seconds(uplink_bytes))

    @staticmethod
    def _ledger(wire_kinds: Optional[Tuple[str, str]],
                uplink_total: int, downlink_total: int,
                tier_bytes: Optional[Tuple[int, int]] = None,
                retry_bytes: int = 0) -> Dict[str, int]:
        """Per-direction, per-wire-kind byte entries for one record.

        Flat star topology keys uplink traffic as ``uplink/<kind>``;
        under a two-tier topology the same traffic splits into
        ``edge_uplink/<kind>`` (client->edge, every completed upload) and
        ``server_uplink/<kind>`` (edge->server backhaul) via
        ``tier_bytes=(edge_total, server_total)``. ``retry_bytes`` is the
        fault-injected crash-retry re-broadcast traffic, ledgered under
        its own ``retry_downlink/<kind>`` key so wasted bytes are
        auditable separately from the first dispatch.
        """
        if wire_kinds is None:
            return {}
        up_kind, down_kind = wire_kinds
        if tier_bytes is None:
            entries = {f"uplink/{up_kind}": uplink_total}
        else:
            entries = {f"edge_uplink/{up_kind}": tier_bytes[0],
                       f"server_uplink/{up_kind}": tier_bytes[1]}
        entries[f"downlink/{down_kind}"] = downlink_total
        if retry_bytes:
            entries[f"retry_downlink/{down_kind}"] = retry_bytes
        return entries

    def _sync_uplink_accounting(self, n_arrivals: int, uplink_bytes: int,
                                survivor_clients: np.ndarray,
                                survivor_t: np.ndarray, t_policy_end: float,
                                down_edges: Sequence[int] = (),
                                ) -> Tuple[float, int, Optional[Tuple[int, int]],
                                           Optional[int]]:
        """Apply the topology tier (if any) to one sync round's cut.

        Returns ``(t_end, uplink_total, tier_bytes, edges)`` — shared by
        both backends so their topology arithmetic is the same code.
        ``down_edges`` (fault injection) marks edge aggregators in an
        outage window; their clients re-home inside ``sync_round``.
        """
        flat_total = n_arrivals * uplink_bytes
        if self.topology is None:
            return float(t_policy_end), flat_total, None, None
        t_end, edges, server_bytes = self.topology.sync_round(
            survivor_clients, survivor_t, t_policy_end, uplink_bytes,
            down_edges=down_edges)
        return t_end, flat_total + server_bytes, \
            (flat_total, server_bytes), edges

    # ---- synchronous policies: reference heapq backend --------------------
    def _run_sync(self, rounds, sample_cohort, uplink_bytes, downlink_bytes,
                  execute, place, wire_kinds=None, inj=None, cursor=None,
                  on_round=None) -> Trace:
        rng = np.random.default_rng(self.seed)
        trace = Trace()
        t = 0.0
        start = 0
        if cursor is not None:
            start = int(cursor["round"])
            t = float(cursor["t"])
            rng.bit_generator.state = cursor["rng"]
        crash_on = inj is not None and inj.plan.crash_rate > 0
        rec_fl = flight.flights_enabled()
        for rd in range(start, rounds):
            if inj is not None and inj.server_killed(rd):
                raise ServerKilled(rd)
            faults: Dict[str, int] = {}
            with obs.span("scheduler.round", cat="scheduler", round=rd):
                ids = [int(c) for c in sample_cohort(rd)]
                dropouts: List[int] = []
                heap: List[Tuple[float, int, int]] = []
                gone_ids: List[int] = []
                retry_dl = 0
                # per-cohort-position arrival times for the flight frame
                # (NaN = dropout / retry budget exhausted); filled with the
                # exact scalars pushed on the heap, so the recorded column
                # is bitwise-identical to the vector backend's array form
                arr_by_pos = np.full(len(ids), np.nan) if rec_fl else None
                if not crash_on:
                    for seq, cid in enumerate(ids):
                        p = self.fleet[cid]
                        if rng.random() < p.dropout_prob:
                            dropouts.append(cid)
                            continue
                        dt = self._round_trip(p, uplink_bytes, downlink_bytes)
                        t_arr = t + dt
                        heapq.heappush(heap, (t_arr, seq, cid))
                        if arr_by_pos is not None:
                            arr_by_pos[seq] = t_arr
                else:
                    # benign dropout draws FIRST (same RNG order as the
                    # fault-free path), then stateless crash/retry draws
                    # over the live set — collect-then-push so the retry
                    # arithmetic runs through the same vectorized helper
                    # as the vector backend
                    live: List[Tuple[int, int, float, float]] = []
                    for seq, cid in enumerate(ids):
                        p = self.fleet[cid]
                        if rng.random() < p.dropout_prob:
                            dropouts.append(cid)
                            continue
                        live.append((
                            seq, cid,
                            self._round_trip(p, uplink_bytes, downlink_bytes),
                            p.downlink_seconds(downlink_bytes)
                            + p.compute_seconds(self.client_step_seconds)))
                    crashes = inj.crash_attempts_sync(
                        rd, np.asarray([c for _, c, _, _ in live], np.int64))
                    extra, gone = inj.retry_overhead(
                        crashes, np.asarray([dc for *_, dc in live]))
                    xdl = inj.extra_downlinks(crashes, gone)
                    retry_dl = int(xdl.sum())
                    for (seq, cid, dt, _), ex, g in zip(live, extra, gone):
                        if g:
                            gone_ids.append(cid)
                            continue
                        t_arr = t + (float(ex) + dt)
                        heapq.heappush(heap, (t_arr, seq, cid))
                        if arr_by_pos is not None:
                            arr_by_pos[seq] = t_arr
                    n_crashes = int(crashes.sum())
                    if n_crashes:
                        faults["crashes"] = n_crashes
                        faults["retries"] = retry_dl
                    if gone_ids:
                        faults["crash_dropped"] = len(gone_ids)
                arrivals: List[Arrival] = []
                arrival_seqs: List[int] = []
                while heap:
                    t_arr, sq, cid = heapq.heappop(heap)
                    arrivals.append(Arrival(cid, rd, t_arr))
                    arrival_seqs.append(sq)
                survivors, cut, t_end = self.policy.split(arrivals, t)
                down = inj.down_edges(t) \
                    if inj is not None and self.topology is not None else ()
                t_end, uplink_total, tier_bytes, edges = \
                    self._sync_uplink_accounting(
                        len(arrivals), uplink_bytes,
                        np.asarray([a.client for a in survivors], np.int64),
                        np.asarray([a.t_arrival for a in survivors]), t_end,
                        down)
                if down:
                    faults["edges_down"] = len(down)
                    rehomed = getattr(self.topology, "last_rehomed", 0)
                    if rehomed:
                        faults["rehomed"] = rehomed
                t_end += self.server_step_seconds
                fl_frame = None
                if rec_fl:
                    # survivors/cut are the SAME Arrival objects the pop
                    # loop appended (policies sort/filter, never copy), so
                    # identity maps each back to its cohort position
                    seq_of = {id(a): s for a, s in
                              zip(arrivals, arrival_seqs)}  # fedlint: disable=python-loop-over-fleet
                    fl_kw = {}
                    if crash_on:
                        fl_kw = dict(
                            live_pos=np.asarray([sq for sq, *_ in live],
                                                np.int64),
                            crashes=crashes, extra_downlinks=xdl,
                            retry_seconds=extra, gone=gone)
                    fl_frame = flight.sync_frame(
                        rd, t, np.asarray(ids, np.int64), arr_by_pos,
                        np.asarray([seq_of[id(a)] for a in survivors],
                                   np.int64),
                        np.asarray([seq_of[id(a)] for a in cut], np.int64),
                        topology=self.topology, down_edges=down, **fl_kw)
                survivors = place(survivors)
                if fl_frame is not None and self._attribute_shards:
                    flight.assign_shards(fl_frame, survivors)
                metrics = execute(rd, survivors, [1.0] * len(survivors)) \
                    if survivors else {}
            span_extra = {} if edges is None else {"edges": edges}
            obs.virtual_span("scheduler.round", t, t_end, round=rd,
                             participants=len(survivors),
                             dropped=len(dropouts) + len(gone_ids) + len(cut),
                             **span_extra)
            if cut:
                obs.event("policy.cut", cat="scheduler", lane="virtual",
                          t=t_end, round=rd,
                          policy=getattr(self.policy, "name", "?"),
                          cut=[a.client for a in cut])
            if faults:
                obs.event("fault.round", cat="faults", lane="virtual",
                          t=t_end, round=rd, **faults)
            trace.append(RoundRecord(
                round=rd, t_start=t, t_end=t_end,
                participants=tuple(a.client for a in survivors),
                dropped=tuple(dropouts) + tuple(gone_ids)
                + tuple(a.client for a in cut),
                # every completed upload crossed a wire, aggregated or not;
                # under a topology this is both tiers' traffic
                uplink_bytes=uplink_total,
                downlink_bytes=(len(ids) + retry_dl) * downlink_bytes,
                staleness=(0,) * len(survivors),
                shards=tuple(a.shard for a in survivors),
                metrics=metrics,
                ledger=self._ledger(wire_kinds, uplink_total,
                                    len(ids) * downlink_bytes, tier_bytes,
                                    retry_dl * downlink_bytes),
                faults=faults))
            if fl_frame is not None:
                trace.flights.append(fl_frame)
            t = t_end
            if on_round is not None:
                on_round(rd, {"round": rd + 1, "t": t,
                              "rng": rng.bit_generator.state})
        trace.cursor = {"round": rounds, "t": t,
                        "rng": rng.bit_generator.state}
        return trace

    # ---- synchronous policies: vectorized fleet-scale backend -------------
    def _run_sync_vector(self, rounds, sample_cohort, uplink_bytes,
                         downlink_bytes, execute, place,
                         wire_kinds=None, inj=None, cursor=None,
                         on_round=None) -> Trace:
        """Whole-cohort array core; Python only at round boundaries.

        Per round: one vectorized dropout draw over the cohort (same RNG
        stream as the reference's per-member scalar draws), one gathered
        round-trip computation over the live members, one stable argsort
        (reproducing heap pop order: ties break on cohort seq in both),
        and an O(1) policy prefix cut. `Arrival` objects materialize for
        survivors only — the executor/trace API stays object-based while
        the 10^4..10^6-element math never touches Python.
        """
        fleet = ClientFleet.from_any(self.fleet)
        rng = np.random.default_rng(self.seed)
        trace = Trace()
        t = 0.0
        start = 0
        if cursor is not None:
            start = int(cursor["round"])
            t = float(cursor["t"])
            rng.bit_generator.state = cursor["rng"]
        crash_on = inj is not None and inj.plan.crash_rate > 0
        rec_fl = flight.flights_enabled()
        for rd in range(start, rounds):
            if inj is not None and inj.server_killed(rd):
                raise ServerKilled(rd)
            faults: Dict[str, int] = {}
            with obs.span("scheduler.round", cat="scheduler", round=rd):
                ids = np.asarray([int(c) for c in sample_cohort(rd)],
                                 dtype=np.int64)
                draws = rng.random(ids.shape[0])
                alive = draws >= fleet.dropout_prob[ids]
                dropouts = ids[~alive]
                live = ids[alive]
                dt = fleet.round_trip_seconds(live, uplink_bytes,
                                              downlink_bytes,
                                              self.client_step_seconds)
                gone_ids = np.empty(0, np.int64)
                retry_dl = 0
                if not crash_on:
                    t_arrivals = t + dt
                    arr_all = t_arrivals
                else:
                    crashes = inj.crash_attempts_sync(rd, live)
                    extra, gone = inj.retry_overhead(
                        crashes, fleet.downlink_compute_seconds(
                            live, downlink_bytes, self.client_step_seconds))
                    xdl = inj.extra_downlinks(crashes, gone)
                    retry_dl = int(xdl.sum())
                    gone_ids = live[gone]
                    arr_all = t + (extra + dt)
                    t_arrivals = arr_all[~gone]
                    live = live[~gone]
                    n_crashes = int(crashes.sum())
                    if n_crashes:
                        faults["crashes"] = n_crashes
                        faults["retries"] = retry_dl
                    if gone_ids.shape[0]:
                        faults["crash_dropped"] = int(gone_ids.shape[0])
                order = np.argsort(t_arrivals, kind="stable")
                t_sorted = t_arrivals[order]
                cid_sorted = live[order]
                keep, t_end = self.policy.split_vector(t_sorted, t)
                n_arrivals = int(t_sorted.shape[0])
                down = inj.down_edges(t) \
                    if inj is not None and self.topology is not None else ()
                t_end, uplink_total, tier_bytes, edges = \
                    self._sync_uplink_accounting(
                        n_arrivals, uplink_bytes, cid_sorted[:keep],
                        t_sorted[:keep], t_end, down)
                if down:
                    faults["edges_down"] = len(down)
                    rehomed = getattr(self.topology, "last_rehomed", 0)
                    if rehomed:
                        faults["rehomed"] = rehomed
                t_end += self.server_step_seconds
                fl_frame = None
                if rec_fl:
                    # scatter the already-computed arrival/fault columns
                    # back to cohort positions — pure array ops, O(cohort)
                    alive_pos = np.nonzero(alive)[0]
                    arr_by_pos = np.full(int(ids.shape[0]), np.nan)
                    if crash_on:
                        arr_by_pos[alive_pos] = np.where(gone, np.nan,
                                                         arr_all)
                        sorted_pos = alive_pos[~gone][order]
                        fl_kw = dict(live_pos=alive_pos, crashes=crashes,
                                     extra_downlinks=xdl,
                                     retry_seconds=extra, gone=gone)
                    else:
                        arr_by_pos[alive_pos] = arr_all
                        sorted_pos = alive_pos[order]
                        fl_kw = {}
                    fl_frame = flight.sync_frame(
                        rd, t, ids, arr_by_pos, sorted_pos[:keep],
                        sorted_pos[keep:], topology=self.topology,
                        down_edges=down, **fl_kw)
                survivors = [Arrival(c, rd, ta) for c, ta in
                             zip(cid_sorted[:keep].tolist(),
                                 t_sorted[:keep].tolist())]
                cut_clients = cid_sorted[keep:].tolist()
                survivors = place(survivors)
                if fl_frame is not None and self._attribute_shards:
                    flight.assign_shards(fl_frame, survivors)
                metrics = execute(rd, survivors, [1.0] * len(survivors)) \
                    if survivors else {}
            span_extra = {} if edges is None else {"edges": edges}
            obs.virtual_span("scheduler.round", t, t_end, round=rd,
                             participants=len(survivors),
                             dropped=int(dropouts.shape[0])
                             + int(gone_ids.shape[0]) + len(cut_clients),
                             **span_extra)
            if cut_clients:
                obs.event("policy.cut", cat="scheduler", lane="virtual",
                          t=t_end, round=rd,
                          policy=getattr(self.policy, "name", "?"),
                          cut=cut_clients)
            if faults:
                obs.event("fault.round", cat="faults", lane="virtual",
                          t=t_end, round=rd, **faults)
            trace.append(RoundRecord(
                round=rd, t_start=t, t_end=t_end,
                participants=tuple(a.client for a in survivors),
                dropped=tuple(dropouts.tolist()) + tuple(gone_ids.tolist())
                + tuple(cut_clients),
                uplink_bytes=uplink_total,
                downlink_bytes=(int(ids.shape[0]) + retry_dl)
                * downlink_bytes,
                staleness=(0,) * len(survivors),
                shards=tuple(a.shard for a in survivors),
                metrics=metrics,
                ledger=self._ledger(wire_kinds, uplink_total,
                                    int(ids.shape[0]) * downlink_bytes,
                                    tier_bytes, retry_dl * downlink_bytes),
                faults=faults))
            if fl_frame is not None:
                trace.flights.append(fl_frame)
            t = t_end
            if on_round is not None:
                on_round(rd, {"round": rd + 1, "t": t,
                              "rng": rng.bit_generator.state})
        trace.cursor = {"round": rounds, "t": t,
                        "rng": rng.bit_generator.state}
        return trace

    # ---- async buffer: reference heapq backend ----------------------------
    def _run_async(self, rounds, sample_cohort, uplink_bytes, downlink_bytes,
                   execute, place, wire_kinds=None, inj=None) -> Trace:
        """FedBuff loop: the initial cohort sets the concurrency; every
        completed (or dropped) slot is refilled with the next client from a
        fresh-cohort stream, so the whole population keeps rotating through
        the in-flight set just as sync rounds resample each round."""
        policy: AsyncBuffer = self.policy
        rng = np.random.default_rng(self.seed)
        trace = Trace()
        # async edges relay each contribution (no pre-combination: staleness
        # weights are per contribution, known only at server flush)
        relay_hop = 0.0 if self.topology is None else \
            self.topology.relay_hop_seconds(uplink_bytes)
        # heap entries: (t_arrival, seq, client, version, was_dropped)
        heap: List[Tuple[float, int, int, int, bool]] = []
        seq = 0
        version = 0
        wave = 0
        queue: List[int] = []
        # per-flush-window fault counters (accounted at dispatch time, the
        # point both backends share; crash keys on the dispatch stream seq)
        fw = {"crashes": 0, "crash_dropped": 0, "retries": 0, "jittered": 0}
        rec_fl = flight.flights_enabled()
        # per-seq flight columns (dispatch order == stream order, matching
        # the vector backend's s_* arrays element by element)
        fl_cid: List[int] = []
        fl_t0: List[float] = []
        fl_drop: List[bool] = []
        fl_crash: List[int] = []
        fl_rdl: List[int] = []
        fl_rs: List[float] = []
        fl_gone: List[bool] = []
        win_done: List[Tuple[int, float]] = []  # (seq, t_pop) this window

        def next_client() -> int:
            nonlocal wave
            if not queue:
                queue.extend(int(c) for c in sample_cohort(wave))
                wave += 1
            return queue.pop(0)

        def dispatch(cid: int, t: float, ver: int):
            nonlocal seq
            p = self.fleet[cid]
            dropped = bool(rng.random() < p.dropout_prob)
            raw_drop = dropped          # pre-override benign dropout draw
            n_crash = n_rdl = 0
            r_s = 0.0
            is_gone = False
            dt = self._round_trip(p, uplink_bytes, downlink_bytes) + relay_hop
            if inj is not None:
                # scalar path == vectorized helpers on singleton arrays
                s_arr = np.asarray([seq], np.int64)
                c_arr = np.asarray([cid], np.int64)
                crashes = inj.crash_attempts_async(s_arr, c_arr)
                extra, gone = inj.retry_overhead(
                    crashes, np.asarray([p.downlink_seconds(downlink_bytes)
                                         + p.compute_seconds(
                                             self.client_step_seconds)]))
                jitter = inj.reorder_jitter(c_arr, s_arr)
                dt = (dt + float(extra[0])) + float(jitter[0])
                n_crash = int(crashes[0])
                n_rdl = int(inj.extra_downlinks(crashes, gone)[0])
                r_s = float(extra[0])
                is_gone = bool(gone[0])
                fw["crashes"] += n_crash
                fw["retries"] += n_rdl
                if jitter[0] > 0:
                    fw["jittered"] += 1
                if is_gone:
                    fw["crash_dropped"] += 1
                    dropped = True   # retry budget exhausted: lost slot
            if rec_fl:
                fl_cid.append(cid)
                fl_t0.append(t)
                fl_drop.append(raw_drop)
                fl_crash.append(n_crash)
                fl_rdl.append(n_rdl)
                fl_rs.append(r_s)
                fl_gone.append(is_gone)
            heapq.heappush(heap, (t + dt, seq, cid, ver, dropped))
            seq += 1

        for cid in sample_cohort(wave):
            dispatch(int(cid), 0.0, version)
        wave += 1

        buffer: List[Arrival] = []
        dropped_accum: List[int] = []
        dispatches = len(heap)   # downlink pushes since last flush
        t_round_start = 0.0
        updates = 0
        # termination guard: a fleet that only ever drops out would otherwise
        # spin the virtual clock forever without filling the buffer
        consecutive_drops = 0
        max_consecutive_drops = max(1000, 10 * len(self.fleet))
        while updates < rounds and heap:
            t_arr, sq, cid, ver, was_dropped = heapq.heappop(heap)
            if was_dropped:
                if rec_fl:
                    win_done.append((sq, t_arr))
                dropped_accum.append(cid)
                dispatch(next_client(), t_arr, version)
                dispatches += 1
                consecutive_drops += 1
                if consecutive_drops >= max_consecutive_drops:
                    logger.warning(
                        "async scheduler: %d consecutive dropouts with no "
                        "progress after %d updates; stopping early",
                        consecutive_drops, updates)
                    break
                continue
            consecutive_drops = 0
            buffer.append(Arrival(cid, ver, t_arr))
            if rec_fl:
                win_done.append((sq, t_arr))
            if len(buffer) >= policy.buffer_size:
                if inj is not None and inj.server_killed(updates):
                    raise ServerKilled(updates)
                t_end = t_arr + self.server_step_seconds
                # place BEFORE computing weights so staleness stays aligned
                # with the (possibly reordered) cohort execute receives
                buffer = place(buffer)
                staleness = [version - a.version for a in buffer]
                weights = [policy.staleness_weight(s) for s in staleness]
                with obs.span("scheduler.flush", cat="scheduler",
                              update=updates, buffered=len(buffer)):
                    metrics = execute(updates, buffer, weights)
                obs.virtual_span("scheduler.flush", t_round_start, t_end,
                                 update=updates, buffered=len(buffer),
                                 staleness_max=max(staleness))
                fl_frame = None
                if rec_fl:
                    # frame over the flights that TERMINATED this window
                    # (fault counters accrue at dispatch time instead, so
                    # async ledger<->flight reconciliation is approximate;
                    # sync rounds reconcile exactly — see repro.obs.flight)
                    fl_frame = flight.async_frame(
                        updates, win_done, fl_cid, fl_t0, fl_drop,
                        fl_crash, fl_rdl, fl_rs, fl_gone,
                        topology=self.topology)
                    if self._attribute_shards:
                        flight.assign_shards(fl_frame, buffer)
                version += 1
                dispatch(next_client(), t_arr, version)  # slot sees new model
                dispatches += 1
                flat_total = len(buffer) * uplink_bytes
                tier_bytes = None if self.topology is None else \
                    (flat_total, flat_total)   # relayed 1:1, no combine
                uplink_total = flat_total if tier_bytes is None else \
                    tier_bytes[0] + tier_bytes[1]
                retry_dl = fw["retries"]
                faults = {k: v for k, v in fw.items() if v}
                if faults:
                    obs.event("fault.flush", cat="faults", lane="virtual",
                              t=t_end, round=updates, **faults)
                trace.append(RoundRecord(
                    round=updates, t_start=t_round_start, t_end=t_end,
                    participants=tuple(a.client for a in buffer),
                    dropped=tuple(dropped_accum),
                    uplink_bytes=uplink_total,
                    downlink_bytes=(dispatches + retry_dl) * downlink_bytes,
                    staleness=tuple(staleness),
                    shards=tuple(a.shard for a in buffer),
                    metrics=metrics,
                    ledger=self._ledger(wire_kinds, uplink_total,
                                        dispatches * downlink_bytes,
                                        tier_bytes,
                                        retry_bytes=retry_dl * downlink_bytes),
                    faults=faults))
                if fl_frame is not None:
                    trace.flights.append(fl_frame)
                buffer, dropped_accum, dispatches = [], [], 0
                win_done = []
                fw = {k: 0 for k in fw}
                t_round_start = t_end
                updates += 1
            else:
                dispatch(next_client(), t_arr, version)
                dispatches += 1
        return trace

    # ---- async buffer: vectorized fleet-scale backend ---------------------
    def _run_async_vector(self, rounds, sample_cohort, uplink_bytes,
                          downlink_bytes, execute, place,
                          wire_kinds=None, inj=None) -> Trace:
        """Lean-heap FedBuff core over a vectorized dispatch stream.

        Asynchrony is inherently sequential — each completion triggers a
        refill dispatch whose time depends on completion order — so a
        heap survives; but its entries shrink to ``(time, seq)`` scalar
        tuples and ALL per-dispatch work is precomputed in waves:
        dispatch order consumes the cohort stream FIFO, so seq == stream
        index == RNG draw order, and each wave's dropout draws and round
        trips are single array ops. Staleness at flush is vectorized
        against the per-seq version array. Fault draws hash on the stream
        seq (never the RNG), so each wave's crash/retry/jitter columns
        are one vectorized injector call, bitwise-matching the heapq
        backend's singleton-array calls element by element.
        """
        policy: AsyncBuffer = self.policy
        fleet = ClientFleet.from_any(self.fleet)
        rng = np.random.default_rng(self.seed)
        trace = Trace()
        relay_hop = 0.0 if self.topology is None else \
            self.topology.relay_hop_seconds(uplink_bytes)

        # dispatch stream, extended one vectorized wave at a time
        s_cid = np.empty(0, np.int64)     # stream idx -> client id
        s_drop = np.empty(0, bool)        # stream idx -> dropout draw
        s_dt = np.empty(0, np.float64)    # stream idx -> round-trip time
        s_ver: List[int] = []             # stream idx -> model version seen
        # fault columns (only populated when inj is active)
        s_gone = np.empty(0, bool)        # retry budget exhausted -> lost
        s_crash = np.empty(0, np.int64)   # crashed attempts before success
        s_retry = np.empty(0, np.int64)   # extra downlink dispatches
        s_jit = np.empty(0, bool)         # reorder jitter applied
        wave = 0
        consumed = 0                      # next unused stream index
        fw = {"crashes": 0, "crash_dropped": 0, "retries": 0, "jittered": 0}
        rec_fl = flight.flights_enabled()
        s_t0: List[float] = []            # stream idx -> dispatch time
        s_extra = np.empty(0, np.float64)  # stream idx -> retry seconds
        win_done: List[Tuple[int, float]] = []  # (seq, t_pop) this window

        def extend_stream():
            nonlocal s_cid, s_drop, s_dt, wave
            nonlocal s_gone, s_crash, s_retry, s_jit, s_extra
            ids = np.asarray([int(c) for c in sample_cohort(wave)],
                             dtype=np.int64)
            wave += 1
            draws = rng.random(ids.shape[0])
            dts = fleet.round_trip_seconds(ids, uplink_bytes, downlink_bytes,
                                           self.client_step_seconds) \
                + relay_hop
            if inj is not None and ids.shape[0]:
                base = s_cid.shape[0]
                seqs = np.arange(base, base + ids.shape[0], dtype=np.int64)
                crashes = inj.crash_attempts_async(seqs, ids)
                extra, gone = inj.retry_overhead(
                    crashes, fleet.downlink_compute_seconds(
                        ids, downlink_bytes, self.client_step_seconds))
                jitter = inj.reorder_jitter(ids, seqs)
                dts = (dts + extra) + jitter
                s_gone = np.concatenate([s_gone, gone])
                s_crash = np.concatenate([s_crash, crashes])
                s_retry = np.concatenate(
                    [s_retry, inj.extra_downlinks(crashes, gone)])
                s_jit = np.concatenate([s_jit, jitter > 0])
                if rec_fl:
                    s_extra = np.concatenate([s_extra, extra])
            s_cid = np.concatenate([s_cid, ids])
            s_drop = np.concatenate([s_drop, draws < fleet.dropout_prob[ids]])
            s_dt = np.concatenate([s_dt, dts])
            return int(ids.shape[0])

        heap: List[Tuple[float, int]] = []   # (t_arrival, seq)

        def dispatch(t: float, ver: int):
            """Launch the next stream client at virtual time ``t``."""
            nonlocal consumed
            while consumed >= s_cid.shape[0]:
                if extend_stream() == 0:
                    raise ValueError("sample_cohort returned an empty cohort "
                                     "while async slots need refilling")
            s = consumed
            consumed += 1
            s_ver.append(ver)
            if rec_fl:
                s_t0.append(t)
            if inj is not None:
                # counters accrue at consume time — the point the heapq
                # backend draws the same hashes on singleton arrays
                fw["crashes"] += int(s_crash[s])
                fw["retries"] += int(s_retry[s])
                if s_jit[s]:
                    fw["jittered"] += 1
                if s_gone[s]:
                    fw["crash_dropped"] += 1
            heapq.heappush(heap, (t + float(s_dt[s]), s))

        first_wave = extend_stream()
        for _ in range(first_wave):
            dispatch(0.0, 0)

        version = 0
        buffer: List[Tuple[float, int]] = []   # (t_arrival, stream idx)
        dropped_accum: List[int] = []
        dispatches = len(heap)
        t_round_start = 0.0
        updates = 0
        consecutive_drops = 0
        max_consecutive_drops = max(1000, 10 * len(fleet))
        while updates < rounds and heap:
            t_arr, s = heapq.heappop(heap)
            if s_drop[s] or (inj is not None and s_gone[s]):
                if rec_fl:
                    win_done.append((s, t_arr))
                dropped_accum.append(int(s_cid[s]))
                dispatch(t_arr, version)
                dispatches += 1
                consecutive_drops += 1
                if consecutive_drops >= max_consecutive_drops:
                    logger.warning(
                        "async scheduler: %d consecutive dropouts with no "
                        "progress after %d updates; stopping early",
                        consecutive_drops, updates)
                    break
                continue
            consecutive_drops = 0
            buffer.append((t_arr, s))
            if rec_fl:
                win_done.append((s, t_arr))
            if len(buffer) >= policy.buffer_size:
                if inj is not None and inj.server_killed(updates):
                    raise ServerKilled(updates)
                t_end = t_arr + self.server_step_seconds
                cohort = [Arrival(int(s_cid[i]), s_ver[i], ta)
                          for ta, i in buffer]
                cohort = place(cohort)
                stal = version - np.asarray([a.version for a in cohort])
                staleness = [int(x) for x in stal]
                weights = [policy.staleness_weight(x) for x in staleness]
                with obs.span("scheduler.flush", cat="scheduler",
                              update=updates, buffered=len(cohort)):
                    metrics = execute(updates, cohort, weights)
                obs.virtual_span("scheduler.flush", t_round_start, t_end,
                                 update=updates, buffered=len(cohort),
                                 staleness_max=max(staleness))
                fl_frame = None
                if rec_fl:
                    armed = inj is not None
                    fl_frame = flight.async_frame(
                        updates, win_done, s_cid, s_t0, s_drop,
                        s_crash if armed else None,
                        s_retry if armed else None,
                        s_extra if armed else None,
                        s_gone if armed else None,
                        topology=self.topology)
                    if self._attribute_shards:
                        flight.assign_shards(fl_frame, cohort)
                version += 1
                dispatch(t_arr, version)   # refilled slot sees new model
                dispatches += 1
                flat_total = len(cohort) * uplink_bytes
                tier_bytes = None if self.topology is None else \
                    (flat_total, flat_total)
                uplink_total = flat_total if tier_bytes is None else \
                    tier_bytes[0] + tier_bytes[1]
                retry_dl = fw["retries"]
                faults = {k: v for k, v in fw.items() if v}
                if faults:
                    obs.event("fault.flush", cat="faults", lane="virtual",
                              t=t_end, round=updates, **faults)
                trace.append(RoundRecord(
                    round=updates, t_start=t_round_start, t_end=t_end,
                    participants=tuple(a.client for a in cohort),
                    dropped=tuple(dropped_accum),
                    uplink_bytes=uplink_total,
                    downlink_bytes=(dispatches + retry_dl) * downlink_bytes,
                    staleness=tuple(staleness),
                    shards=tuple(a.shard for a in cohort),
                    metrics=metrics,
                    ledger=self._ledger(wire_kinds, uplink_total,
                                        dispatches * downlink_bytes,
                                        tier_bytes,
                                        retry_bytes=retry_dl * downlink_bytes),
                    faults=faults))
                if fl_frame is not None:
                    trace.flights.append(fl_frame)
                buffer, dropped_accum, dispatches = [], [], 0
                win_done = []
                fw = {k: 0 for k in fw}
                t_round_start = t_end
                updates += 1
            else:
                dispatch(t_arr, version)
                dispatches += 1
        return trace


def ideal_scheduler(num_clients: int, *, seed: int = 0) -> Scheduler:
    """The pre-subsystem simulation: identical infinitely-fast clients,
    no dropout, full synchronization — bitwise-preserves the original
    `FederatedTrainer` trajectory (tests/test_scheduler.py)."""
    from repro.federated.network import uniform_fleet
    return Scheduler(fleet=uniform_fleet(num_clients), policy=FullSync(),
                     client_step_seconds=1.0, seed=seed)
