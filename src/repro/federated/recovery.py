"""Crash-consistent runtime snapshots + the self-healing run driver.

`federated/faults.py` can kill the server between rounds (`ServerKilled`);
this module is what survives it. A **snapshot** captures everything a
`FederatedTrainer.run` needs to continue bitwise-identically:

  * the `TrainState` (params, optimizer state, step counter);
  * the cross-round cut-layer state — per-client / cohort-global
    `QuantizerState` warm-start lineages, the seed codebook, and every
    client's error-feedback memory;
  * the trainer's cohort-sampling RNG (`numpy` bit-generator state);
  * the scheduler cursor ({round, virtual clock, scheduler RNG state});
  * the trace records and history rows of every completed round.

Snapshots ride the `checkpointing/checkpoint.py` atomic-write + manifest
machinery (tmp + rename, sha256-verified restore), with the non-array
state in the manifest-covered meta json, so a kill mid-save can never
leave a restorable-but-corrupt snapshot.

`run_with_recovery` drives training in ``checkpoint_every``-round
segments, snapshotting after each, and reacts to a `ServerKilled` the way
a restarted process would: restore the latest snapshot FROM DISK (the
in-memory trainer is treated as lost), disarm the kill that already
fired (a restarted server does not re-die at the same round), and resume
from the cursor. Final params and trace are bitwise-identical to an
uninterrupted run (tests/test_faults.py pins this).
"""

from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.checkpointing.checkpoint import (restore_checkpoint,
                                            save_checkpoint)
from repro.core.quantizer import QuantizerState
from repro.federated.faults import ServerKilled
from repro.federated.trace import RoundRecord, Trace
from repro.obs import flight as flightlib

__all__ = ["snapshot_runtime", "restore_runtime", "run_with_recovery"]


def _q_tree(q: Optional[QuantizerState]) -> Optional[Dict[str, Any]]:
    return None if q is None else dict(q._asdict())


def _q_from(tree: Optional[Dict[str, Any]]) -> Optional[QuantizerState]:
    return None if tree is None else QuantizerState(**tree)


def snapshot_runtime(trainer, state, cursor: Dict[str, Any],
                     trace: Trace, history: List[Dict[str, Any]],
                     ckpt_dir: str) -> str:
    """Write one atomic, manifest-verified snapshot at ``cursor['round']``.

    Array state goes into the npz (TrainState leaves by flatten order +
    the cut-layer dicts by client id); everything host-side — both RNG
    states, the cursor, completed trace records, history — goes into the
    manifest-covered meta json.
    """
    step = int(cursor["round"])
    tree: Dict[str, Any] = {
        "train": {f"{i:04d}": leaf
                  for i, leaf in enumerate(jax.tree.leaves(state))},
        "client_q": {str(c): _q_tree(q)
                     for c, q in trainer._client_q.items()},
        "ef": {str(c): m for c, m in trainer._ef_memory.items()},
    }
    if trainer._global_q is not None:
        tree["global_q"] = _q_tree(trainer._global_q)
    if trainer._seed_q is not None:
        tree["seed_q"] = _q_tree(trainer._seed_q)
    meta = {
        "cursor": cursor,
        "trainer_rng": trainer._rng.bit_generator.state,
        "global_q_nparts": trainer._global_q_nparts,
        "records": [dataclasses.asdict(r) for r in trace.records],
        "trace_meta": dict(trace.meta),
        "history": history,
        # flight-recorder frames ride the manifest too: a resumed run's
        # exemplar lifecycles cover the WHOLE run, not just the tail
        "flights": [f.to_json() for f in getattr(trace, "flights", [])],
    }
    with obs.span("recovery.snapshot", cat="io", round=step):
        return save_checkpoint(ckpt_dir, step, tree, extra=meta)


def _load_meta(ckpt_dir: str, step: int) -> Dict[str, Any]:
    with open(os.path.join(ckpt_dir, f"meta_{step:08d}.json")) as f:
        return json.load(f)


def restore_runtime(trainer, template_state, ckpt_dir: str,
                    step: Optional[int] = None,
                    ) -> Tuple[Any, Dict[str, Any], Trace,
                               List[Dict[str, Any]]]:
    """Rebuild ``(state, cursor, trace, history)`` from the latest (or
    given) snapshot and reinstall the cut-layer + RNG state on ``trainer``.

    ``template_state`` supplies the TrainState treedef — snapshots store
    leaves in flatten order, which is deterministic for a fixed trainer
    construction, exactly what a restarted process rebuilds."""
    from repro.checkpointing.checkpoint import latest_step
    step = step if step is not None else latest_step(ckpt_dir)
    tree = restore_checkpoint(ckpt_dir, step)
    meta = _load_meta(ckpt_dir, step)
    leaves = [tree["train"][k] for k in sorted(tree["train"])]
    state = jax.tree.unflatten(jax.tree.structure(template_state), leaves)
    trainer._client_q = {int(c): _q_from(q)
                         for c, q in tree.get("client_q", {}).items()}
    trainer._ef_memory = {int(c): m for c, m in tree.get("ef", {}).items()}
    trainer._global_q = _q_from(tree.get("global_q"))
    trainer._seed_q = _q_from(tree.get("seed_q"))
    trainer._global_q_nparts = int(meta["global_q_nparts"])
    trainer._rng = np.random.default_rng()
    trainer._rng.bit_generator.state = meta["trainer_rng"]
    trace = Trace(records=[RoundRecord(**r) for r in meta["records"]],
                  meta=dict(meta["trace_meta"]))
    trace.flights = [flightlib.FlightFrame.from_json(d)
                     for d in meta.get("flights", [])]
    for r in trace.records:   # json round-trips tuples as lists
        r.participants = tuple(r.participants)
        r.dropped = tuple(r.dropped)
        r.staleness = tuple(r.staleness)
        r.shards = tuple(r.shards)
    return state, meta["cursor"], trace, list(meta["history"])


def run_with_recovery(trainer, steps: int, key, ckpt_dir: str, *,
                      checkpoint_every: int = 5, log_every: int = 0,
                      max_restarts: int = 8):
    """Run ``steps`` rounds with periodic snapshots and kill recovery.

    Returns ``(state, history)`` like `FederatedTrainer.run`, with the
    merged whole-run `Trace` in ``trainer.last_trace``. Only synchronous
    policies are supported (the cursor contract). ``max_restarts`` bounds
    pathological plans that kill faster than a segment completes.
    """
    if checkpoint_every <= 0:
        raise ValueError("checkpoint_every must be positive")
    plan = trainer.fault_plan
    state = trainer.init_state(key)
    template = state
    cursor: Optional[Dict[str, Any]] = None
    trace = Trace()
    history: List[Dict[str, Any]] = []
    restarts = 0
    done = 0
    while done < steps:
        end = min(done + checkpoint_every, steps)
        try:
            state, seg_hist = trainer.run(end, key, log_every=log_every,
                                          state=state, cursor=cursor)
        except ServerKilled as e:
            restarts += 1
            if restarts > max_restarts:
                raise
            obs.event("fault.server_restart", cat="faults",
                      round=e.round_index, restarts=restarts)
            # a restarted process recovers from DISK, not from the dirty
            # in-memory trainer — and the fired kill stays fired
            trainer.fault_plan = trainer.fault_plan.disarm_kills_through(
                e.round_index)
            if done == 0:
                # killed before the first snapshot: cold restart
                state = trainer.init_state(key)
                trainer._client_q = {}
                trainer._ef_memory = {}
                trainer._global_q = None
                trainer._seed_q = None
                trainer._global_q_nparts = 0
                trainer._rng = np.random.default_rng(trainer.seed)
                cursor = None
                trace = Trace()
                history = []
            else:
                state, cursor, trace, history = restore_runtime(
                    trainer, template, ckpt_dir)
                done = int(cursor["round"])
            continue
        seg_trace = trainer.last_trace
        trace.records.extend(seg_trace.records)
        trace.flights.extend(getattr(seg_trace, "flights", []))
        trace.meta.update(seg_trace.meta)
        trace.cursor = seg_trace.cursor
        history.extend(seg_hist)
        cursor = seg_trace.cursor
        done = end
        snapshot_runtime(trainer, state, cursor, trace, history, ckpt_dir)
    trainer.fault_plan = plan
    trainer.last_trace = trace
    return state, history
