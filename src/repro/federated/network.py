"""Heterogeneous client/network models for the federated simulator.

A ``ClientProfile`` describes one device-under-simulation: asymmetric
uplink/downlink bandwidth, one-way latency, a compute-speed multiplier
(relative to the reference client the paper times), and a per-round
dropout probability. Fleet samplers build realistic populations:

  * ``uniform_fleet``   — every client identical (``IDEAL`` reproduces the
                          pre-subsystem simulation: infinite bandwidth,
                          zero latency, no dropout).
  * ``lognormal_fleet`` — lognormal bandwidth + compute spread, the
                          standard empirical model for last-mile links
                          (heavy right tail of slow clients = stragglers).
  * ``mobile_fleet``    — a wired/mobile mixture: a fraction of flaky
                          mobile clients with low bandwidth, high latency
                          and nonzero dropout, the Caldas-style
                          resource-constrained population FedLite targets.

All times are in (virtual) seconds, bandwidth in bits/second. Transfer
cost is the affine model ``latency + bits/bandwidth``; infinite bandwidth
and zero latency make any transfer free, so the ideal profile adds
exactly nothing to the virtual clock.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    """Static resource description of one simulated client."""
    uplink_bps: float = math.inf      # client -> server bandwidth (bits/s)
    downlink_bps: float = math.inf    # server -> client bandwidth (bits/s)
    latency_s: float = 0.0            # one-way link latency (seconds)
    compute_multiplier: float = 1.0   # local step time multiplier (1 = reference)
    dropout_prob: float = 0.0         # P(client vanishes mid-round)

    def __post_init__(self):
        if self.uplink_bps <= 0 or self.downlink_bps <= 0:
            raise ValueError("bandwidth must be positive (use math.inf for ideal)")
        if not 0.0 <= self.dropout_prob <= 1.0:
            raise ValueError(f"dropout_prob={self.dropout_prob} not in [0, 1]")
        if self.compute_multiplier < 0:
            raise ValueError("compute_multiplier must be >= 0")

    def uplink_seconds(self, nbytes: float) -> float:
        return transfer_seconds(nbytes, self.uplink_bps, self.latency_s)

    def downlink_seconds(self, nbytes: float) -> float:
        return transfer_seconds(nbytes, self.downlink_bps, self.latency_s)

    def compute_seconds(self, base_step_seconds: float) -> float:
        return base_step_seconds * self.compute_multiplier


IDEAL = ClientProfile()


def transfer_seconds(nbytes: float, bps: float, latency_s: float = 0.0) -> float:
    """Affine transfer-time model; free when bandwidth is infinite."""
    if nbytes <= 0:
        return 0.0
    serialization = 0.0 if math.isinf(bps) else nbytes * 8.0 / bps
    return latency_s + serialization


# ---------------------------------------------------------------------------
# fleet samplers
# ---------------------------------------------------------------------------

def uniform_fleet(num_clients: int,
                  profile: ClientProfile = IDEAL) -> List[ClientProfile]:
    """Every client identical; the IDEAL default is the pre-subsystem sim."""
    return [profile] * num_clients


def lognormal_fleet(num_clients: int, *,
                    median_uplink_bps: float = 5e6,
                    median_downlink_bps: float = 20e6,
                    bandwidth_sigma: float = 1.0,
                    latency_s: float = 0.05,
                    compute_sigma: float = 0.4,
                    dropout_prob: float = 0.0,
                    seed: int = 0) -> List[ClientProfile]:
    """Lognormal bandwidth + compute spread around the given medians.

    ``bandwidth_sigma`` is the log-scale std; sigma=1 gives roughly a 7x
    spread between the 10th and 90th percentile client — a realistic
    residential-broadband distribution with a heavy straggler tail.
    """
    rng = np.random.default_rng(seed)
    up = median_uplink_bps * np.exp(rng.normal(0, bandwidth_sigma, num_clients))
    down = median_downlink_bps * np.exp(rng.normal(0, bandwidth_sigma, num_clients))
    comp = np.exp(rng.normal(0, compute_sigma, num_clients))
    return [ClientProfile(uplink_bps=float(u), downlink_bps=float(d),
                          latency_s=latency_s,
                          compute_multiplier=float(c),
                          dropout_prob=dropout_prob)
            for u, d, c in zip(up, down, comp)]


def mobile_fleet(num_clients: int, *,
                 flaky_fraction: float = 0.3,
                 wired_uplink_bps: float = 20e6,
                 wired_downlink_bps: float = 100e6,
                 mobile_uplink_bps: float = 1e6,
                 mobile_downlink_bps: float = 5e6,
                 mobile_latency_s: float = 0.15,
                 mobile_dropout_prob: float = 0.2,
                 mobile_compute_multiplier: float = 3.0,
                 seed: int = 0) -> List[ClientProfile]:
    """Wired/mobile mixture: ``flaky_fraction`` of the fleet is slow mobile
    hardware on a lossy link (Caldas et al.'s resource-constrained cohort)."""
    rng = np.random.default_rng(seed)
    is_mobile = rng.random(num_clients) < flaky_fraction
    fleet = []
    for m in is_mobile:
        if m:
            fleet.append(ClientProfile(
                uplink_bps=mobile_uplink_bps,
                downlink_bps=mobile_downlink_bps,
                latency_s=mobile_latency_s,
                compute_multiplier=mobile_compute_multiplier,
                dropout_prob=mobile_dropout_prob))
        else:
            fleet.append(ClientProfile(
                uplink_bps=wired_uplink_bps,
                downlink_bps=wired_downlink_bps,
                latency_s=0.02))
    return fleet


def validate_fleet(fleet: Sequence[ClientProfile], num_clients: int) -> None:
    if len(fleet) != num_clients:
        raise ValueError(
            f"fleet has {len(fleet)} profiles for {num_clients} clients")
