"""Heterogeneous client/network models for the federated simulator.

A ``ClientProfile`` describes one device-under-simulation: asymmetric
uplink/downlink bandwidth, one-way latency, a compute-speed multiplier
(relative to the reference client the paper times), and a per-round
dropout probability. At fleet scale the per-client object is the wrong
representation — a million-profile Python list is hundreds of MB of
boxed floats that every scheduler round re-unboxes — so populations are
held as a ``ClientFleet``: one struct-of-arrays with a float64 column
per field. The vectorized scheduler backend
(``federated/scheduler.py``) computes whole-cohort round trips and
dropout draws directly on the columns; ``fleet[i]`` still materializes
a `ClientProfile` on demand, so per-arrival call sites (the heapq
reference backend) run unchanged.

Fleet samplers build realistic populations (all return `ClientFleet`):

  * ``uniform_fleet``   — every client identical (``IDEAL`` reproduces the
                          pre-subsystem simulation: infinite bandwidth,
                          zero latency, no dropout).
  * ``lognormal_fleet`` — lognormal bandwidth + compute spread, the
                          standard empirical model for last-mile links
                          (heavy right tail of slow clients = stragglers).
  * ``mobile_fleet``    — a wired/mobile mixture: a fraction of flaky
                          mobile clients with low bandwidth, high latency
                          and nonzero dropout, the Caldas-style
                          resource-constrained population FedLite targets.

All times are in (virtual) seconds, bandwidth in bits/second. Transfer
cost is the affine model ``latency + bits/bandwidth``; infinite bandwidth
and zero latency make any transfer free, so the ideal profile adds
exactly nothing to the virtual clock. The array ops evaluate the exact
same IEEE-double expressions as the scalar `ClientProfile` methods, in
the same association order — the vectorized scheduler backend's bitwise
trace parity with the heapq reference rests on that (asserted in
tests/test_fleet_scale.py).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterator, Sequence, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientProfile:
    """Static resource description of one simulated client."""
    uplink_bps: float = math.inf      # client -> server bandwidth (bits/s)
    downlink_bps: float = math.inf    # server -> client bandwidth (bits/s)
    latency_s: float = 0.0            # one-way link latency (seconds)
    compute_multiplier: float = 1.0   # local step time multiplier (1 = reference)
    dropout_prob: float = 0.0         # P(client vanishes mid-round)

    def __post_init__(self):
        if self.uplink_bps <= 0 or self.downlink_bps <= 0:
            raise ValueError("bandwidth must be positive (use math.inf for ideal)")
        if not 0.0 <= self.dropout_prob <= 1.0:
            raise ValueError(f"dropout_prob={self.dropout_prob} not in [0, 1]")
        if self.compute_multiplier < 0:
            raise ValueError("compute_multiplier must be >= 0")

    def uplink_seconds(self, nbytes: float) -> float:
        return transfer_seconds(nbytes, self.uplink_bps, self.latency_s)

    def downlink_seconds(self, nbytes: float) -> float:
        return transfer_seconds(nbytes, self.downlink_bps, self.latency_s)

    def compute_seconds(self, base_step_seconds: float) -> float:
        return base_step_seconds * self.compute_multiplier


IDEAL = ClientProfile()


def transfer_seconds(nbytes: float, bps: float, latency_s: float = 0.0) -> float:
    """Affine transfer-time model; free when bandwidth is infinite."""
    if nbytes <= 0:
        return 0.0
    serialization = 0.0 if math.isinf(bps) else nbytes * 8.0 / bps
    return latency_s + serialization


# ---------------------------------------------------------------------------
# struct-of-arrays fleet
# ---------------------------------------------------------------------------

_FIELDS = ("uplink_bps", "downlink_bps", "latency_s", "compute_multiplier",
           "dropout_prob")


@dataclasses.dataclass(eq=False)
class ClientFleet:
    """A population of clients as one float64 column per profile field.

    This is the fleet representation the vectorized scheduler core runs
    on: ``round_trip_seconds`` computes a whole cohort's
    downlink + compute + uplink times as three gathers and two adds, and
    ``dropout_prob[ids]`` feeds a single vectorized Bernoulli draw per
    round. Validation happens once at construction over the whole
    population (the vectorized twin of ``ClientProfile.__post_init__``),
    not per object.

    The sequence protocol keeps every pre-array call site working:
    ``len(fleet)``, iteration, and ``fleet[i]`` (materializing one
    `ClientProfile` from row ``i`` — exactly the floats the columns
    hold, so the heapq reference backend computes bit-identical times).
    """
    uplink_bps: np.ndarray
    downlink_bps: np.ndarray
    latency_s: np.ndarray
    compute_multiplier: np.ndarray
    dropout_prob: np.ndarray

    def __post_init__(self):
        for f in _FIELDS:
            setattr(self, f, np.ascontiguousarray(getattr(self, f),
                                                  dtype=np.float64))
        n = self.uplink_bps.shape
        if any(getattr(self, f).shape != n for f in _FIELDS) or len(n) != 1:
            raise ValueError(
                "ClientFleet columns must be 1-D arrays of one shared "
                f"length; got {[getattr(self, f).shape for f in _FIELDS]}")
        # whole-population validation, one pass per rule
        if (self.uplink_bps <= 0).any() or (self.downlink_bps <= 0).any():
            raise ValueError("bandwidth must be positive (use math.inf for ideal)")
        if ((self.dropout_prob < 0) | (self.dropout_prob > 1)).any():
            raise ValueError("dropout_prob not in [0, 1] for some client")
        if (self.compute_multiplier < 0).any():
            raise ValueError("compute_multiplier must be >= 0")

    # ---- construction ------------------------------------------------------
    @classmethod
    def from_profiles(cls, profiles: Sequence[ClientProfile]) -> "ClientFleet":
        """Adapter for legacy profile lists (O(n) Python, once per run —
        never in the per-round path)."""
        return cls(*(np.asarray([getattr(p, f) for p in profiles],  # fedlint: disable=python-loop-over-fleet
                                dtype=np.float64) for f in _FIELDS))

    @classmethod
    def broadcast(cls, profile: ClientProfile, num_clients: int) -> "ClientFleet":
        """``num_clients`` identical rows of ``profile``."""
        return cls(*(np.full(num_clients, getattr(profile, f), np.float64)
                     for f in _FIELDS))

    @classmethod
    def from_any(cls, fleet: Union["ClientFleet", Sequence[ClientProfile]],
                 ) -> "ClientFleet":
        """Normalize either representation to arrays."""
        return fleet if isinstance(fleet, ClientFleet) \
            else cls.from_profiles(fleet)

    # ---- sequence protocol (ClientProfile adapter) -------------------------
    def __len__(self) -> int:
        return int(self.uplink_bps.shape[0])

    def __getitem__(self, i) -> Union[ClientProfile, "ClientFleet"]:
        if isinstance(i, (slice, np.ndarray, list)):
            return ClientFleet(*(getattr(self, f)[i] for f in _FIELDS))
        return ClientProfile(*(float(getattr(self, f)[i]) for f in _FIELDS))

    def __iter__(self) -> Iterator[ClientProfile]:
        return (self[i] for i in range(len(self)))

    # ---- vectorized time model ---------------------------------------------
    def _transfer_seconds(self, nbytes: float, bps: np.ndarray,
                          latency: np.ndarray) -> np.ndarray:
        """Array twin of `transfer_seconds`, same association order."""
        if nbytes <= 0:
            return np.zeros_like(bps)
        # x / inf == 0.0 exactly, so the infinite-bandwidth branch of the
        # scalar model falls out of the same expression
        return latency + nbytes * 8.0 / bps

    def uplink_seconds(self, nbytes: float, ids: np.ndarray) -> np.ndarray:
        return self._transfer_seconds(nbytes, self.uplink_bps[ids],
                                      self.latency_s[ids])

    def downlink_seconds(self, nbytes: float, ids: np.ndarray) -> np.ndarray:
        return self._transfer_seconds(nbytes, self.downlink_bps[ids],
                                      self.latency_s[ids])

    def compute_seconds(self, base_step_seconds: float,
                        ids: np.ndarray) -> np.ndarray:
        return base_step_seconds * self.compute_multiplier[ids]

    def downlink_compute_seconds(self, ids: np.ndarray, downlink_bytes: int,
                                 base_step_seconds: float) -> np.ndarray:
        """``downlink + compute`` seconds — the virtual time one crashed
        attempt wastes before the failure is noticed (the upload never
        happens). Associated like the scalar path so the fault injector's
        retry arithmetic is bitwise-identical across backends."""
        return self.downlink_seconds(downlink_bytes, ids) \
            + self.compute_seconds(base_step_seconds, ids)

    def round_trip_seconds(self, ids: np.ndarray, uplink_bytes: int,
                           downlink_bytes: int,
                           base_step_seconds: float) -> np.ndarray:
        """Whole-cohort ``downlink -> compute -> uplink`` times.

        Left-associated like the scalar path
        (``(downlink + compute) + uplink``) so the heapq backend's
        per-client sums reproduce bitwise.
        """
        return (self.downlink_seconds(downlink_bytes, ids)
                + self.compute_seconds(base_step_seconds, ids)) \
            + self.uplink_seconds(uplink_bytes, ids)


# ---------------------------------------------------------------------------
# fleet samplers (all vectorized: no per-client Python objects built)
# ---------------------------------------------------------------------------

def uniform_fleet(num_clients: int,
                  profile: ClientProfile = IDEAL) -> ClientFleet:
    """Every client identical; the IDEAL default is the pre-subsystem sim."""
    return ClientFleet.broadcast(profile, num_clients)


def lognormal_fleet(num_clients: int, *,
                    median_uplink_bps: float = 5e6,
                    median_downlink_bps: float = 20e6,
                    bandwidth_sigma: float = 1.0,
                    latency_s: float = 0.05,
                    compute_sigma: float = 0.4,
                    dropout_prob: float = 0.0,
                    seed: int = 0) -> ClientFleet:
    """Lognormal bandwidth + compute spread around the given medians.

    ``bandwidth_sigma`` is the log-scale std; sigma=1 gives roughly a 7x
    spread between the 10th and 90th percentile client — a realistic
    residential-broadband distribution with a heavy straggler tail. The
    RNG draw sequence is unchanged from the profile-list era, so seeded
    fleets (and every trace derived from them) stay reproducible.
    """
    rng = np.random.default_rng(seed)
    up = median_uplink_bps * np.exp(rng.normal(0, bandwidth_sigma, num_clients))
    down = median_downlink_bps * np.exp(rng.normal(0, bandwidth_sigma, num_clients))
    comp = np.exp(rng.normal(0, compute_sigma, num_clients))
    return ClientFleet(
        uplink_bps=up, downlink_bps=down,
        latency_s=np.full(num_clients, latency_s, np.float64),
        compute_multiplier=comp,
        dropout_prob=np.full(num_clients, dropout_prob, np.float64))


def mobile_fleet(num_clients: int, *,
                 flaky_fraction: float = 0.3,
                 wired_uplink_bps: float = 20e6,
                 wired_downlink_bps: float = 100e6,
                 mobile_uplink_bps: float = 1e6,
                 mobile_downlink_bps: float = 5e6,
                 mobile_latency_s: float = 0.15,
                 mobile_dropout_prob: float = 0.2,
                 mobile_compute_multiplier: float = 3.0,
                 seed: int = 0) -> ClientFleet:
    """Wired/mobile mixture: ``flaky_fraction`` of the fleet is slow mobile
    hardware on a lossy link (Caldas et al.'s resource-constrained cohort)."""
    rng = np.random.default_rng(seed)
    is_mobile = rng.random(num_clients) < flaky_fraction
    pick = lambda mobile, wired: np.where(is_mobile, mobile, wired)  # noqa: E731
    return ClientFleet(
        uplink_bps=pick(mobile_uplink_bps, wired_uplink_bps),
        downlink_bps=pick(mobile_downlink_bps, wired_downlink_bps),
        latency_s=pick(mobile_latency_s, 0.02),
        compute_multiplier=pick(mobile_compute_multiplier, 1.0),
        dropout_prob=pick(mobile_dropout_prob, 0.0))


def validate_fleet(fleet: Union[ClientFleet, Sequence[ClientProfile]],
                   num_clients: int) -> None:
    """Whole-fleet validation without touching per-client objects.

    `ClientFleet` columns were bounds-checked in bulk at construction and
    `ClientProfile` objects in ``__post_init__``; the only cross-cutting
    invariant left is the population size.
    """
    if len(fleet) != num_clients:
        raise ValueError(
            f"fleet has {len(fleet)} profiles for {num_clients} clients")
