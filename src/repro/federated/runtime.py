"""Federated runtime: client sampling, weighted aggregation, round drivers.

Implements the three algorithms the paper compares (§3, Table 1):

  * FEDAVG      — every sampled client runs H local SGD steps on the FULL
                  model, the server averages the deltas weighted by p_i.
  * SPLITFED    — per iteration, the cohort's activations hit the server,
                  gradients come back; equivalent to mini-batch SGD (§3).
  * FEDLITE     — SplitFed + grouped PQ + gradient correction at the cut.

SplitFed/FedLite iterations are realized by a single jitted train step over
the cohort's combined batch (see ``core/fedlite.py``) — mathematically
identical to per-client messaging with p_i-weighted server aggregation when
client batches are equal-sized, and exactly what the production mesh runs
(each data shard = one cohort). FedAvg keeps the explicit per-client local
loop since its local-step structure cannot be fused.

`FederatedTrainer.run` drives rounds through the virtual-clock
``federated/scheduler.py``: the default fleet/policy (identical
infinitely-fast clients, full sync) bitwise-reproduces the original
synchronous loop, while heterogeneous fleets + straggler policies turn the
same trainer into a measurement harness — per-round simulated wall-clock
and *measured* wire bytes (``federated/wire.py``) land in
``trainer.last_trace``.
"""

from __future__ import annotations

import dataclasses
import logging
import operator
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressors import (CutCompressor, NoneCompressor,
                                    PQCompressor, make_compressor)
from repro.core.fedlite import TrainState, make_train_step, make_weighted_step
from repro.data.synthetic import FederatedDataset
from repro.federated.network import ClientProfile, uniform_fleet, validate_fleet
from repro.federated.scheduler import (Arrival, AsyncBuffer, FullSync,
                                       Policy, Scheduler)
from repro.federated.trace import Trace
from repro.optim import Optimizer

logger = logging.getLogger(__name__)


def sample_clients(rng: np.random.Generator, num_clients: int, cohort: int,
                   weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Sample a cohort without replacement, uniformly or p_i-proportionally.

    ``weights`` (e.g. ``FederatedDataset.client_weights``, p_i ∝ n_i) biases
    selection toward data-rich clients — the sampling the FedAvg analysis
    assumes; ``None`` keeps the uniform sampling SplitFed/FedLite use.
    """
    size = min(cohort, num_clients)
    if weights is None:
        return rng.choice(num_clients, size=size, replace=False)
    p = np.asarray(weights, np.float64)
    if p.shape != (num_clients,) or (p < 0).any() or p.sum() <= 0:
        raise ValueError("weights must be a nonnegative (num_clients,) vector")
    return rng.choice(num_clients, size=size, replace=False, p=p / p.sum())


def weighted_average(trees: Sequence[Any], weights: Sequence[float]):
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    return jax.tree.map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *trees)


# ---------------------------------------------------------------------------
# FedAvg baseline
# ---------------------------------------------------------------------------

def fedavg_round(model, params, data: FederatedDataset, client_ids,
                 key: jax.Array, *, local_steps: int, batch: int,
                 lr: float, batch_kwargs: Optional[dict] = None):
    """One FedAvg round: H local SGD steps per client, weighted delta average.

    Returns (new_params, mean local loss). Local updates are plain SGD as in
    McMahan et al. (2017).
    """
    batch_kwargs = batch_kwargs or {}

    # jitted single local step (client batch sampled outside jit)
    @jax.jit
    def sgd_step(p, b):
        loss, grads = jax.value_and_grad(
            lambda q: model.loss(q, b, quantize=False)[0])(p)
        new_p = jax.tree.map(lambda x, g: x - lr * g, p, grads)
        return new_p, loss

    deltas, weights, losses = [], [], []
    for i, cid in enumerate(client_ids):
        p = params
        ck = jax.random.fold_in(key, int(cid))
        for s in range(local_steps):
            b = data.sample_batch(int(cid), jax.random.fold_in(ck, s), batch,
                                  **batch_kwargs)
            p, loss = sgd_step(p, b)
            losses.append(float(loss))
        deltas.append(jax.tree.map(operator.sub, p, params))
        weights.append(float(data.client_weights[int(cid)]))

    mean_delta = weighted_average(deltas, weights)
    new_params = jax.tree.map(operator.add, params, mean_delta)
    return new_params, float(np.mean(losses))


def run_fedavg(model, params, data: FederatedDataset, *, rounds: int,
               cohort: int, key: jax.Array, local_steps: int, batch: int,
               lr: float, weighted_sampling: bool = True, seed: int = 0,
               batch_kwargs: Optional[dict] = None):
    """FedAvg driver: p_i-proportional cohort sampling + weighted averaging.

    Returns (params, per-round mean-loss list)."""
    rng = np.random.default_rng(seed)
    weights = data.client_weights if weighted_sampling else None
    losses = []
    for r in range(rounds):
        ids = sample_clients(rng, data.num_clients, cohort, weights=weights)
        params, loss = fedavg_round(
            model, params, data, ids, jax.random.fold_in(key, r + 1),
            local_steps=local_steps, batch=batch, lr=lr,
            batch_kwargs=batch_kwargs)
        losses.append(loss)
    return params, losses


# ---------------------------------------------------------------------------
# SplitFed / FedLite trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FederatedTrainer:
    """Round driver for split-learning algorithms on a FederatedDataset.

    Each round samples a cohort, stacks the cohort's client batches into one
    global batch (cohort = leading batch dim) and runs the jitted split step.

    Rounds are dispatched by the virtual-clock `Scheduler`: ``fleet`` (one
    `ClientProfile` per client; default identical ideal clients) and
    ``policy`` (default `FullSync`) select the heterogeneity scenario. With
    the defaults the trajectory is bitwise-identical to a plain
    ``round()``-by-``round()`` loop; under straggler policies the stacked
    batch shrinks to the survivors (one extra jit cache entry per distinct
    survivor count). ``run`` leaves the per-round `Trace` — simulated
    wall-clock + measured wire bytes — in ``self.last_trace``.
    """
    model: Any
    optimizer: Optimizer
    data: FederatedDataset
    cohort: int
    client_batch: int
    quantize: bool = True
    batch_kwargs: Optional[dict] = None
    seed: int = 0
    fleet: Optional[Sequence[ClientProfile]] = None
    policy: Optional[Policy] = None
    client_step_seconds: float = 1.0
    server_step_seconds: float = 0.0
    codebook_wire_dtype: str = "float16"
    # per-direction cut-layer codecs (spec string or CutCompressor; see
    # core/compressors.py). Uplink default: the model's PQ ("pq") or dense
    # ("none"). Downlink default: whatever the model carries, else dense.
    # A downlink spec is installed INTO the model (dataclasses.replace), so
    # the training VJP and the measured wire bytes use the same codec.
    uplink_compressor: Any = None
    downlink_compressor: Any = None

    def __post_init__(self):
        pq = getattr(self.model, "pq", None)
        dl = make_compressor(self.downlink_compressor, pq=pq)
        if dl is not None and hasattr(self.model, "downlink_compressor"):
            self.model = dataclasses.replace(self.model,
                                             downlink_compressor=dl)
        self.downlink = dl if dl is not None else \
            getattr(self.model, "downlink_compressor", None)
        # the uplink codec is INSTALLED into the model (or must match what
        # the model already runs) so the trained path and the measured
        # traffic never diverge
        up = make_compressor(self.uplink_compressor, pq=pq)
        if up is None:
            up = PQCompressor(pq) if (self.quantize and pq is not None) \
                else NoneCompressor()
        elif isinstance(up, NoneCompressor):
            if self.quantize and pq is not None:
                raise ValueError(
                    "uplink_compressor='none' conflicts with the model's PQ "
                    "config; pass quantize=False or a model without pq")
        elif isinstance(up, PQCompressor):
            if not self.quantize:
                raise ValueError("uplink_compressor='pq' needs quantize=True")
            if up.cfg != pq:
                self.model = dataclasses.replace(self.model, pq=up.cfg)
        elif hasattr(self.model, "uplink_compressor"):
            if not self.quantize:
                raise ValueError(
                    f"uplink_compressor={up.spec!r} needs quantize=True")
            self.model = dataclasses.replace(self.model, uplink_compressor=up)
        else:
            raise ValueError(
                f"{type(self.model).__name__} has no uplink_compressor "
                f"field; only 'pq'/'none' uplinks are realizable for it")
        self.uplink = up
        self._step = make_train_step(self.model, self.optimizer,
                                     quantize=self.quantize, donate=False)
        self._weighted_step = make_weighted_step(self.model, self.optimizer,
                                                 quantize=self.quantize)
        self._rng = np.random.default_rng(self.seed)
        if self.fleet is None:
            self.fleet = uniform_fleet(self.data.num_clients)
        validate_fleet(self.fleet, self.data.num_clients)
        if self.policy is None:
            self.policy = FullSync()
        self.last_trace: Optional[Trace] = None

    def init_state(self, key: jax.Array) -> TrainState:
        return TrainState.create(self.model.init(key), self.optimizer)

    # ---- batch assembly ----------------------------------------------------
    def client_batch_for(self, cid: int, round_key: jax.Array):
        return self.data.sample_batch(int(cid),
                                      jax.random.fold_in(round_key, int(cid)),
                                      self.client_batch,
                                      **(self.batch_kwargs or {}))

    def stack_batches(self, parts: Sequence[Dict[str, jax.Array]]):
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    def cohort_batch(self, key: jax.Array) -> Dict[str, jax.Array]:
        ids = sample_clients(self._rng, self.data.num_clients, self.cohort)
        return self.stack_batches([self.client_batch_for(cid, key)
                                   for cid in ids])

    def round(self, state: TrainState, key: jax.Array):
        batch = self.cohort_batch(key)
        return self._step(state, batch)

    # ---- wire measurement --------------------------------------------------
    def measure_round_bytes(self, state: TrainState, key: jax.Array):
        """Measured per-client (uplink, downlink) payload bytes for a round.

        One real client forward feeds both directions. Uplink: the cut
        activations through the configured uplink codec and the tagged wire
        format (`federated/wire.py`). Downlink: the cut-layer gradient
        message through the downlink codec — its payload layout is
        shape-determined (indices count, code widths), so the activation
        tensor stands in for the gradient and a single measurement is exact
        for every round. ``none`` on either side measures the dense tensor
        at its native dtype.
        """
        batch = self.data.sample_batch(0, key, self.client_batch,
                                       **(self.batch_kwargs or {}))
        acts = self.model.client_forward(state.params["client"], batch)
        if isinstance(acts, tuple):   # TransformerLM returns (acts, aux...)
            acts = acts[0]
        acts2 = acts.reshape(-1, acts.shape[-1])
        raw_bytes = int(acts.size * jnp.dtype(acts.dtype).itemsize)

        def measured(compressor: Optional[CutCompressor]) -> int:
            # quantize=False disables the cut codecs in the training VJP
            # (models gate on it), so the measurement must stay dense too
            if not self.quantize or compressor is None \
                    or compressor.name == "none":
                return raw_bytes
            comp = compressor.compress(acts2)
            return len(compressor.wire_payload(
                comp, value_dtype=self.codebook_wire_dtype))

        return measured(self.uplink), measured(self.downlink)

    def measure_uplink_bytes(self, state: TrainState, key: jax.Array) -> int:
        return self.measure_round_bytes(state, key)[0]

    def measure_downlink_bytes(self, state: TrainState, key: jax.Array) -> int:
        return self.measure_round_bytes(state, key)[1]

    def measure_dense_bytes(self, state: TrainState, key: jax.Array) -> int:
        """The uncompressed cut tensor (either direction's dense baseline)."""
        batch = self.data.sample_batch(0, key, self.client_batch,
                                       **(self.batch_kwargs or {}))
        acts = self.model.client_forward(state.params["client"], batch)
        if isinstance(acts, tuple):
            acts = acts[0]
        return int(acts.size * jnp.dtype(acts.dtype).itemsize)

    # ---- scheduled run -----------------------------------------------------
    def run(self, steps: int, key: jax.Array, log_every: int = 0):
        """Run ``steps`` server updates through the scheduler.

        Returns (final state, history) where history holds one dict per
        server update: the step metrics (host-synced once, at the end of the
        run — not per round) plus the round's simulation fields. The full
        `Trace` is kept in ``self.last_trace``.
        """
        state = self.init_state(key)
        device_metrics: List[Dict[str, jax.Array]] = []

        def execute(update_idx: int, participants: Sequence[Arrival],
                    weights: Sequence[float]) -> Dict:
            nonlocal state
            round_keys = {}
            parts = []
            for a in participants:
                rk = round_keys.setdefault(
                    a.version, jax.random.fold_in(key, a.version + 1))
                parts.append(self.client_batch_for(a.client, rk))
            if isinstance(self.policy, AsyncBuffer):
                # per-contribution staleness weighting (FedBuff): each
                # client's gradient split is discounted by ITS OWN staleness
                # before aggregation — not by the cohort mean. Every async
                # flush takes this path (even all-fresh buffers) so the
                # per-client quantization granularity is consistent across
                # a run instead of flipping with the staleness draw.
                batches = jax.tree.map(
                    lambda *xs: jnp.stack(xs, axis=0), *parts)
                state, metrics = self._weighted_step(
                    state, batches, jnp.asarray(weights, jnp.float32))
            else:
                batch = self.stack_batches(parts)
                state, metrics = self._step(state, batch)
            device_metrics.append(metrics)
            if log_every and update_idx % log_every == 0:
                # the only mid-run host sync, at the caller-chosen cadence
                logger.info("step %d: loss=%.4f", update_idx,
                            float(metrics.get("loss", 0.0)))
            return metrics

        scheduler = Scheduler(fleet=self.fleet, policy=self.policy,
                              client_step_seconds=self.client_step_seconds,
                              server_step_seconds=self.server_step_seconds,
                              seed=self.seed)
        uplink, downlink = self.measure_round_bytes(
            state, jax.random.fold_in(key, 0))
        trace = scheduler.run(
            steps, sample_cohort=lambda rd: sample_clients(
                self._rng, self.data.num_clients, self.cohort),
            uplink_bytes=uplink, downlink_bytes=downlink, execute=execute)
        dl = self.downlink
        trace.meta.update({
            "uplink_compressor": getattr(self.uplink, "spec",
                                         self.uplink.name),
            "downlink_compressor": "none" if dl is None
            else getattr(dl, "spec", dl.name),
            "uplink_bytes_per_client": uplink,
            "downlink_bytes_per_client": downlink,
        })

        # one blocking transfer for the whole run
        host_metrics = jax.device_get(device_metrics)
        history: List[Dict[str, float]] = []
        it = iter(host_metrics)
        for rec in trace:
            floats = {k: float(v) for k, v in next(it).items()} \
                if rec.metrics else {}
            rec.metrics = floats
            entry = dict(floats, step=rec.round, t_start=rec.t_start,
                         t_end=rec.t_end, uplink_bytes=rec.uplink_bytes,
                         downlink_bytes=rec.downlink_bytes,
                         participants=len(rec.participants),
                         dropped=len(rec.dropped))
            history.append(entry)
        self.last_trace = trace
        return state, history
