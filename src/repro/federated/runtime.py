"""Federated runtime: client sampling, weighted aggregation, round drivers.

Implements the three algorithms the paper compares (§3, Table 1):

  * FEDAVG      — every sampled client runs H local SGD steps on the FULL
                  model, the server averages the deltas weighted by p_i.
  * SPLITFED    — per iteration, the cohort's activations hit the server,
                  gradients come back; equivalent to mini-batch SGD (§3).
  * FEDLITE     — SplitFed + grouped PQ + gradient correction at the cut.

SplitFed/FedLite iterations are realized by a single jitted train step over
the cohort's combined batch (see ``core/fedlite.py``) — mathematically
identical to per-client messaging with p_i-weighted server aggregation when
client batches are equal-sized, and exactly what the production mesh runs
(each data shard = one cohort). FedAvg keeps the explicit per-client local
loop since its local-step structure cannot be fused.

`FederatedTrainer.run` drives rounds through the virtual-clock
``federated/scheduler.py``: the default fleet/policy (identical
infinitely-fast clients, full sync) bitwise-reproduces the original
synchronous loop, while heterogeneous fleets + straggler policies turn the
same trainer into a measurement harness — per-round simulated wall-clock
and *measured* wire bytes (``federated/wire.py``) land in
``trainer.last_trace``.
"""

from __future__ import annotations

import dataclasses
import logging
import operator
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.compressors import (CutCompressor, CutState, NoneCompressor,
                                    PQCompressor, make_compressor)
from repro.core.fedlite import TrainState
from repro.core.quantizer import QuantizerState, quantize_stateful
from repro.data.synthetic import FederatedDataset
from repro.federated import wire
from repro.federated.executor import make_executor
from repro.federated.faults import FaultPlan, make_injector
from repro.federated.network import ClientProfile, uniform_fleet, validate_fleet
from repro.federated.scheduler import (Arrival, AsyncBuffer, FullSync,
                                       Policy, Scheduler)
from repro.federated.trace import Trace
from repro.obs import flight as flightlib
from repro.optim import Optimizer

logger = logging.getLogger(__name__)


def sample_clients(rng: np.random.Generator, num_clients: int, cohort: int,
                   weights: Optional[np.ndarray] = None) -> np.ndarray:
    """Sample a cohort without replacement, uniformly or p_i-proportionally.

    ``weights`` (e.g. ``FederatedDataset.client_weights``, p_i ∝ n_i) biases
    selection toward data-rich clients — the sampling the FedAvg analysis
    assumes; ``None`` keeps the uniform sampling SplitFed/FedLite use.
    """
    size = min(cohort, num_clients)
    if weights is None:
        return rng.choice(num_clients, size=size, replace=False)
    p = np.asarray(weights, np.float64)
    if p.shape != (num_clients,) or (p < 0).any() or p.sum() <= 0:
        raise ValueError("weights must be a nonnegative (num_clients,) vector")
    return rng.choice(num_clients, size=size, replace=False, p=p / p.sum())


def weighted_average(trees: Sequence[Any], weights: Sequence[float]):
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    return jax.tree.map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *trees)


# ---------------------------------------------------------------------------
# FedAvg baseline
# ---------------------------------------------------------------------------

def make_fedavg_step(model, lr: float):
    """The jitted single local SGD step (client batch sampled outside jit).

    Built ONCE per (model, lr) and reused across every round — a jit
    closure rebuilt inside the round function would retrace per round."""
    @jax.jit
    def sgd_step(p, b):
        loss, grads = jax.value_and_grad(
            lambda q: model.loss(q, b, quantize=False)[0])(p)
        new_p = jax.tree.map(lambda x, g: x - lr * g, p, grads)
        return new_p, loss
    return sgd_step


def fedavg_round(model, params, data: FederatedDataset, client_ids,
                 key: jax.Array, *, local_steps: int, batch: int,
                 lr: float, batch_kwargs: Optional[dict] = None,
                 sgd_step=None):
    """One FedAvg round: H local SGD steps per client, weighted delta average.

    Returns (new_params, mean local loss). Local updates are plain SGD as in
    McMahan et al. (2017). ``sgd_step`` (from `make_fedavg_step`) lets the
    round driver reuse one jit cache across rounds. The mean loss is
    returned as a DEVICE scalar — no host sync per round; the caller
    batches the transfer (``run_fedavg`` flushes every round's loss through
    one `obs.MetricsBuffer` transfer at the end of the run).
    """
    batch_kwargs = batch_kwargs or {}
    if sgd_step is None:
        sgd_step = make_fedavg_step(model, lr)

    deltas, losses = [], []
    for cid in client_ids:
        p = params
        ck = jax.random.fold_in(key, int(cid))
        for s in range(local_steps):
            b = data.sample_batch(int(cid), jax.random.fold_in(ck, s), batch,
                                  **batch_kwargs)
            p, loss = sgd_step(p, b)
            losses.append(loss)
        deltas.append(jax.tree.map(operator.sub, p, params))
    weights = [float(data.client_weights[int(cid)]) for cid in client_ids]

    mean_delta = weighted_average(deltas, weights)
    new_params = jax.tree.map(operator.add, params, mean_delta)
    return new_params, jnp.mean(jnp.stack(losses))


def run_fedavg(model, params, data: FederatedDataset, *, rounds: int,
               cohort: int, key: jax.Array, local_steps: int, batch: int,
               lr: float, weighted_sampling: bool = True, seed: int = 0,
               batch_kwargs: Optional[dict] = None):
    """FedAvg driver: p_i-proportional cohort sampling + weighted averaging.

    Returns (params, per-round mean-loss list)."""
    rng = np.random.default_rng(seed)
    weights = data.client_weights if weighted_sampling else None
    sgd_step = make_fedavg_step(model, lr)   # one jit cache for the run
    buf = obs.MetricsBuffer()   # device losses; one transfer at end of run
    for r in range(rounds):
        ids = sample_clients(rng, data.num_clients, cohort, weights=weights)
        params, loss = fedavg_round(
            model, params, data, ids, jax.random.fold_in(key, r + 1),
            local_steps=local_steps, batch=batch, lr=lr,
            batch_kwargs=batch_kwargs, sgd_step=sgd_step)
        buf.record({"loss": loss})
    return params, [m["loss"] for m in buf.flush()]


# ---------------------------------------------------------------------------
# SplitFed / FedLite trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FederatedTrainer:
    """Round driver for split-learning algorithms on a FederatedDataset.

    Each round samples a cohort, stacks the cohort's client batches into one
    global batch (cohort = leading batch dim) and runs the jitted split step.

    Rounds are dispatched by the virtual-clock `Scheduler`: ``fleet`` (one
    `ClientProfile` per client; default identical ideal clients) and
    ``policy`` (default `FullSync`) select the heterogeneity scenario. With
    the defaults the trajectory is bitwise-identical to a plain
    ``round()``-by-``round()`` loop; under straggler policies the stacked
    batch shrinks to the survivors (one extra jit cache entry per distinct
    survivor count). ``run`` leaves the per-round `Trace` — simulated
    wall-clock + measured wire bytes — in ``self.last_trace``.

    WHERE each round's per-client math executes is the ``executor``'s job
    (``federated/executor.py``): the ``"stacked"`` default is the
    single-device path described above; ``"mesh"`` shards the cohort over
    the ``clients`` axis of a device mesh (per-client batches/PRNG
    keys/EF memories/`CutState`s placed with NamedSharding, shard-local
    gradients combined by one explicit psum). Policies, traces and the
    wire measurement are executor-agnostic; traces additionally record
    each participant's shard placement.
    """
    model: Any
    optimizer: Optimizer
    data: FederatedDataset
    cohort: int
    client_batch: int
    quantize: bool = True
    batch_kwargs: Optional[dict] = None
    seed: int = 0
    fleet: Optional[Sequence[ClientProfile]] = None
    policy: Optional[Policy] = None
    client_step_seconds: float = 1.0
    server_step_seconds: float = 0.0
    codebook_wire_dtype: str = "float16"
    # per-direction cut-layer codecs (spec string or CutCompressor; see
    # core/compressors.py). Uplink default: the model's PQ ("pq") or dense
    # ("none"). Downlink default: whatever the model carries, else dense.
    # A downlink spec is installed INTO the model (dataclasses.replace), so
    # the training VJP and the measured wire bytes use the same codec.
    uplink_compressor: Any = None
    downlink_compressor: Any = None
    # ---- cross-round cut-layer state (all default-off: bitwise-historical)
    # warm_start: carry the PQ codebooks across scheduler rounds — Lloyd
    # resumes from last round's codebook at PQConfig.warm_iters iterations
    # (cohort-global on the stacked/FullSync path; per-client under
    # AsyncBuffer, falling back to a cold round whenever the buffer holds a
    # first-time client).
    warm_start: bool = False
    # error_feedback: per-client uplink error-feedback memory (the
    # `ErrorFeedback` telescoping semantics), gathered/scattered by client
    # id across rounds — clients re-add their accumulated compression error
    # before compressing.
    error_feedback: bool = False
    # stochastic_downlink: thread a per-step PRNG key into the downlink
    # VJP so scalarq gradient codecs round stochastically (unbiased).
    stochastic_downlink: bool = False
    # codebook_delta_bits: measure the pq directions with the `pq-delta`
    # wire kind (quantized codebook deltas vs the acked reference) instead
    # of fresh fp16 codebooks; the measured steady-state bytes feed the
    # scheduler. Applies to the uplink AND — when the downlink codec is pq
    # — the downlink gradient message (PR 4's delta machinery covers both
    # directions).
    codebook_delta_bits: Optional[int] = None
    # executor: the cohort execution engine (federated/executor.py) that
    # maps each server update's per-client math onto devices — "stacked"
    # (single-device historical path, bitwise default), "mesh" /
    # "mesh(shards=N)" (shard_map over the `clients` device axis), or a
    # CohortExecutor instance.
    executor: Any = "stacked"
    # topology: optional aggregation hierarchy (federated/topology.py) —
    # None is the flat client->server star; TwoTierTopology(...) routes
    # uploads through location-clustered edge aggregators (per-tier times
    # on the virtual clock, edge_uplink/server_uplink ledger entries, and
    # cluster-aware cohort placement on the mesh executor).
    topology: Any = None
    # scheduler_backend: "auto" (vectorized fleet-scale core whenever the
    # policy supports it) | "vector" | "heapq" (per-arrival reference).
    # Both backends produce bitwise-identical traces.
    scheduler_backend: str = "auto"
    # fault_plan: optional seeded chaos schedule (federated/faults.py).
    # None (default) injects nothing and leaves every path bitwise-
    # historical. A `FaultPlan` adds client crashes with scheduler-side
    # retry, wire corruption + poisoned gradients screened server-side
    # (quarantine + quorum), reorder jitter, edge outages, and server
    # kills — all drawn from the plan's own hash stream, never the
    # training or scheduler RNGs.
    fault_plan: Optional[FaultPlan] = None
    # slo_monitor: optional `repro.obs.HealthMonitor` graded against the
    # finished trace at the end of every run() — failing rules emit
    # structured ``slo_violation`` obs events next to the run's own spans.
    slo_monitor: Optional[Any] = None

    def __post_init__(self):
        pq = getattr(self.model, "pq", None)
        dl = make_compressor(self.downlink_compressor, pq=pq)
        if dl is not None and hasattr(self.model, "downlink_compressor"):
            self.model = dataclasses.replace(self.model,
                                             downlink_compressor=dl)
        self.downlink = dl if dl is not None else \
            getattr(self.model, "downlink_compressor", None)
        # the uplink codec is INSTALLED into the model (or must match what
        # the model already runs) so the trained path and the measured
        # traffic never diverge
        up = make_compressor(self.uplink_compressor, pq=pq)
        if up is None:
            up = PQCompressor(pq) if (self.quantize and pq is not None) \
                else NoneCompressor()
        elif isinstance(up, NoneCompressor):
            if self.quantize and pq is not None:
                raise ValueError(
                    "uplink_compressor='none' conflicts with the model's PQ "
                    "config; pass quantize=False or a model without pq")
        elif isinstance(up, PQCompressor):
            if not self.quantize:
                raise ValueError("uplink_compressor='pq' needs quantize=True")
            if up.cfg != pq:
                self.model = dataclasses.replace(self.model, pq=up.cfg)
        elif hasattr(self.model, "uplink_compressor"):
            if not self.quantize:
                raise ValueError(
                    f"uplink_compressor={up.spec!r} needs quantize=True")
            self.model = dataclasses.replace(self.model, uplink_compressor=up)
        else:
            raise ValueError(
                f"{type(self.model).__name__} has no uplink_compressor "
                f"field; only 'pq'/'none' uplinks are realizable for it")
        self.uplink = up
        if self.codebook_delta_bits is not None:
            if not 1 <= self.codebook_delta_bits <= 16:
                raise ValueError(f"codebook_delta_bits="
                                 f"{self.codebook_delta_bits} not in [1, 16]")
            if not isinstance(up, PQCompressor) \
                    and not isinstance(self.downlink, PQCompressor):
                raise ValueError(
                    "codebook_delta_bits needs a pq uplink or downlink")
            if not self.quantize:
                raise ValueError("codebook_delta_bits needs quantize=True")
        if self.warm_start and not isinstance(up, PQCompressor):
            raise ValueError("warm_start needs a pq uplink")
        if (self.warm_start or self.error_feedback) and not self.quantize:
            raise ValueError("warm_start/error_feedback need quantize=True")
        # the execution engine owns the jitted steps and the device mapping
        # (federated/executor.py); it is bound AFTER the codecs above were
        # installed so its steps see the final model
        self.executor = make_executor(self.executor)
        self.executor.bind(self)
        self._wants_cut_state = self.warm_start or self.error_feedback
        self._global_q: Optional[QuantizerState] = None   # cohort-global
        self._global_q_nparts = 0                         # cohort size of it
        self._client_q: Dict[int, Any] = {}               # keyed by client id
        self._seed_q: Optional[Any] = None                # latest absorbed
        #                               per-client codebook: warm-start seed
        #                               for first-time clients
        self._ef_memory: Dict[int, Any] = {}              # per-client rows
        self._act_struct = None                           # per-client acts
        self.last_codebook_meta: Dict[str, Any] = {}
        # (uplink, downlink) wire-kind tags behind the measured payload
        # bytes; set by measure_round_bytes and fed to the scheduler's
        # per-round byte ledger (RoundRecord.ledger)
        self.last_wire_kinds = ("dense", "dense")
        # canary uplink payload (set by measure_round_bytes): the real
        # wire bytes a client would ship, corrupted per-plan in
        # _screen_cohort so detection runs against the actual wire format
        self._canary_payload: Optional[bytes] = None
        # per-round screening counters, merged into the trace after run()
        self._fault_log: Dict[int, Dict[str, int]] = {}
        # per-round screening verdicts (who was quarantined / was the
        # round voided), replayed onto the flight recorder's frames after
        # run() so exemplar lifecycles carry final server-side states
        self._screen_log: Dict[int, Dict[str, Any]] = {}
        self._rng = np.random.default_rng(self.seed)
        if self.fleet is None:
            self.fleet = uniform_fleet(self.data.num_clients)
        validate_fleet(self.fleet, self.data.num_clients)
        if self.policy is None:
            self.policy = FullSync()
        if self.topology is not None:
            # cluster the fleet once up front so the executor's placement
            # and every scheduler run see the same client->edge map
            self.topology.ensure(self.data.num_clients)
            self.executor.set_topology(self.topology)
        self.last_trace: Optional[Trace] = None

    def init_state(self, key: jax.Array) -> TrainState:
        return TrainState.create(self.model.init(key), self.optimizer)

    # ---- batch assembly ----------------------------------------------------
    def client_batch_for(self, cid: int, round_key: jax.Array):
        return self.data.sample_batch(int(cid),
                                      jax.random.fold_in(round_key, int(cid)),
                                      self.client_batch,
                                      **(self.batch_kwargs or {}))

    def stack_batches(self, parts: Sequence[Dict[str, jax.Array]]):
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    def cohort_batch(self, key: jax.Array) -> Dict[str, jax.Array]:
        ids = sample_clients(self._rng, self.data.num_clients, self.cohort)
        return self.stack_batches([self.client_batch_for(cid, key)
                                   for cid in ids])

    def round(self, state: TrainState, key: jax.Array):
        """One synchronous server update on a fresh cohort, through the
        configured executor (the stacked default concatenates the cohort
        into one fused batch — the bitwise-historical path)."""
        ids = sample_clients(self._rng, self.data.num_clients, self.cohort)
        parts = [self.client_batch_for(cid, key) for cid in ids]
        return self.executor.execute(state, parts)

    # ---- cross-round cut-layer state ---------------------------------------
    def _client_act_struct(self, params, part):
        """Shape/dtype of one client's cut activation (eval_shape, cached)."""
        if self._act_struct is None:
            acts = jax.eval_shape(
                lambda p, b: self.model.client_forward(p, b),
                params["client"], part)
            if isinstance(acts, tuple):   # TransformerLM: (acts, caches, aux)
                acts = acts[0]
            self._act_struct = acts
        return self._act_struct

    def _client_ef(self, cid: int):
        mem = self._ef_memory.get(int(cid))
        return mem if mem is not None \
            else jnp.zeros(self._act_struct.shape, self._act_struct.dtype)

    def _gather_client_q(self, cids):
        """Per-client codebook states stacked in participant order.

        Warm-start lineage is keyed by CLIENT ID on every path, so straggler
        policies that reshuffle cohort composition (DropSlowestK / Deadline
        survivors, AsyncBuffer flushes) keep each client's lineage intact.
        A client with no state yet is SEEDED from the most recently absorbed
        codebook (`_seed_q`) — activation distributions drift slowly, so a
        neighbor's codebook is a good warm initializer and the round stays
        warm instead of cold-flushing the whole cohort. Returns ``None``
        (cold round) only before any per-client state exists."""
        if not self._client_q and self._seed_q is None:
            return None
        states = [self._client_q.get(c, self._seed_q) for c in cids]
        if any(s is None for s in states):   # no seed to warm first-timers
            return None
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *states)

    def _cut_state_for(self, participants, params, parts, stacked: bool):
        """Assemble the round's `CutState` (or None when both features are
        off). Stacked path: per-client codebooks stacked in participant
        order when the model quantizes per client (falling back to the
        cohort-global codebook for models with one codebook per cohort) +
        per-client EF rows concatenated in participant order. Per-client
        path (AsyncBuffer flushes, every mesh-executor update): every leaf
        gains a leading client axis."""
        if not self._wants_cut_state:
            return None
        self._client_act_struct(params, parts[0])
        cids = [int(a.client) for a in participants]
        if stacked:
            q = None
            if self.warm_start:
                q = self._gather_client_q(cids)
                if q is None:
                    # cohort-global lineage (one codebook per cohort,
                    # model.client_batch == 0) — or a manually injected
                    # stacked state, which only fits the cohort size that
                    # produced it: fall back to cold on a count change
                    q = self._global_q
                    if q is not None and q.codebooks.ndim > 3 \
                            and len(cids) != self._global_q_nparts:
                        q = None
            ef = jnp.concatenate([self._client_ef(c) for c in cids], axis=0) \
                if self.error_feedback else None
            return CutState(quantizer=q, ef_memory=ef)
        q = self._gather_client_q(cids) if self.warm_start else None
        ef = jnp.stack([self._client_ef(c) for c in cids], axis=0) \
            if self.error_feedback else None
        return CutState(quantizer=q, ef_memory=ef)

    def _absorb_cut_state(self, participants, new_cut, stacked: bool):
        """Scatter a step's returned `CutState` back into the per-client
        slots keyed by client id (per-client-axis state may carry padded
        executor slots past ``len(participants)``; they are ignored). State
        with one codebook per cohort — or a stacked axis that does not
        match the participant count — lands in the cohort-global slot."""
        if new_cut is None:
            return
        cids = [int(a.client) for a in participants]
        if self.warm_start and new_cut.quantizer is not None:
            q = new_cut.quantizer
            per_client = q.codebooks.ndim > 3 \
                and q.codebooks.shape[0] >= len(cids) \
                and (not stacked or q.codebooks.shape[0] == len(cids))
            if per_client:
                for i, c in enumerate(cids):
                    self._client_q[c] = jax.tree.map(lambda x: x[i], q)
                self._seed_q = self._client_q[cids[-1]]
            else:
                self._global_q = q
                self._global_q_nparts = len(cids)
        if self.error_feedback and new_cut.ef_memory is not None:
            if stacked:
                rows = self._act_struct.shape[0]
                for i, c in enumerate(cids):
                    self._ef_memory[c] = \
                        new_cut.ef_memory[i * rows:(i + 1) * rows]
            else:
                for i, c in enumerate(cids):
                    self._ef_memory[c] = new_cut.ef_memory[i]

    # ---- server-side admission screening (chaos plans only) ----------------
    def _screen_cohort(self, inj, update_idx: int, participants, parts,
                       weights):
        """Inject the plan's payload faults, then quarantine every
        contribution that fails the server's admission checks before any
        of it can touch the aggregate.

        Corruption is applied to the round's canary — the real uplink
        wire frame — and detection is the actual `federated/wire.py`
        decode (CRC + typed errors), so a corrupt contribution is either
        caught in transit (quarantined) or counted in
        ``corrupt_undetected`` (the chaos canary: must stay 0). Poisoned
        clients ship NaN-filled tensors; the finiteness screen catches
        them regardless of how they were poisoned. Survivors keep their
        own staleness weights — aggregation renormalizes over the kept
        cohort exactly as under straggler cuts. A round whose survivor
        fraction falls below ``quorum_fraction`` is VOIDED: no server
        update, counters only.

        Returns ``(participants, parts, weights, fault_counters)`` —
        empty lists mean the round was voided.
        """
        cids = np.asarray([int(a.client) for a in participants], np.int64)
        poison = inj.poison_mask(update_idx, cids)
        corrupt = inj.corrupt_mask(update_idx, cids)
        fl: Dict[str, int] = {}
        if not poison.any() and not corrupt.any():
            return participants, parts, weights, fl
        parts = list(parts)
        for i in np.nonzero(poison)[0]:
            parts[i] = jax.tree.map(
                lambda x: jnp.full_like(x, jnp.nan)
                if jnp.issubdtype(x.dtype, jnp.floating) else x, parts[i])
        keep = np.ones(len(parts), bool)
        undetected = 0
        canary = self._canary_payload
        for i in range(len(parts)):
            if corrupt[i] and canary is not None:
                bad = inj.corrupt_payload(canary, update_idx, int(cids[i]))
                try:
                    wire.decode_payload(bad)
                except wire.WireError:
                    keep[i] = False       # caught in transit -> quarantined
                    continue
                undetected += 1           # CRC missed: canary assertion trips
            if not keep[i]:
                continue
            for leaf in jax.tree.leaves(parts[i]):
                if jnp.issubdtype(leaf.dtype, jnp.floating) \
                        and not bool(jnp.isfinite(leaf).all()):
                    keep[i] = False       # non-finite -> quarantined
                    break
        quarantined = int((~keep).sum())
        if quarantined:
            fl["quarantined"] = quarantined
        if undetected:
            fl["corrupt_undetected"] = undetected
        voided = \
            int(keep.sum()) < self.fault_plan.quorum_fraction * len(parts)
        if quarantined or voided:
            self._screen_log[update_idx] = {
                "quarantined": [int(c) for c in cids[~keep]],
                "voided": voided}
        if voided:
            fl["round_voided"] = 1
            obs.event("fault.round_voided", cat="faults", round=update_idx,
                      quarantined=quarantined, cohort=len(parts))
            return [], [], [], fl
        if quarantined:
            participants = [a for a, k in zip(participants, keep) if k]
            parts = [p for p, k in zip(parts, keep) if k]
            if weights is not None:
                weights = [w for w, k in zip(weights, keep) if k]
        return participants, parts, weights, fl

    # ---- wire measurement --------------------------------------------------
    def measure_round_bytes(self, state: TrainState, key: jax.Array):
        """Measured per-client (uplink, downlink) payload bytes for a round.

        One real client forward feeds both directions. Uplink: the cut
        activations through the configured uplink codec and the tagged wire
        format (`federated/wire.py`). Downlink: the cut-layer gradient
        message through the downlink codec — its payload layout is
        shape-determined (indices count, code widths), so the activation
        tensor stands in for the gradient and a single measurement is exact
        for every round. ``none`` on either side measures the dense tensor
        at its native dtype.

        With ``codebook_delta_bits`` set, each pq direction is measured as
        the steady-state ``pq-delta`` payload: a second round's tensor is
        quantized warm-started from the first, its codebook is delta-encoded
        against the acked (fp16-decoded) round-0 reference, and the measured
        codebook-bytes reduction lands in ``self.last_codebook_meta`` (and
        the run's ``trace.meta``) — uplink keys unprefixed (the historical
        layout), downlink keys under ``downlink_``.
        """
        batch = self.data.sample_batch(0, key, self.client_batch,
                                       **(self.batch_kwargs or {}))
        acts = self.executor.client_forward(state.params["client"], batch)
        if isinstance(acts, tuple):   # TransformerLM returns (acts, aux...)
            acts = acts[0]
        acts2 = acts.reshape(-1, acts.shape[-1])
        raw_bytes = int(acts.size * jnp.dtype(acts.dtype).itemsize)

        def measured(compressor: Optional[CutCompressor]):
            # quantize=False disables the cut codecs in the training VJP
            # (models gate on it), so the measurement must stay dense too
            if not self.quantize or compressor is None \
                    or compressor.name == "none":
                return raw_bytes, "dense", None
            comp = compressor.compress(acts2)
            payload = compressor.wire_payload(
                comp, value_dtype=self.codebook_wire_dtype)
            # the kind tag the receiver will dispatch on — read from the
            # actual payload header so chains report their outermost stage
            return len(payload), wire.payload_kind(payload), payload

        with obs.span("trainer.measure_round_bytes", cat="wire"):
            uplink_bytes, up_kind, up_payload = measured(self.uplink)
            downlink_bytes, down_kind, _ = measured(self.downlink)
            # the chaos canary: one real uplink frame (dense tensors get a
            # dense frame; pq-delta measurement keeps the self-contained pq
            # frame — delta decode needs receiver state a canary lacks)
            self._canary_payload = up_payload if up_payload is not None \
                else wire.encode_dense(np.asarray(acts2, np.float32),
                                       acts2.shape[0], acts2.shape[1],
                                       "float32")
            self.last_codebook_meta = {}
            if self.codebook_delta_bits is not None and self.quantize:
                acts_b = self._second_round_acts(state, key)
                if isinstance(self.uplink, PQCompressor):
                    uplink_bytes = self._measure_delta_direction(
                        self.uplink.cfg, acts2, acts_b, uplink_bytes,
                        prefix="", bytes_key="uplink_bytes")
                    up_kind = "pq-delta"
                if isinstance(self.downlink, PQCompressor):
                    # same machinery, other direction: the gradient
                    # message's codebooks delta-encoded against the
                    # previous round's acked reference (the activation
                    # tensor stands in for the gradient, as for the
                    # non-delta downlink measurement)
                    downlink_bytes = self._measure_delta_direction(
                        self.downlink.cfg, acts2, acts_b, downlink_bytes,
                        prefix="downlink_", bytes_key="downlink_bytes")
                    down_kind = "pq-delta"
        self.last_wire_kinds = (up_kind, down_kind)
        return uplink_bytes, downlink_bytes

    def _second_round_acts(self, state: TrainState, key: jax.Array):
        """A second round's cut tensor (for steady-state delta payloads)."""
        batch2 = self.data.sample_batch(0, jax.random.fold_in(key, 1),
                                        self.client_batch,
                                        **(self.batch_kwargs or {}))
        acts_b = self.executor.client_forward(state.params["client"], batch2)
        if isinstance(acts_b, tuple):
            acts_b = acts_b[0]
        return acts_b.reshape(-1, acts_b.shape[-1])

    def _measure_delta_direction(self, cfg, acts2, acts_b, full_bytes: int,
                                 *, prefix: str, bytes_key: str) -> int:
        """Steady-state `pq-delta` payload bytes for one direction.

        Round 0 quantizes cold and ships full codebooks; the acked
        reference is what the receiver decoded — the codebook at wire
        fidelity, not the sender's private fp32 copy. Round 1 quantizes
        warm-started from round 0's `QuantizerState` and ships b-bit
        codebook deltas against the reference."""
        qb1, qstate = quantize_stateful(acts2, cfg)
        # loopback of bytes we just encoded — nothing untrusted on this wire
        ref = wire.decode_bytes(  # fedlint: disable=unchecked-wire-decode
            wire.encode_bytes(qb1, self.codebook_wire_dtype)) \
            .codebooks.astype(np.float32)
        qb2, _ = quantize_stateful(acts_b, cfg, qstate)
        payload, _ = wire.encode_pq_delta(qb2, ref, self.codebook_delta_bits)
        d = int(acts2.shape[-1])
        cb_full = int(np.prod(cfg.codebook_shape(d))) \
            * wire._np_dtype(self.codebook_wire_dtype).itemsize
        code_bytes = len(wire.encode_bytes(qb2, self.codebook_wire_dtype)) \
            - wire.HEADER_BYTES - wire.CRC_BYTES - cb_full
        cb_delta = len(payload) - wire.HEADER_BYTES - wire.CRC_BYTES \
            - code_bytes
        self.last_codebook_meta.update({
            f"{prefix}codebook_delta_bits": self.codebook_delta_bits,
            f"{bytes_key}_full_codebook": full_bytes,
            f"{bytes_key}_delta_codebook": len(payload),
            f"{prefix}codebook_bytes_full": cb_full,
            f"{prefix}codebook_bytes_delta": cb_delta,
            f"{prefix}codebook_bytes_reduction": cb_full / max(cb_delta, 1),
        })
        return len(payload)

    def measure_uplink_bytes(self, state: TrainState, key: jax.Array) -> int:
        return self.measure_round_bytes(state, key)[0]

    def measure_downlink_bytes(self, state: TrainState, key: jax.Array) -> int:
        return self.measure_round_bytes(state, key)[1]

    def measure_dense_bytes(self, state: TrainState, key: jax.Array) -> int:
        """The uncompressed cut tensor (either direction's dense baseline)."""
        batch = self.data.sample_batch(0, key, self.client_batch,
                                       **(self.batch_kwargs or {}))
        acts = self.executor.client_forward(state.params["client"], batch)
        if isinstance(acts, tuple):
            acts = acts[0]
        return int(acts.size * jnp.dtype(acts.dtype).itemsize)

    # ---- scheduled run -----------------------------------------------------
    def run(self, steps: int, key: jax.Array, log_every: int = 0,
            state: Optional[TrainState] = None,
            cursor: Optional[Dict[str, Any]] = None,
            on_round=None):
        """Run ``steps`` server updates through the scheduler.

        Returns (final state, history) where history holds one dict per
        server update: the step metrics (host-synced once, at the end of the
        run — not per round) plus the round's simulation fields. The full
        `Trace` is kept in ``self.last_trace``.

        ``state`` (optional) continues training from an existing
        `TrainState` instead of a fresh init — what the trace-driven
        autoscaler uses to re-run segments of one training run under
        successive (cohort, policy, compressor) plans
        (``federated/autoscale.py``). The caller's state is copied on
        entry: the executors' weighted steps donate their input buffers,
        and donation must never reach arrays the caller still owns.

        ``cursor`` / ``on_round`` are the crash-recovery hooks forwarded
        to `Scheduler.run` (sync policies only): a cursor resumes the
        virtual clock + scheduler RNG mid-run with ``steps`` as the
        absolute end index, and ``on_round(rd, cursor)`` fires after
        each completed round — `federated/recovery.py` snapshots there.
        """
        state = self.init_state(key) if state is None \
            else jax.tree.map(jnp.copy, state)
        # per-round step metrics stay on device; MetricsBuffer.flush is the
        # run's single blocking transfer (tests/test_obs.py counts it)
        metrics_buf = obs.MetricsBuffer()
        inj = make_injector(self.fault_plan)
        self._fault_log = {}
        self._screen_log = {}

        def execute(update_idx: int, participants: Sequence[Arrival],
                    weights: Sequence[float]) -> Dict:
            nonlocal state
            round_keys = {}
            parts = []
            for a in participants:
                rk = round_keys.setdefault(
                    a.version, jax.random.fold_in(key, a.version + 1))
                parts.append(self.client_batch_for(a.client, rk))
            if inj is not None and parts:
                participants, parts, weights, fl = self._screen_cohort(
                    inj, update_idx, participants, parts, weights)
                if fl:
                    self._fault_log[update_idx] = fl
                if not parts:
                    return {}   # round voided: below quorum, no update
            # AsyncBuffer flushes run the per-contribution staleness
            # weighting (FedBuff): each client's gradient split is
            # discounted by ITS OWN staleness before aggregation — not by
            # the cohort mean. Every async flush takes this path (even
            # all-fresh buffers) so the per-client quantization granularity
            # is consistent across a run instead of flipping with the
            # staleness draw. Synchronous policies pass weights=None and
            # the executor picks its fused/cohort semantics.
            is_async = isinstance(self.policy, AsyncBuffer)
            per_client = self.executor.per_client_layout(is_async)
            cut_in = self._cut_state_for(participants, state.params, parts,
                                         stacked=not per_client)
            state, metrics = self.executor.execute(
                state, parts, weights if is_async else None, cut_in)
            self._absorb_cut_state(participants,
                                   metrics.pop("cut_state", None),
                                   stacked=not per_client)
            metrics_buf.record(metrics)
            if log_every and update_idx % log_every == 0:
                # the only mid-run host sync, at the caller-chosen cadence
                logger.info("step %d: loss=%.4f", update_idx,
                            float(metrics.get("loss", 0.0)))  # fedlint: disable=host-sync-in-callback
            return metrics

        scheduler = Scheduler(fleet=self.fleet, policy=self.policy,
                              client_step_seconds=self.client_step_seconds,
                              server_step_seconds=self.server_step_seconds,
                              seed=self.seed,
                              backend=self.scheduler_backend,
                              topology=self.topology,
                              faults=self.fault_plan)
        uplink, downlink = self.measure_round_bytes(
            state, jax.random.fold_in(key, 0))
        trace = scheduler.run(
            steps, sample_cohort=lambda rd: sample_clients(
                self._rng, self.data.num_clients, self.cohort),
            uplink_bytes=uplink, downlink_bytes=downlink, execute=execute,
            placement=self.executor.place,
            wire_kinds=self.last_wire_kinds,
            cursor=cursor, on_round=on_round)
        dl = self.downlink
        trace.meta.update({
            "uplink_compressor": getattr(self.uplink, "spec",
                                         self.uplink.name),
            "downlink_compressor": "none" if dl is None
            else getattr(dl, "spec", dl.name),
            "uplink_bytes_per_client": uplink,
            "downlink_bytes_per_client": downlink,
            "warm_start": self.warm_start,
            "error_feedback": self.error_feedback,
            "stochastic_downlink": self.stochastic_downlink,
            "executor": self.executor.name,
            "executor_shards": getattr(self.executor, "num_shards", 1),
            "uplink_wire_kind": self.last_wire_kinds[0],
            "downlink_wire_kind": self.last_wire_kinds[1],
            "scheduler_backend": scheduler._resolve_backend(),
        })
        if self.topology is not None:
            trace.meta.update(self.topology.meta())
        trace.meta.update(self.last_codebook_meta)

        # one blocking transfer for the whole run
        host_metrics = metrics_buf.flush()
        history: List[Dict[str, float]] = []
        it = iter(host_metrics)
        for rec in trace:
            # merge server-side screening counters into the scheduler's
            # wire-level fault counters for the same round
            fl = self._fault_log.get(rec.round)
            if fl:
                rec.faults.update(fl)
            floats = next(it) if rec.metrics else {}
            rec.metrics = floats
            entry = dict(floats, step=rec.round, t_start=rec.t_start,
                         t_end=rec.t_end, uplink_bytes=rec.uplink_bytes,
                         downlink_bytes=rec.downlink_bytes,
                         participants=len(rec.participants),
                         dropped=len(rec.dropped))
            history.append(entry)
        # replay server-side screening verdicts onto the flight frames the
        # scheduler recorded at wire level (aggregated -> quarantined /
        # voided), so the emitted lifecycles show final outcomes
        if trace.flights and self._screen_log:
            flightlib.apply_screening(trace.flights, self._screen_log)
        self.last_trace = trace
        obs.log_trace(trace)   # no-op unless a recorder is configured
        if self.slo_monitor is not None:
            self.slo_monitor.check(trace)
        return state, history
