"""Federated runtime: client sampling, weighted aggregation, round drivers.

Implements the three algorithms the paper compares (§3, Table 1):

  * FEDAVG      — every sampled client runs H local SGD steps on the FULL
                  model, the server averages the deltas weighted by p_i.
  * SPLITFED    — per iteration, the cohort's activations hit the server,
                  gradients come back; equivalent to mini-batch SGD (§3).
  * FEDLITE     — SplitFed + grouped PQ + gradient correction at the cut.

SplitFed/FedLite iterations are realized by a single jitted train step over
the cohort's combined batch (see ``core/fedlite.py``) — mathematically
identical to per-client messaging with p_i-weighted server aggregation when
client batches are equal-sized, and exactly what the production mesh runs
(each data shard = one cohort). FedAvg keeps the explicit per-client local
loop since its local-step structure cannot be fused.
"""

from __future__ import annotations

import dataclasses
import operator
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fedlite import TrainState, make_train_step
from repro.data.synthetic import FederatedDataset
from repro.optim import Optimizer


def sample_clients(rng: np.random.Generator, num_clients: int,
                   cohort: int) -> np.ndarray:
    return rng.choice(num_clients, size=min(cohort, num_clients), replace=False)


def weighted_average(trees: Sequence[Any], weights: Sequence[float]):
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    return jax.tree.map(
        lambda *xs: sum(wi * x for wi, x in zip(w, xs)), *trees)


# ---------------------------------------------------------------------------
# FedAvg baseline
# ---------------------------------------------------------------------------

def fedavg_round(model, params, data: FederatedDataset, client_ids,
                 key: jax.Array, *, local_steps: int, batch: int,
                 lr: float, batch_kwargs: Optional[dict] = None):
    """One FedAvg round: H local SGD steps per client, weighted delta average.

    Returns (new_params, mean local loss). Local updates are plain SGD as in
    McMahan et al. (2017).
    """
    batch_kwargs = batch_kwargs or {}

    # jitted single local step (client batch sampled outside jit)
    @jax.jit
    def sgd_step(p, b):
        loss, grads = jax.value_and_grad(
            lambda q: model.loss(q, b, quantize=False)[0])(p)
        new_p = jax.tree.map(lambda x, g: x - lr * g, p, grads)
        return new_p, loss

    deltas, weights, losses = [], [], []
    for i, cid in enumerate(client_ids):
        p = params
        ck = jax.random.fold_in(key, int(cid))
        for s in range(local_steps):
            b = data.sample_batch(int(cid), jax.random.fold_in(ck, s), batch,
                                  **batch_kwargs)
            p, loss = sgd_step(p, b)
            losses.append(float(loss))
        deltas.append(jax.tree.map(operator.sub, p, params))
        weights.append(float(data.client_weights[int(cid)]))

    mean_delta = weighted_average(deltas, weights)
    new_params = jax.tree.map(operator.add, params, mean_delta)
    return new_params, float(np.mean(losses))


# ---------------------------------------------------------------------------
# SplitFed / FedLite trainer
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class FederatedTrainer:
    """Round driver for split-learning algorithms on a FederatedDataset.

    Each round samples a cohort, stacks the cohort's client batches into one
    global batch (cohort = leading batch dim) and runs the jitted split step.
    """
    model: Any
    optimizer: Optimizer
    data: FederatedDataset
    cohort: int
    client_batch: int
    quantize: bool = True
    batch_kwargs: Optional[dict] = None
    seed: int = 0

    def __post_init__(self):
        self._step = make_train_step(self.model, self.optimizer,
                                     quantize=self.quantize, donate=False)
        self._rng = np.random.default_rng(self.seed)

    def init_state(self, key: jax.Array) -> TrainState:
        return TrainState.create(self.model.init(key), self.optimizer)

    def cohort_batch(self, key: jax.Array) -> Dict[str, jax.Array]:
        ids = sample_clients(self._rng, self.data.num_clients, self.cohort)
        parts = [self.data.sample_batch(int(cid), jax.random.fold_in(key, int(cid)),
                                        self.client_batch,
                                        **(self.batch_kwargs or {}))
                 for cid in ids]
        return jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)

    def round(self, state: TrainState, key: jax.Array):
        batch = self.cohort_batch(key)
        return self._step(state, batch)

    def run(self, steps: int, key: jax.Array, log_every: int = 0):
        state = self.init_state(key)
        history: List[Dict[str, float]] = []
        for t in range(steps):
            state, metrics = self.round(state, jax.random.fold_in(key, t + 1))
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = t
            history.append(rec)
            if log_every and t % log_every == 0:
                print(f"step {t}: loss={rec.get('loss', 0):.4f}")
        return state, history
