"""Bit-packed wire codec for the cut-layer uplink (`QuantizedBatch`).

This is the byte layout that would actually cross the client->server WAN
link, so measured payload sizes replace/validate the analytic
``PQConfig.message_bits`` accounting:

    +--------+---------------------+------------------------------+
    | header | codebooks           | codes                        |
    | 24 B   | R*L*(d/q) * w bytes | ceil(N*q*b / 8) bytes        |
    +--------+---------------------+------------------------------+

  * header — magic ``FLW1``, version, codebook dtype, bits-per-code b,
    and the shape tuple (n, d, q, R, L); see ``_HEADER``.
  * codebooks — the (R, L, d/q) centroid tensor at wire width ``w``
    (fp16 by default; fp32/bf16 supported for lossless round-trips of
    higher-precision codebooks).
  * codes — all R*(q/R)*N cluster indices packed at b = ceil(log2 L)
    bits each into one little-endian bit stream (L=1 needs no codes).

The codec is bit-exact: ``decode_bytes(encode_bytes(qb))`` reproduces the
codes exactly and the codebooks exactly at the wire dtype, and
``encode_bytes`` of the decoded batch is byte-identical (idempotent).
The only lossy step is the explicit codebook dtype cast, which is the
transport decision the paper's φ accounts for — not a codec artifact.

Total size is ``wire_bits(cfg, n, d)`` bits, which differs from
``PQConfig.message_bits(n, d, phi_bits=w)`` only by the 24-byte header
plus <1 byte of code-stream padding (asserted in tests/test_wire.py).

Everything here is host-side numpy — the codec runs outside jit, on the
simulation's measurement path, never inside the train step.
"""

from __future__ import annotations

import struct
from typing import NamedTuple, Union

import numpy as np

from repro.core.quantizer import PQConfig, QuantizedBatch, bits_per_code

# magic, version, dtype code, bits-per-code, flags, n, d, q, R, L
_HEADER = struct.Struct("<4sBBBBIIHHI")
HEADER_BYTES = _HEADER.size  # 24
_MAGIC = b"FLW1"
_VERSION = 1

_DTYPE_CODES = {"float16": 1, "float32": 2, "bfloat16": 3}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import jax.numpy as jnp  # ml_dtypes-backed bfloat16 numpy dtype
        return np.dtype(jnp.bfloat16)
    return np.dtype(name)


def _dtype_name(dtype) -> str:
    name = np.dtype(dtype).name if np.dtype(dtype).name in _DTYPE_CODES \
        else str(dtype)
    if name not in _DTYPE_CODES:
        raise ValueError(f"unsupported wire codebook dtype {dtype!r}; "
                         f"supported: {sorted(_DTYPE_CODES)}")
    return name


class WireBatch(NamedTuple):
    """Decoded wire payload: everything the server needs to dequantize."""
    codes: np.ndarray      # (R, (q/R)*n) int32, values in [0, L)
    codebooks: np.ndarray  # (R, L, d/q) at the wire dtype
    n: int                 # activation vectors in the batch
    d: int                 # activation dim


# ---------------------------------------------------------------------------
# bit packing
# ---------------------------------------------------------------------------

def _pack_codes(codes: np.ndarray, bits: int) -> bytes:
    """Pack int codes at ``bits`` bits each, LSB-first, into a byte stream."""
    if bits == 0:
        return b""
    flat = codes.reshape(-1).astype(np.uint32)
    bitmat = (flat[:, None] >> np.arange(bits, dtype=np.uint32)) & 1
    return np.packbits(bitmat.astype(np.uint8).reshape(-1),
                       bitorder="little").tobytes()


def _unpack_codes(buf: bytes, count: int, bits: int) -> np.ndarray:
    if bits == 0:
        return np.zeros(count, np.int32)
    flat = np.unpackbits(np.frombuffer(buf, np.uint8),
                         count=count * bits, bitorder="little")
    weights = (np.uint32(1) << np.arange(bits, dtype=np.uint32))
    return (flat.reshape(count, bits).astype(np.uint32) * weights) \
        .sum(axis=1).astype(np.int32)


# ---------------------------------------------------------------------------
# encode / decode
# ---------------------------------------------------------------------------

def encode_bytes(qb: QuantizedBatch,
                 codebook_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Serialize a ``QuantizedBatch`` to the wire layout above.

    The geometry (n, d, q, R, L) is derived from the batch itself, so the
    payload is self-describing — ``decode_bytes`` needs no side channel.
    """
    codes = np.asarray(qb.codes)
    cbs = np.asarray(qb.codebooks)
    if codes.ndim != 2 or cbs.ndim != 3 or codes.shape[0] != cbs.shape[0]:
        raise ValueError(f"malformed QuantizedBatch: codes {codes.shape}, "
                         f"codebooks {cbs.shape}")
    r, m = codes.shape
    _, num_clusters, dsub = cbs.shape
    d = int(qb.dequantized.shape[-1])
    n = int(qb.dequantized.size // d)
    if r * m % max(n, 1) or (r * m // max(n, 1)) * dsub != d:
        raise ValueError(f"code/codebook geometry inconsistent with n={n}, d={d}")
    q = r * m // n

    name = _dtype_name(codebook_dtype)
    bits = bits_per_code(num_clusters)
    if codes.min(initial=0) < 0 or codes.max(initial=0) >= num_clusters:
        raise ValueError("codes out of range [0, L)")
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES[name], bits, 0,
                          n, d, q, r, num_clusters)
    return header + cbs.astype(_np_dtype(name)).tobytes() \
        + _pack_codes(codes, bits)


def decode_bytes(payload: bytes) -> WireBatch:
    """Parse a wire payload back into codes + codebooks, bit-exactly."""
    if len(payload) < HEADER_BYTES:
        raise ValueError(f"payload shorter than header ({len(payload)} B)")
    (magic, version, dtype_code, bits, _flags,
     n, d, q, r, num_clusters) = _HEADER.unpack_from(payload)
    if magic != _MAGIC:
        raise ValueError(f"bad magic {magic!r}")
    if version != _VERSION:
        raise ValueError(f"unsupported wire version {version}")
    dtype = _np_dtype(_CODE_DTYPES[dtype_code])
    dsub = d // q
    cb_bytes = r * num_clusters * dsub * dtype.itemsize
    m = (q // r) * n
    code_bytes = _code_stream_bytes(r * m, bits)
    expected = HEADER_BYTES + cb_bytes + code_bytes
    if len(payload) != expected:
        raise ValueError(f"payload is {len(payload)} B, expected {expected}")
    cbs = np.frombuffer(payload, dtype, count=r * num_clusters * dsub,
                        offset=HEADER_BYTES).reshape(r, num_clusters, dsub)
    codes = _unpack_codes(payload[HEADER_BYTES + cb_bytes:], r * m, bits) \
        .reshape(r, m)
    return WireBatch(codes=codes, codebooks=cbs, n=n, d=d)


def dequantize(wb: WireBatch) -> np.ndarray:
    """Server-side reconstruction z̃ = codebook gather, (n, d).

    Inverts the grouping of ``quantizer._to_groups``: group r holds
    subvector positions [r·q/R, (r+1)·q/R) of every example.
    """
    r, m = wb.codes.shape
    dsub = wb.codebooks.shape[-1]
    q = r * m // wb.n
    groups = wb.codebooks[np.arange(r)[:, None], wb.codes]  # (R, M, dsub)
    sub = groups.reshape(q, wb.n, dsub).transpose(1, 0, 2)
    return sub.reshape(wb.n, wb.d)


# ---------------------------------------------------------------------------
# analytic size accounting (must match len(encode_bytes(...)) exactly)
# ---------------------------------------------------------------------------

def _code_stream_bytes(num_codes: int, bits: int) -> int:
    return (num_codes * bits + 7) // 8


def wire_bits(cfg: PQConfig, n: int, d: int,
              codebook_dtype: Union[str, np.dtype] = "float16") -> int:
    """Exact wire payload size in bits for an (n, d) batch under ``cfg``.

    ``tests/test_wire.py`` asserts this equals ``8 * len(encode_bytes(...))``
    and stays within ``HEADER_BYTES*8 + 7`` bits of
    ``cfg.message_bits(n, d, phi_bits=<wire width>)``.
    """
    w = _np_dtype(_dtype_name(codebook_dtype)).itemsize * 8
    r, num_clusters, dsub = cfg.codebook_shape(d)
    cb_bits = r * num_clusters * dsub * w
    code_bits = 8 * _code_stream_bytes(cfg.num_codes(n), cfg.bits_per_code)
    return HEADER_BYTES * 8 + cb_bits + code_bits
