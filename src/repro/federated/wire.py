"""Versioned, tagged wire codec for cut-layer payloads (both directions).

Every payload that crosses the simulated client<->server WAN link is a
24-byte header followed by a kind-specific body:

    +--------+----------------------------------------------------------+
    | header | body (kind-specific, see below)                          |
    | 24 B   |                                                          |
    +--------+----------------------------------------------------------+

  header — magic ``FLW1``, **format version**, value dtype code, bit width,
  **payload kind**, and the geometry tuple (n, d, q, R, L); see ``_HEADER``.
  Version-2 payload kinds:

  * ``pq``     — FedLite's uplink message: (R, L, d/q) codebooks at the wire
                 dtype + all R·(q/R)·N cluster indices packed at
                 b = ceil(log2 L) bits (L=1 needs no codes).
  * ``dense``  — the uncompressed tensor (SplitFed activations, dense
                 downlink gradients): n·d values at the wire dtype.
  * ``sparse`` — top-k sparsification: nnz indices into the flattened
                 tensor packed at ceil(log2 n·d) bits, then either nnz
                 values at the wire dtype or — when the value dtype code is
                 0 — a complete *nested* payload carrying the values (how
                 ``chain:topk+scalarq`` lands on the wire).
  * ``scalar`` — uniform b-bit quantization: an 8-byte f32 (lo, scale)
                 range followed by n·d codes packed at b bits.

  Version-3 adds one kind (older kinds still ride version 2 so v2 decoders
  keep working — the kind is *version-gated*):

  * ``pq-delta`` — the codebook-reuse uplink: instead of L·(d/q)·R fresh
                 fp16 codebook entries, the payload carries uniformly
                 quantized *deltas* against the last acked codebook — an
                 8-byte f32 (lo, scale) range + R·L·(d/q) delta codes at
                 ``delta_bits`` (header ``bits`` field; default 8 → 2× on
                 the codebook component) + the packed cluster codes (width
                 derived from L). The codec is closed-loop (DPCM): the
                 encoder returns the reconstruction ``ref + deq(delta)``
                 and BOTH sides adopt it as the next acked reference, so
                 client and server never drift. Decoding requires the
                 reference (``decode_pq_delta``); the self-describing
                 ``decode_payload`` rejects it with a pointer to that API.

Unknown versions and kinds are rejected with a clear error — a stale or
foreign payload fails loudly instead of decoding as garbage. Version-1
payloads (the PR 2 codec, which only ever carried PQ uplink messages with a
zero flags byte where the kind now lives) still decode, as do all
version-2 payloads.

The codec is bit-exact: ``decode_payload(encode)`` reproduces every code,
index and range word exactly, values exactly at the wire dtype, and
re-encoding a decoded payload is byte-identical (idempotent; asserted in
tests). The only lossy step is the explicit value dtype cast (and, for
``pq-delta``, the explicit delta quantization — whose reconstruction is
itself bit-exactly reproduced on both sides), which is the transport
decision the paper's φ accounts for — not a codec artifact.

Everything here is host-side numpy — the codec runs outside jit, on the
simulation's measurement path, never inside the train step. (The b-bit
code packing also has a Pallas twin for on-device producers:
``repro.kernels.scalar_quant`` writes the identical little-endian LSB-first
stream when 32 % b == 0.)
"""

from __future__ import annotations

import struct
from typing import NamedTuple, Optional, Tuple, Union

import numpy as np

from repro.core import compressors as comps
from repro.core.quantizer import PQConfig, QuantizedBatch, bits_per_code

# magic, version, dtype code, bit width, payload kind, n, d, q, R, L
_HEADER = struct.Struct("<4sBBBBIIHHI")
HEADER_BYTES = _HEADER.size  # 24
_MAGIC = b"FLW1"
_VERSION = 2          # what the v2 kinds are written as (v2 decoders work)
_VERSION_DELTA = 3    # pq-delta is version-gated: introduced in v3
_SUPPORTED_VERSIONS = (1, 2, 3)

KIND_PQ = 0        # == the version-1 flags byte, so v1 payloads parse as pq
KIND_DENSE = 1
KIND_SPARSE = 2
KIND_SCALAR = 3
KIND_PQ_DELTA = 4  # version >= 3 only
_KIND_NAMES = {KIND_PQ: "pq", KIND_DENSE: "dense", KIND_SPARSE: "sparse",
               KIND_SCALAR: "scalar", KIND_PQ_DELTA: "pq-delta"}

# value dtype code 0 is reserved: in a sparse payload it means "the values
# are carried by a nested payload" (chained compressors)
_DTYPE_CODES = {"float16": 1, "float32": 2, "bfloat16": 3}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}
_NESTED = 0


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import jax.numpy as jnp  # ml_dtypes-backed bfloat16 numpy dtype
        return np.dtype(jnp.bfloat16)
    return np.dtype(name)


def _dtype_name(dtype) -> str:
    name = np.dtype(dtype).name if np.dtype(dtype).name in _DTYPE_CODES \
        else str(dtype)
    if name not in _DTYPE_CODES:
        raise ValueError(f"unsupported wire value dtype {dtype!r}; "
                         f"supported: {sorted(_DTYPE_CODES)}")
    return name


def _check_header(payload: bytes):
    if len(payload) < HEADER_BYTES:
        raise ValueError(f"payload shorter than header ({len(payload)} B)")
    fields = _HEADER.unpack_from(payload)
    magic, version, kind = fields[0], fields[1], fields[4]
    if magic != _MAGIC:
        raise ValueError(f"bad magic {magic!r}")
    if version not in _SUPPORTED_VERSIONS:
        raise ValueError(
            f"unsupported wire format version {version}; this codec "
            f"understands versions {_SUPPORTED_VERSIONS} — refusing to "
            f"decode a stale or foreign payload")
    if kind not in _KIND_NAMES:
        raise ValueError(f"unknown payload kind {kind}; known kinds: "
                         f"{sorted(_KIND_NAMES.values())}")
    if version == 1 and kind != KIND_PQ:
        raise ValueError(f"version-1 payloads are always pq; got kind {kind}")
    if kind == KIND_PQ_DELTA and version < _VERSION_DELTA:
        raise ValueError(
            f"pq-delta payloads require wire version >= {_VERSION_DELTA}; "
            f"got version {version}")
    return fields


def payload_kind(payload: bytes) -> str:
    """The kind tag of a payload ("pq" | "dense" | "sparse" | "scalar" |
    "pq-delta") from its header alone — what the byte ledger records
    without decoding the body. Nested chain payloads report the OUTERMOST
    stage, the one the receiver dispatches on first."""
    return _KIND_NAMES[_check_header(payload)[4]]


class WireBatch(NamedTuple):
    """Decoded pq payload: everything the server needs to dequantize."""
    codes: np.ndarray      # (R, (q/R)*n) int32, values in [0, L)
    codebooks: np.ndarray  # (R, L, d/q) at the wire dtype
    n: int                 # activation vectors in the batch
    d: int                 # activation dim


class Decoded(NamedTuple):
    """One parsed tagged payload (``inner`` set for chained sparse)."""
    kind: str                       # "pq" | "dense" | "sparse" | "scalar"
    n: int
    d: int
    bits: int                       # code/index bit width (kind-specific)
    arrays: dict                    # kind-specific numpy arrays
    inner: Optional["Decoded"] = None


# ---------------------------------------------------------------------------
# bit packing
# ---------------------------------------------------------------------------

def _pack_codes(codes: np.ndarray, bits: int) -> bytes:
    """Pack int codes at ``bits`` bits each, LSB-first, into a byte stream."""
    if bits == 0:
        return b""
    flat = codes.reshape(-1).astype(np.uint32)
    bitmat = (flat[:, None] >> np.arange(bits, dtype=np.uint32)) & 1
    return np.packbits(bitmat.astype(np.uint8).reshape(-1),
                       bitorder="little").tobytes()


def _unpack_codes(buf: bytes, count: int, bits: int) -> np.ndarray:
    if bits == 0:
        return np.zeros(count, np.int32)
    flat = np.unpackbits(np.frombuffer(buf, np.uint8),
                         count=count * bits, bitorder="little")
    weights = (np.uint32(1) << np.arange(bits, dtype=np.uint32))
    return (flat.reshape(count, bits).astype(np.uint32) * weights) \
        .sum(axis=1).astype(np.int32)


def _code_stream_bytes(num_codes: int, bits: int) -> int:
    return (num_codes * bits + 7) // 8


# ---------------------------------------------------------------------------
# pq payloads (the PR 2 codec, now kind-tagged)
# ---------------------------------------------------------------------------

def encode_bytes(qb: QuantizedBatch,
                 codebook_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Serialize a ``QuantizedBatch`` to a ``pq`` payload.

    The geometry (n, d, q, R, L) is derived from the batch itself, so the
    payload is self-describing — ``decode_bytes`` needs no side channel.
    """
    codes = np.asarray(qb.codes)
    cbs = np.asarray(qb.codebooks)
    if codes.ndim != 2 or cbs.ndim != 3 or codes.shape[0] != cbs.shape[0]:
        raise ValueError(f"malformed QuantizedBatch: codes {codes.shape}, "
                         f"codebooks {cbs.shape}")
    r, m = codes.shape
    _, num_clusters, dsub = cbs.shape
    d = int(qb.dequantized.shape[-1])
    n = int(qb.dequantized.size // d)
    if r * m % max(n, 1) or (r * m // max(n, 1)) * dsub != d:
        raise ValueError(f"code/codebook geometry inconsistent with n={n}, d={d}")
    q = r * m // n

    name = _dtype_name(codebook_dtype)
    bits = bits_per_code(num_clusters)
    if codes.min(initial=0) < 0 or codes.max(initial=0) >= num_clusters:
        raise ValueError("codes out of range [0, L)")
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES[name], bits, KIND_PQ,
                          n, d, q, r, num_clusters)
    return header + cbs.astype(_np_dtype(name)).tobytes() \
        + _pack_codes(codes, bits)


def decode_bytes(payload: bytes) -> WireBatch:
    """Parse a ``pq`` payload back into codes + codebooks, bit-exactly."""
    (_, _, dtype_code, bits, kind,
     n, d, q, r, num_clusters) = _check_header(payload)
    if kind != KIND_PQ:
        raise ValueError(
            f"expected a pq payload, got kind {_KIND_NAMES[kind]!r}; "
            f"use decode_payload for tagged payloads")
    dtype = _np_dtype(_CODE_DTYPES[dtype_code])
    dsub = d // q
    cb_bytes = r * num_clusters * dsub * dtype.itemsize
    m = (q // r) * n
    code_bytes = _code_stream_bytes(r * m, bits)
    expected = HEADER_BYTES + cb_bytes + code_bytes
    if len(payload) != expected:
        raise ValueError(f"payload is {len(payload)} B, expected {expected}")
    cbs = np.frombuffer(payload, dtype, count=r * num_clusters * dsub,
                        offset=HEADER_BYTES).reshape(r, num_clusters, dsub)
    codes = _unpack_codes(payload[HEADER_BYTES + cb_bytes:], r * m, bits) \
        .reshape(r, m)
    return WireBatch(codes=codes, codebooks=cbs, n=n, d=d)


def dequantize(wb: WireBatch) -> np.ndarray:
    """Server-side reconstruction z̃ = codebook gather, (n, d).

    Inverts the grouping of ``quantizer._to_groups``: group r holds
    subvector positions [r·q/R, (r+1)·q/R) of every example.
    """
    r, m = wb.codes.shape
    dsub = wb.codebooks.shape[-1]
    q = r * m // wb.n
    groups = wb.codebooks[np.arange(r)[:, None], wb.codes]  # (R, M, dsub)
    sub = groups.reshape(q, wb.n, dsub).transpose(1, 0, 2)
    return sub.reshape(wb.n, wb.d)


# ---------------------------------------------------------------------------
# pq-delta payloads (cross-round codebook reuse; version >= 3)
# ---------------------------------------------------------------------------

def encode_pq_delta(qb: QuantizedBatch, ref_codebooks: np.ndarray,
                    delta_bits: int = 8) -> Tuple[bytes, np.ndarray]:
    """Serialize a ``QuantizedBatch`` as quantized codebook *deltas* against
    the last acked codebook (closed-loop DPCM; see module docstring).

    ``ref_codebooks`` is the (R, L, d/q) f32 reference BOTH sides hold —
    the reconstruction of the previous round's payload, not the client's
    private fp32 codebook. Returns ``(payload, recon)`` where ``recon`` is
    the f32 codebook the decoder will reproduce bit-exactly: the caller
    must adopt it as the next round's reference.

    Codebook bytes: 8 (range) + ceil(R·L·(d/q)·delta_bits / 8), vs
    2·R·L·(d/q) for the fp16 ``pq`` kind — 2× at the default 8 bits.
    """
    if not 1 <= delta_bits <= 16:
        raise ValueError(f"delta_bits={delta_bits} must be in [1, 16]")
    codes = np.asarray(qb.codes)
    cbs = np.asarray(qb.codebooks, np.float32)
    ref = np.asarray(ref_codebooks, np.float32)
    if cbs.shape != ref.shape:
        raise ValueError(
            f"reference codebooks {ref.shape} do not match {cbs.shape}")
    r, m = codes.shape
    _, num_clusters, dsub = cbs.shape
    d = int(qb.dequantized.shape[-1])
    n = int(qb.dequantized.size // d)
    q = r * m // n

    delta = cbs - ref
    lo = float(delta.min(initial=0.0))
    hi = float(delta.max(initial=0.0))
    levels = (1 << delta_bits) - 1
    scale = (hi - lo) / levels
    scale = np.float32(scale if scale > 0 else 1.0)
    lo = np.float32(lo)
    dcodes = np.clip(np.round((delta - lo) / scale), 0, levels) \
        .astype(np.uint32)
    recon = ref + (lo + dcodes.astype(np.float32) * scale)

    bits = bits_per_code(num_clusters)
    if codes.min(initial=0) < 0 or codes.max(initial=0) >= num_clusters:
        raise ValueError("codes out of range [0, L)")
    header = _HEADER.pack(_MAGIC, _VERSION_DELTA, _DTYPE_CODES["float32"],
                          delta_bits, KIND_PQ_DELTA, n, d, q, r, num_clusters)
    rng = np.array([lo, scale], np.float32).tobytes()
    return (header + rng + _pack_codes(dcodes, delta_bits)
            + _pack_codes(codes, bits), recon)


def decode_pq_delta(payload: bytes, ref_codebooks: np.ndarray) -> WireBatch:
    """Parse a ``pq-delta`` payload against the acked reference codebooks.

    The returned ``codebooks`` are f32 and bit-exactly equal to the
    ``recon`` the encoder returned — the server must keep them as the next
    round's reference."""
    (_, _, _, delta_bits, kind,
     n, d, q, r, num_clusters) = _check_header(payload)
    if kind != KIND_PQ_DELTA:
        raise ValueError(
            f"expected a pq-delta payload, got kind {_KIND_NAMES[kind]!r}")
    ref = np.asarray(ref_codebooks, np.float32)
    dsub = d // q
    if ref.shape != (r, num_clusters, dsub):
        raise ValueError(f"reference codebooks {ref.shape} do not match the "
                         f"payload geometry ({r}, {num_clusters}, {dsub})")
    body = payload[HEADER_BYTES:]
    num_delta = r * num_clusters * dsub
    delta_bytes = _code_stream_bytes(num_delta, delta_bits)
    m = (q // r) * n
    bits = bits_per_code(num_clusters)
    expected = 8 + delta_bytes + _code_stream_bytes(r * m, bits)
    if len(body) != expected:
        raise ValueError(f"pq-delta body is {len(body)} B, expected {expected}")
    rng = np.frombuffer(body[:8], np.float32, count=2)
    dcodes = _unpack_codes(body[8:8 + delta_bytes], num_delta, delta_bits) \
        .astype(np.uint32)
    cbs = ref + (rng[0] + dcodes.astype(np.float32) * rng[1]) \
        .reshape(r, num_clusters, dsub)
    codes = _unpack_codes(body[8 + delta_bytes:], r * m, bits).reshape(r, m)
    return WireBatch(codes=codes, codebooks=cbs, n=n, d=d)


def pq_delta_wire_bits(cfg: PQConfig, n: int, d: int,
                       delta_bits: int = 8) -> int:
    """Exact ``pq-delta`` payload size in bits (analytic twin of
    ``wire_bits``; asserted against ``len(encode_pq_delta(...))`` in
    tests)."""
    r, num_clusters, dsub = cfg.codebook_shape(d)
    cb_bits = 8 * (8 + _code_stream_bytes(r * num_clusters * dsub,
                                          delta_bits))
    code_bits = 8 * _code_stream_bytes(cfg.num_codes(n), cfg.bits_per_code)
    return HEADER_BYTES * 8 + cb_bits + code_bits


# ---------------------------------------------------------------------------
# dense / sparse / scalar payloads
# ---------------------------------------------------------------------------

def encode_dense(values: np.ndarray, n: int, d: int,
                 dtype: Union[str, np.dtype] = "float32") -> bytes:
    name = _dtype_name(dtype)
    vals = np.asarray(values).reshape(n * d).astype(_np_dtype(name))
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES[name], 0, KIND_DENSE,
                          n, d, 0, 0, 0)
    return header + vals.tobytes()


def encode_sparse(indices: np.ndarray, n: int, d: int, *,
                  values: Optional[np.ndarray] = None,
                  inner: Optional[bytes] = None,
                  value_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Top-k payload: packed flat indices + values (or a nested payload)."""
    if (values is None) == (inner is None):
        raise ValueError("pass exactly one of values / inner")
    idx = np.asarray(indices).reshape(-1).astype(np.int64)
    if idx.size and (idx.min() < 0 or idx.max() >= n * d):
        raise ValueError(f"indices out of range [0, {n * d})")
    bits = comps.index_bits(n * d)
    if values is not None:
        name = _dtype_name(value_dtype)
        body = np.asarray(values).reshape(-1).astype(_np_dtype(name)).tobytes()
        dtype_code = _DTYPE_CODES[name]
    else:
        body = inner
        dtype_code = _NESTED
    header = _HEADER.pack(_MAGIC, _VERSION, dtype_code, bits, KIND_SPARSE,
                          n, d, 0, 0, idx.size)
    return header + _pack_codes(idx.astype(np.uint32), bits) + body


def encode_scalar(codes: np.ndarray, lo: float, scale: float, bits: int,
                  n: int, d: int) -> bytes:
    """Uniform b-bit payload: 8 B f32 (lo, scale) + packed codes."""
    c = np.asarray(codes).reshape(-1).astype(np.int64)
    if c.size != n * d:
        raise ValueError(f"expected {n * d} codes, got {c.size}")
    if c.size and (c.min() < 0 or c.max() >= (1 << bits)):
        raise ValueError(f"codes out of range [0, 2^{bits})")
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES["float32"], bits,
                          KIND_SCALAR, n, d, 0, 0, 0)
    rng = np.array([lo, scale], np.float32).tobytes()
    return header + rng + _pack_codes(c.astype(np.uint32), bits)


def decode_payload(payload: bytes) -> Decoded:
    """Parse any tagged payload (recursing into nested sparse values)."""
    (_, _, dtype_code, bits, kind, n, d, q, r, L) = _check_header(payload)
    body = payload[HEADER_BYTES:]
    if kind == KIND_PQ_DELTA:
        raise ValueError(
            "pq-delta payloads are not self-describing: decoding needs the "
            "acked reference codebooks — use decode_pq_delta(payload, ref)")
    if kind == KIND_PQ:
        wb = decode_bytes(payload)
        return Decoded("pq", n, d, bits,
                       {"codes": wb.codes, "codebooks": wb.codebooks})
    if kind == KIND_DENSE:
        dtype = _np_dtype(_CODE_DTYPES[dtype_code])
        expected = n * d * dtype.itemsize
        if len(body) != expected:
            raise ValueError(f"dense body is {len(body)} B, expected {expected}")
        vals = np.frombuffer(payload, dtype, count=n * d,
                             offset=HEADER_BYTES).reshape(n, d)
        return Decoded("dense", n, d, 0, {"values": vals})
    if kind == KIND_SPARSE:
        nnz = L
        idx_bytes = _code_stream_bytes(nnz, bits)
        idx = _unpack_codes(body[:idx_bytes], nnz, bits)
        rest = body[idx_bytes:]
        if dtype_code == _NESTED:
            inner = decode_payload(rest)
            return Decoded("sparse", n, d, bits, {"indices": idx},
                           inner=inner)
        dtype = _np_dtype(_CODE_DTYPES[dtype_code])
        if len(rest) != nnz * dtype.itemsize:
            raise ValueError(f"sparse values are {len(rest)} B, expected "
                             f"{nnz * dtype.itemsize}")
        vals = np.frombuffer(rest, dtype, count=nnz)
        return Decoded("sparse", n, d, bits,
                       {"indices": idx, "values": vals})
    if kind == KIND_SCALAR:
        expected = 8 + _code_stream_bytes(n * d, bits)
        if len(body) != expected:
            raise ValueError(
                f"scalar body is {len(body)} B, expected {expected}")
        rng = np.frombuffer(body[:8], np.float32, count=2)
        codes = _unpack_codes(body[8:], n * d, bits)
        return Decoded("scalar", n, d, bits,
                       {"codes": codes, "lo": rng[0], "scale": rng[1]})
    # _check_header already rejects unknown kinds; this guards the dispatch
    # above staying exhaustive when the next kind is added
    raise ValueError(f"no decoder arm for payload kind "
                     f"{_KIND_NAMES.get(kind, kind)!r}")


def reconstruct(dp: Decoded) -> np.ndarray:
    """Receiver-side reconstruction of a decoded payload, (n, d)."""
    if dp.kind == "pq":
        wb = WireBatch(codes=dp.arrays["codes"],
                       codebooks=dp.arrays["codebooks"], n=dp.n, d=dp.d)
        return dequantize(wb)
    if dp.kind == "dense":
        return np.asarray(dp.arrays["values"], np.float32)
    if dp.kind == "scalar":
        return (dp.arrays["lo"]
                + dp.arrays["codes"].astype(np.float32) * dp.arrays["scale"]
                ).reshape(dp.n, dp.d)
    # sparse
    vals = reconstruct(dp.inner).reshape(-1) if dp.inner is not None \
        else np.asarray(dp.arrays["values"], np.float32)
    flat = np.zeros(dp.n * dp.d, np.float32)
    flat[dp.arrays["indices"]] = vals
    return flat.reshape(dp.n, dp.d)


def encode_decoded(dp: Decoded,
                   value_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Re-serialize a decoded payload (round-trip idempotence helper).

    ``value_dtype`` applies only where the decoded arrays do not already
    carry a wire dtype (they always do after ``decode_payload``, so a
    re-encode of a decode is byte-identical)."""
    if dp.kind == "pq":
        qb = QuantizedBatch(
            dequantized=reconstruct(dp), codes=dp.arrays["codes"],
            codebooks=dp.arrays["codebooks"],
            distortion=np.zeros(()), residual=np.zeros(()))
        return encode_bytes(qb, dp.arrays["codebooks"].dtype)
    if dp.kind == "dense":
        return encode_dense(dp.arrays["values"], dp.n, dp.d,
                            dp.arrays["values"].dtype)
    if dp.kind == "scalar":
        return encode_scalar(dp.arrays["codes"], dp.arrays["lo"],
                             dp.arrays["scale"], dp.bits, dp.n, dp.d)
    if dp.inner is not None:
        return encode_sparse(dp.arrays["indices"], dp.n, dp.d,
                             inner=encode_decoded(dp.inner, value_dtype))
    return encode_sparse(dp.arrays["indices"], dp.n, dp.d,
                         values=dp.arrays["values"],
                         value_dtype=dp.arrays["values"].dtype)


# ---------------------------------------------------------------------------
# compressor -> wire bytes (the `CutCompressor.wire_payload` backend)
# ---------------------------------------------------------------------------

def _geometry(comp: comps.Compressed):
    d = int(comp.recon.shape[-1])
    return int(comp.recon.size // d), d


def encode_compressed(compressor: "comps.CutCompressor",
                      comp: comps.Compressed,
                      value_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Serialize a `Compressed` result to its tagged wire payload.

    Dense payloads keep the tensor's native dtype (lossless — they ARE the
    uncompressed baseline); sparse/pq values ride at ``value_dtype``.
    """
    n, d = _geometry(comp)
    if isinstance(compressor, comps.ChainCompressor):
        payloads = comp.payload
        executed = compressor.stages[:len(payloads)]
        # each stage's payload is encoded against the stage's OWN input
        # geometry: the full tensor for stage 0, the previous stage's
        # carrier (a flat (k, 1) vector) for every later stage
        geoms = []
        cur = (n, d)
        for payload in payloads:
            geoms.append(cur)
            if isinstance(payload, comps.SparsePayload):
                cur = (int(np.asarray(payload.values).size), 1)
            # dense (identity) stages pass their input through unchanged;
            # terminal payloads (pq/scalar) end the walk with the loop
        inner: Optional[bytes] = None
        for stage, payload, (sn, sd) in zip(reversed(executed),
                                            reversed(payloads),
                                            reversed(geoms)):
            inner = _encode_stage(stage, payload, sn, sd, inner, value_dtype)
        return inner
    return _encode_stage(compressor, comp.payload, n, d, None, value_dtype)


def _encode_stage(stage, payload, n, d, inner, value_dtype) -> bytes:
    if isinstance(payload, comps.DensePayload):
        if inner is not None:
            return inner   # the identity stage adds nothing to the wire
        vals = np.asarray(payload.values)
        return encode_dense(vals, n, d, vals.dtype)
    if isinstance(payload, QuantizedBatch):
        if inner is not None:
            raise ValueError("pq payloads are terminal; nothing may nest")
        return encode_bytes(payload, value_dtype)
    if isinstance(payload, comps.SparsePayload):
        if inner is not None:
            return encode_sparse(np.asarray(payload.indices), n, d,
                                 inner=inner)
        return encode_sparse(np.asarray(payload.indices), n, d,
                             values=np.asarray(payload.values),
                             value_dtype=value_dtype)
    if isinstance(payload, comps.ScalarPayload):
        if inner is not None:
            raise ValueError("scalar payloads are terminal; nothing may nest")
        # geometry comes from the stage's OWN input (the chain carrier when
        # nested, the full tensor when standalone), i.e. the codes shape
        codes = np.asarray(payload.codes)
        sd = codes.shape[-1] if codes.ndim >= 2 else 1
        return encode_scalar(codes, float(np.asarray(payload.lo)),
                             float(np.asarray(payload.scale)),
                             stage.bits, codes.size // sd, sd)
    raise TypeError(f"no wire encoding for payload type {type(payload)!r}")


# ---------------------------------------------------------------------------
# analytic size accounting (must match len(encode_...) exactly)
# ---------------------------------------------------------------------------

def wire_bits(cfg: PQConfig, n: int, d: int,
              codebook_dtype: Union[str, np.dtype] = "float16") -> int:
    """Exact pq payload size in bits for an (n, d) batch under ``cfg``.

    ``tests/test_wire.py`` asserts this equals ``8 * len(encode_bytes(...))``
    and stays within ``HEADER_BYTES*8 + 7`` bits of
    ``cfg.message_bits(n, d, phi_bits=<wire width>)``.
    """
    w = _np_dtype(_dtype_name(codebook_dtype)).itemsize * 8
    r, num_clusters, dsub = cfg.codebook_shape(d)
    cb_bits = r * num_clusters * dsub * w
    code_bits = 8 * _code_stream_bytes(cfg.num_codes(n), cfg.bits_per_code)
    return HEADER_BYTES * 8 + cb_bits + code_bits


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------
# Spans around the public codec entry points, applied by REASSIGNMENT rather
# than decorators: the encode_* function bodies (including decorator lists)
# are pinned by AST hash in repro/lint/wire_manifest.json, so a decorator
# would read as an encode-body change without a version bump. Wrapping the
# module attributes leaves the pinned FunctionDefs byte-identical; internal
# callers resolve the module globals at call time, so nested stages record
# nested spans. All wrappers are no-ops until `repro.obs.configure` runs.
from repro import obs as _obs

encode_bytes = _obs.instrument("wire.encode_bytes", cat="wire")(encode_bytes)
decode_bytes = _obs.instrument("wire.decode_bytes", cat="wire")(decode_bytes)
encode_pq_delta = _obs.instrument("wire.encode_pq_delta",
                                  cat="wire")(encode_pq_delta)
decode_pq_delta = _obs.instrument("wire.decode_pq_delta",
                                  cat="wire")(decode_pq_delta)
encode_compressed = _obs.instrument("wire.encode_compressed",
                                    cat="wire")(encode_compressed)
decode_payload = _obs.instrument("wire.decode_payload",
                                 cat="wire")(decode_payload)
