"""Versioned, tagged wire codec for cut-layer payloads (both directions).

Every payload that crosses the simulated client<->server WAN link is a
24-byte header followed by a kind-specific body:

    +--------+----------------------------------------------------------+
    | header | body (kind-specific, see below)                          |
    | 24 B   |                                                          |
    +--------+----------------------------------------------------------+

  header — magic ``FLW1``, **format version**, value dtype code, bit width,
  **payload kind**, and the geometry tuple (n, d, q, R, L); see ``_HEADER``.
  Version-2 payload kinds:

  * ``pq``     — FedLite's uplink message: (R, L, d/q) codebooks at the wire
                 dtype + all R·(q/R)·N cluster indices packed at
                 b = ceil(log2 L) bits (L=1 needs no codes).
  * ``dense``  — the uncompressed tensor (SplitFed activations, dense
                 downlink gradients): n·d values at the wire dtype.
  * ``sparse`` — top-k sparsification: nnz indices into the flattened
                 tensor packed at ceil(log2 n·d) bits, then either nnz
                 values at the wire dtype or — when the value dtype code is
                 0 — a complete *nested* payload carrying the values (how
                 ``chain:topk+scalarq`` lands on the wire).
  * ``scalar`` — uniform b-bit quantization: an 8-byte f32 (lo, scale)
                 range followed by n·d codes packed at b bits.

  Version-3 adds one kind (older kinds still ride version 2 so v2 decoders
  keep working — the kind is *version-gated*):

  * ``pq-delta`` — the codebook-reuse uplink: instead of L·(d/q)·R fresh
                 fp16 codebook entries, the payload carries uniformly
                 quantized *deltas* against the last acked codebook — an
                 8-byte f32 (lo, scale) range + R·L·(d/q) delta codes at
                 ``delta_bits`` (header ``bits`` field; default 8 → 2× on
                 the codebook component) + the packed cluster codes (width
                 derived from L). The codec is closed-loop (DPCM): the
                 encoder returns the reconstruction ``ref + deq(delta)``
                 and BOTH sides adopt it as the next acked reference, so
                 client and server never drift. Decoding requires the
                 reference (``decode_pq_delta``); the self-describing
                 ``decode_payload`` rejects it with a pointer to that API.

  Version-4 hardens the frame against a hostile wire (the chaos layer,
  ``federated/faults.py``):

  * every v4 frame ends with a 4-byte **CRC32 trailer** over header+body,
    so a bit-flipped, truncated, or duplicated payload is *detected* —
    decoders verify it before touching the body and raise
    `WireCorruptionError` instead of reconstructing garbage;
  * ``pq-delta`` bodies gain a leading u32 **lineage epoch** word: both
    ends of the closed DPCM loop count full-codebook resyncs, and a
    payload whose epoch does not match the receiver's reference raises
    `WireResyncError` — the signal to fall back to a full-codebook
    payload (`DeltaCodebookLink` implements the automatic resync).

Unknown versions and kinds are rejected with a clear error — a stale or
foreign payload fails loudly instead of decoding as garbage. Version-1
payloads (the PR 2 codec, which only ever carried PQ uplink messages with a
zero flags byte where the kind now lives) still decode, as do version-2
and version-3 payloads (no CRC, no epoch word — integrity errors in those
frames are detected only when a length check happens to catch them).
Decode failures raise the typed `WireError` hierarchy (all subclasses of
``ValueError``, so pre-v4 callers catching ``ValueError`` keep working):
`WireTruncationError` (shorter than declared), `WireCorruptionError`
(bad magic / CRC mismatch / inconsistent geometry), `WireVersionError`
(unsupported or version-gated), `WireResyncError` (pq-delta lineage).

The codec is bit-exact: ``decode_payload(encode)`` reproduces every code,
index and range word exactly, values exactly at the wire dtype, and
re-encoding a decoded payload is byte-identical (idempotent; asserted in
tests). The only lossy step is the explicit value dtype cast (and, for
``pq-delta``, the explicit delta quantization — whose reconstruction is
itself bit-exactly reproduced on both sides), which is the transport
decision the paper's φ accounts for — not a codec artifact.

Everything here is host-side numpy — the codec runs outside jit, on the
simulation's measurement path, never inside the train step. (The b-bit
code packing also has a Pallas twin for on-device producers:
``repro.kernels.scalar_quant`` writes the identical little-endian LSB-first
stream when 32 % b == 0.)
"""

from __future__ import annotations

import struct
import zlib
from typing import NamedTuple, Optional, Tuple, Union

import numpy as np

from repro.core import compressors as comps
from repro.core.quantizer import PQConfig, QuantizedBatch, bits_per_code

# magic, version, dtype code, bit width, payload kind, n, d, q, R, L
_HEADER = struct.Struct("<4sBBBBIIHHI")
HEADER_BYTES = _HEADER.size  # 24
_MAGIC = b"FLW1"
_VERSION = 4          # what every encoder writes (CRC32-trailed frames)
_VERSION_DELTA = 3    # pq-delta is version-gated: introduced in v3
_CRC_VERSION = 4      # frames at version >= 4 end with a CRC32 trailer
_SUPPORTED_VERSIONS = (1, 2, 3, 4)
_CRC = struct.Struct("<I")
CRC_BYTES = _CRC.size  # 4


class WireError(ValueError):
    """Base of the typed decode-failure hierarchy (a ``ValueError`` so
    pre-v4 call sites catching ``ValueError`` keep working)."""


class WireTruncationError(WireError):
    """The payload is shorter than its header/geometry declares."""


class WireCorruptionError(WireError):
    """The payload's content is inconsistent: bad magic, CRC32 mismatch,
    trailing garbage, or geometry that contradicts the body length."""


class WireVersionError(WireError):
    """Unsupported format version, or a kind used below its gate version."""


class WireResyncError(WireError):
    """The pq-delta closed loop lost lineage: the payload's epoch or the
    reference codebook geometry does not match the receiver's state. The
    cure is a full-codebook resync (see `DeltaCodebookLink`)."""

KIND_PQ = 0        # == the version-1 flags byte, so v1 payloads parse as pq
KIND_DENSE = 1
KIND_SPARSE = 2
KIND_SCALAR = 3
KIND_PQ_DELTA = 4  # version >= 3 only
_KIND_NAMES = {KIND_PQ: "pq", KIND_DENSE: "dense", KIND_SPARSE: "sparse",
               KIND_SCALAR: "scalar", KIND_PQ_DELTA: "pq-delta"}

# value dtype code 0 is reserved: in a sparse payload it means "the values
# are carried by a nested payload" (chained compressors)
_DTYPE_CODES = {"float16": 1, "float32": 2, "bfloat16": 3}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}
_NESTED = 0


def _np_dtype(name: str) -> np.dtype:
    if name == "bfloat16":
        import jax.numpy as jnp  # ml_dtypes-backed bfloat16 numpy dtype
        return np.dtype(jnp.bfloat16)
    return np.dtype(name)


def _dtype_name(dtype) -> str:
    name = np.dtype(dtype).name if np.dtype(dtype).name in _DTYPE_CODES \
        else str(dtype)
    if name not in _DTYPE_CODES:
        raise ValueError(f"unsupported wire value dtype {dtype!r}; "
                         f"supported: {sorted(_DTYPE_CODES)}")
    return name


def _check_header(payload: bytes):
    if len(payload) < HEADER_BYTES:
        raise WireTruncationError(
            f"payload shorter than header ({len(payload)} B)")
    fields = _HEADER.unpack_from(payload)
    magic, version, kind = fields[0], fields[1], fields[4]
    if magic != _MAGIC:
        raise WireCorruptionError(f"bad magic {magic!r}")
    if version not in _SUPPORTED_VERSIONS:
        raise WireVersionError(
            f"unsupported wire format version {version}; this codec "
            f"understands versions {_SUPPORTED_VERSIONS} — refusing to "
            f"decode a stale or foreign payload")
    if kind not in _KIND_NAMES:
        raise WireCorruptionError(f"unknown payload kind {kind}; known "
                                  f"kinds: {sorted(_KIND_NAMES.values())}")
    if version == 1 and kind != KIND_PQ:
        raise WireCorruptionError(
            f"version-1 payloads are always pq; got kind {kind}")
    if kind == KIND_PQ_DELTA and version < _VERSION_DELTA:
        raise WireVersionError(
            f"pq-delta payloads require wire version >= {_VERSION_DELTA}; "
            f"got version {version}")
    return fields


def _wire_dtype(code: int) -> np.dtype:
    """Map a header dtype code to a numpy dtype, typed-error on garbage."""
    name = _CODE_DTYPES.get(code)
    if name is None:
        raise WireCorruptionError(
            f"unknown wire dtype code {code}; known codes: "
            f"{sorted(_CODE_DTYPES)}")
    return _np_dtype(name)


def _check_pq_geometry(n: int, d: int, q: int, r: int) -> None:
    """Reject header geometry no encoder can produce (decoders divide by
    q and r, so garbage here must fail typed, not crash)."""
    if q == 0 or r == 0 or d % q or q % r or (r * ((q // r) * n)) % max(n, 1):
        raise WireCorruptionError(
            f"inconsistent pq geometry n={n} d={d} q={q} R={r}")


def _seal(frame: bytes) -> bytes:
    """Append the CRC32 trailer every v>=4 frame carries."""
    return frame + _CRC.pack(zlib.crc32(frame) & 0xFFFFFFFF)


def _open_payload(payload: bytes):
    """Header checks + (for v>=4) CRC32 verification.

    Returns ``(fields, frame)`` where ``frame`` is the payload with the
    CRC trailer stripped — the bytes every body length check runs
    against. Pre-v4 frames have no trailer and pass through unchanged.
    """
    fields = _check_header(payload)
    if fields[1] < _CRC_VERSION:
        return fields, payload
    if len(payload) < HEADER_BYTES + CRC_BYTES:
        raise WireTruncationError(
            f"v{fields[1]} payload too short for its CRC32 trailer "
            f"({len(payload)} B)")
    frame, trailer = payload[:-CRC_BYTES], payload[-CRC_BYTES:]
    if (zlib.crc32(frame) & 0xFFFFFFFF) != _CRC.unpack(trailer)[0]:
        raise WireCorruptionError(
            "CRC32 mismatch: the frame was corrupted or truncated in "
            "flight")
    return fields, frame


def payload_kind(payload: bytes) -> str:
    """The kind tag of a payload ("pq" | "dense" | "sparse" | "scalar" |
    "pq-delta") from its header alone — what the byte ledger records
    without decoding the body. Nested chain payloads report the OUTERMOST
    stage, the one the receiver dispatches on first."""
    return _KIND_NAMES[_check_header(payload)[4]]


class WireBatch(NamedTuple):
    """Decoded pq payload: everything the server needs to dequantize."""
    codes: np.ndarray      # (R, (q/R)*n) int32, values in [0, L)
    codebooks: np.ndarray  # (R, L, d/q) at the wire dtype
    n: int                 # activation vectors in the batch
    d: int                 # activation dim


class Decoded(NamedTuple):
    """One parsed tagged payload (``inner`` set for chained sparse)."""
    kind: str                       # "pq" | "dense" | "sparse" | "scalar"
    n: int
    d: int
    bits: int                       # code/index bit width (kind-specific)
    arrays: dict                    # kind-specific numpy arrays
    inner: Optional["Decoded"] = None


# ---------------------------------------------------------------------------
# bit packing
# ---------------------------------------------------------------------------

def _pack_codes(codes: np.ndarray, bits: int) -> bytes:
    """Pack int codes at ``bits`` bits each, LSB-first, into a byte stream."""
    if bits == 0:
        return b""
    flat = codes.reshape(-1).astype(np.uint32)
    bitmat = (flat[:, None] >> np.arange(bits, dtype=np.uint32)) & 1
    return np.packbits(bitmat.astype(np.uint8).reshape(-1),
                       bitorder="little").tobytes()


def _unpack_codes(buf: bytes, count: int, bits: int) -> np.ndarray:
    if bits == 0:
        return np.zeros(count, np.int32)
    flat = np.unpackbits(np.frombuffer(buf, np.uint8),
                         count=count * bits, bitorder="little")
    weights = (np.uint32(1) << np.arange(bits, dtype=np.uint32))
    return (flat.reshape(count, bits).astype(np.uint32) * weights) \
        .sum(axis=1).astype(np.int32)


def _code_stream_bytes(num_codes: int, bits: int) -> int:
    return (num_codes * bits + 7) // 8


# ---------------------------------------------------------------------------
# pq payloads (the PR 2 codec, now kind-tagged)
# ---------------------------------------------------------------------------

def encode_bytes(qb: QuantizedBatch,
                 codebook_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Serialize a ``QuantizedBatch`` to a ``pq`` payload.

    The geometry (n, d, q, R, L) is derived from the batch itself, so the
    payload is self-describing — ``decode_bytes`` needs no side channel.
    """
    codes = np.asarray(qb.codes)
    cbs = np.asarray(qb.codebooks)
    if codes.ndim != 2 or cbs.ndim != 3 or codes.shape[0] != cbs.shape[0]:
        raise ValueError(f"malformed QuantizedBatch: codes {codes.shape}, "
                         f"codebooks {cbs.shape}")
    r, m = codes.shape
    _, num_clusters, dsub = cbs.shape
    d = int(qb.dequantized.shape[-1])
    n = int(qb.dequantized.size // d)
    if r * m % max(n, 1) or (r * m // max(n, 1)) * dsub != d:
        raise ValueError(f"code/codebook geometry inconsistent with n={n}, d={d}")
    q = r * m // n

    name = _dtype_name(codebook_dtype)
    bits = bits_per_code(num_clusters)
    if codes.min(initial=0) < 0 or codes.max(initial=0) >= num_clusters:
        raise ValueError("codes out of range [0, L)")
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES[name], bits, KIND_PQ,
                          n, d, q, r, num_clusters)
    return _seal(header + cbs.astype(_np_dtype(name)).tobytes()
                 + _pack_codes(codes, bits))


def decode_bytes(payload: bytes) -> WireBatch:
    """Parse a ``pq`` payload back into codes + codebooks, bit-exactly."""
    ((_, _, dtype_code, bits, kind,
      n, d, q, r, num_clusters), frame) = _open_payload(payload)
    if kind != KIND_PQ:
        raise WireCorruptionError(
            f"expected a pq payload, got kind {_KIND_NAMES[kind]!r}; "
            f"use decode_payload for tagged payloads")
    _check_pq_geometry(n, d, q, r)
    dtype = _wire_dtype(dtype_code)
    dsub = d // q
    cb_bytes = r * num_clusters * dsub * dtype.itemsize
    m = (q // r) * n
    code_bytes = _code_stream_bytes(r * m, bits)
    expected = HEADER_BYTES + cb_bytes + code_bytes
    if len(frame) != expected:
        exc = WireTruncationError if len(frame) < expected \
            else WireCorruptionError
        raise exc(f"payload is {len(frame)} B, expected {expected}")
    cbs = np.frombuffer(frame, dtype, count=r * num_clusters * dsub,
                        offset=HEADER_BYTES).reshape(r, num_clusters, dsub)
    codes = _unpack_codes(frame[HEADER_BYTES + cb_bytes:], r * m, bits) \
        .reshape(r, m)
    return WireBatch(codes=codes, codebooks=cbs, n=n, d=d)


def dequantize(wb: WireBatch) -> np.ndarray:
    """Server-side reconstruction z̃ = codebook gather, (n, d).

    Inverts the grouping of ``quantizer._to_groups``: group r holds
    subvector positions [r·q/R, (r+1)·q/R) of every example.
    """
    r, m = wb.codes.shape
    dsub = wb.codebooks.shape[-1]
    q = r * m // wb.n
    groups = wb.codebooks[np.arange(r)[:, None], wb.codes]  # (R, M, dsub)
    sub = groups.reshape(q, wb.n, dsub).transpose(1, 0, 2)
    return sub.reshape(wb.n, wb.d)


# ---------------------------------------------------------------------------
# pq-delta payloads (cross-round codebook reuse; version >= 3)
# ---------------------------------------------------------------------------

def encode_pq_delta(qb: QuantizedBatch, ref_codebooks: np.ndarray,
                    delta_bits: int = 8, *,
                    epoch: int = 0) -> Tuple[bytes, np.ndarray]:
    """Serialize a ``QuantizedBatch`` as quantized codebook *deltas* against
    the last acked codebook (closed-loop DPCM; see module docstring).

    ``ref_codebooks`` is the (R, L, d/q) f32 reference BOTH sides hold —
    the reconstruction of the previous round's payload, not the client's
    private fp32 codebook. Returns ``(payload, recon)`` where ``recon`` is
    the f32 codebook the decoder will reproduce bit-exactly: the caller
    must adopt it as the next round's reference.

    ``epoch`` is the lineage tag (how many full-codebook resyncs the loop
    has seen); the decoder verifies it against its own count so a delta
    applied to the wrong reference generation raises `WireResyncError`
    instead of silently drifting.

    Codebook bytes: 8 (range) + ceil(R·L·(d/q)·delta_bits / 8), vs
    2·R·L·(d/q) for the fp16 ``pq`` kind — 2× at the default 8 bits.
    """
    if not 0 <= int(epoch) <= 0xFFFFFFFF:
        raise ValueError(f"epoch={epoch} does not fit the u32 lineage word")
    if not 1 <= delta_bits <= 16:
        raise ValueError(f"delta_bits={delta_bits} must be in [1, 16]")
    codes = np.asarray(qb.codes)
    cbs = np.asarray(qb.codebooks, np.float32)
    ref = np.asarray(ref_codebooks, np.float32)
    if cbs.shape != ref.shape:
        raise ValueError(
            f"reference codebooks {ref.shape} do not match {cbs.shape}")
    r, m = codes.shape
    _, num_clusters, dsub = cbs.shape
    d = int(qb.dequantized.shape[-1])
    n = int(qb.dequantized.size // d)
    q = r * m // n

    delta = cbs - ref
    lo = float(delta.min(initial=0.0))
    hi = float(delta.max(initial=0.0))
    levels = (1 << delta_bits) - 1
    scale = (hi - lo) / levels
    scale = np.float32(scale if scale > 0 else 1.0)
    lo = np.float32(lo)
    dcodes = np.clip(np.round((delta - lo) / scale), 0, levels) \
        .astype(np.uint32)
    recon = ref + (lo + dcodes.astype(np.float32) * scale)

    bits = bits_per_code(num_clusters)
    if codes.min(initial=0) < 0 or codes.max(initial=0) >= num_clusters:
        raise ValueError("codes out of range [0, L)")
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES["float32"],
                          delta_bits, KIND_PQ_DELTA, n, d, q, r, num_clusters)
    rng = np.array([lo, scale], np.float32).tobytes()
    return (_seal(header + _CRC.pack(int(epoch)) + rng
                  + _pack_codes(dcodes, delta_bits)
                  + _pack_codes(codes, bits)), recon)


def decode_pq_delta(payload: bytes, ref_codebooks: np.ndarray, *,
                    expected_epoch: Optional[int] = None) -> WireBatch:
    """Parse a ``pq-delta`` payload against the acked reference codebooks.

    The returned ``codebooks`` are f32 and bit-exactly equal to the
    ``recon`` the encoder returned — the server must keep them as the next
    round's reference. ``expected_epoch`` (the receiver's resync count) is
    verified against the payload's lineage word (v4+ frames): a mismatch
    raises `WireResyncError`, as does reference geometry that does not fit
    the payload — both mean the closed loop must resync with a full
    codebook. Version-3 frames carry no epoch word; the check is skipped.
    """
    ((_, version, _, delta_bits, kind,
      n, d, q, r, num_clusters), frame) = _open_payload(payload)
    if kind != KIND_PQ_DELTA:
        raise WireCorruptionError(
            f"expected a pq-delta payload, got kind {_KIND_NAMES[kind]!r}")
    _check_pq_geometry(n, d, q, r)
    ref = np.asarray(ref_codebooks, np.float32)
    dsub = d // q
    if ref.shape != (r, num_clusters, dsub):
        raise WireResyncError(
            f"reference codebooks {ref.shape} do not match the "
            f"payload geometry ({r}, {num_clusters}, {dsub}); the delta "
            f"loop lost lineage — request a full-codebook resync")
    body = frame[HEADER_BYTES:]
    num_delta = r * num_clusters * dsub
    delta_bytes = _code_stream_bytes(num_delta, delta_bits)
    m = (q // r) * n
    bits = bits_per_code(num_clusters)
    epoch_bytes = CRC_BYTES if version >= _CRC_VERSION else 0
    expected = epoch_bytes + 8 + delta_bytes + _code_stream_bytes(r * m, bits)
    if len(body) != expected:
        exc = WireTruncationError if len(body) < expected \
            else WireCorruptionError
        raise exc(f"pq-delta body is {len(body)} B, expected {expected}")
    if epoch_bytes:
        epoch = _CRC.unpack_from(body)[0]
        if expected_epoch is not None and epoch != int(expected_epoch):
            raise WireResyncError(
                f"pq-delta lineage epoch {epoch} does not match the "
                f"receiver's epoch {int(expected_epoch)}; the delta loop "
                f"lost lineage — request a full-codebook resync")
        body = body[epoch_bytes:]
    rng = np.frombuffer(body[:8], np.float32, count=2)
    dcodes = _unpack_codes(body[8:8 + delta_bytes], num_delta, delta_bits) \
        .astype(np.uint32)
    cbs = ref + (rng[0] + dcodes.astype(np.float32) * rng[1]) \
        .reshape(r, num_clusters, dsub)
    codes = _unpack_codes(body[8 + delta_bytes:], r * m, bits).reshape(r, m)
    return WireBatch(codes=codes, codebooks=cbs, n=n, d=d)


def pq_delta_epoch(payload: bytes) -> int:
    """The lineage epoch word of a v4+ ``pq-delta`` payload (header-only
    peek plus CRC verification; no body decode)."""
    (fields, frame) = _open_payload(payload)
    if fields[4] != KIND_PQ_DELTA:
        raise WireCorruptionError(
            f"expected a pq-delta payload, got kind "
            f"{_KIND_NAMES[fields[4]]!r}")
    if fields[1] < _CRC_VERSION:
        raise WireVersionError(
            f"v{fields[1]} pq-delta frames carry no lineage epoch word")
    if len(frame) < HEADER_BYTES + CRC_BYTES:
        raise WireTruncationError("pq-delta frame too short for its epoch")
    return _CRC.unpack_from(frame, HEADER_BYTES)[0]


def pq_delta_wire_bits(cfg: PQConfig, n: int, d: int,
                       delta_bits: int = 8) -> int:
    """Exact ``pq-delta`` payload size in bits (analytic twin of
    ``wire_bits``; asserted against ``len(encode_pq_delta(...))`` in
    tests). Includes the v4 epoch word and CRC32 trailer."""
    r, num_clusters, dsub = cfg.codebook_shape(d)
    cb_bits = 8 * (CRC_BYTES + 8 + _code_stream_bytes(
        r * num_clusters * dsub, delta_bits))
    code_bits = 8 * _code_stream_bytes(cfg.num_codes(n), cfg.bits_per_code)
    return HEADER_BYTES * 8 + cb_bits + code_bits + CRC_BYTES * 8


# ---------------------------------------------------------------------------
# dense / sparse / scalar payloads
# ---------------------------------------------------------------------------

def _legacy_frame(payload: bytes, version: int) -> bytes:
    """Downgrade a current-version payload to an older frame ``version``.

    Test/compat helper: strips the CRC trailer when targeting a pre-CRC
    version, drops the pq-delta epoch word when targeting v3, and rewrites
    the header's version byte. The result is what an encoder of that
    version would have produced for the same content."""
    fields, frame = _open_payload(payload)
    if not 1 <= version <= fields[1]:
        raise ValueError(f"cannot downgrade a v{fields[1]} frame to "
                         f"v{version}")
    body = frame[HEADER_BYTES:]
    if fields[4] == KIND_PQ_DELTA and version < _CRC_VERSION:
        body = body[CRC_BYTES:]   # v3 pq-delta bodies carry no epoch word
    header = _HEADER.pack(_MAGIC, version, *fields[2:])
    out = header + body
    return _seal(out) if version >= _CRC_VERSION else out


class DeltaCodebookLink:
    """One side of the closed-loop pq-delta codebook channel, with lineage.

    Both endpoints hold a ``DeltaCodebookLink``; each starts unsynced
    (``ref is None``, ``epoch == 0``). The sender's ``encode`` ships a full
    ``pq`` codebook payload whenever the link is unsynced (bumping the
    lineage epoch) and b-bit deltas tagged with the current epoch once
    synced. The receiver's ``decode`` verifies the tag against its own
    epoch — a mismatch (or reference-geometry mismatch) raises
    `WireResyncError`, after which the receiver calls ``request_resync()``
    and the runtime signals the sender to do the same. The handshake
    resets BOTH epochs to zero (full pq payloads carry no epoch word, so
    lockstep is re-established by resetting, not by counting), and the
    resync full codebook advances both sides to epoch 1 together."""

    def __init__(self, delta_bits: int = 8,
                 codebook_dtype: Union[str, np.dtype] = "float16"):
        self.delta_bits = int(delta_bits)
        self.codebook_dtype = codebook_dtype
        self.epoch = 0
        self.ref: Optional[np.ndarray] = None

    @property
    def synced(self) -> bool:
        return self.ref is not None

    def request_resync(self) -> None:
        """Drop the reference and reset the lineage: the next payload must
        be a full codebook, which re-establishes epoch lockstep."""
        self.ref = None
        self.epoch = 0

    # -- sender side ------------------------------------------------------
    def encode(self, qb: QuantizedBatch) -> bytes:
        if self.ref is None:
            payload = encode_bytes(qb, self.codebook_dtype)
            # the decoder's reference is the wire-dtype round-trip of the
            # codebooks; adopt the identical f32 values without a decode
            name = _dtype_name(self.codebook_dtype)
            self.ref = np.asarray(qb.codebooks).astype(_np_dtype(name)) \
                .astype(np.float32)
            self.epoch += 1
            return payload
        payload, recon = encode_pq_delta(qb, self.ref, self.delta_bits,
                                         epoch=self.epoch)
        self.ref = recon
        return payload

    # -- receiver side ----------------------------------------------------
    def decode(self, payload: bytes) -> WireBatch:
        if payload_kind(payload) == "pq":
            wb = decode_bytes(payload)
            self.ref = np.asarray(wb.codebooks, np.float32)
            self.epoch += 1
            return wb
        if self.ref is None:
            raise WireResyncError(
                "received a pq-delta payload on an unsynced link; a full "
                "codebook must arrive first")
        wb = decode_pq_delta(payload, self.ref, expected_epoch=self.epoch)
        self.ref = wb.codebooks
        return wb


def encode_dense(values: np.ndarray, n: int, d: int,
                 dtype: Union[str, np.dtype] = "float32") -> bytes:
    name = _dtype_name(dtype)
    vals = np.asarray(values).reshape(n * d).astype(_np_dtype(name))
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES[name], 0, KIND_DENSE,
                          n, d, 0, 0, 0)
    return _seal(header + vals.tobytes())


def encode_sparse(indices: np.ndarray, n: int, d: int, *,
                  values: Optional[np.ndarray] = None,
                  inner: Optional[bytes] = None,
                  value_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Top-k payload: packed flat indices + values (or a nested payload)."""
    if (values is None) == (inner is None):
        raise ValueError("pass exactly one of values / inner")
    idx = np.asarray(indices).reshape(-1).astype(np.int64)
    if idx.size and (idx.min() < 0 or idx.max() >= n * d):
        raise ValueError(f"indices out of range [0, {n * d})")
    bits = comps.index_bits(n * d)
    if values is not None:
        name = _dtype_name(value_dtype)
        body = np.asarray(values).reshape(-1).astype(_np_dtype(name)).tobytes()
        dtype_code = _DTYPE_CODES[name]
    else:
        body = inner
        dtype_code = _NESTED
    header = _HEADER.pack(_MAGIC, _VERSION, dtype_code, bits, KIND_SPARSE,
                          n, d, 0, 0, idx.size)
    return _seal(header + _pack_codes(idx.astype(np.uint32), bits) + body)


def encode_scalar(codes: np.ndarray, lo: float, scale: float, bits: int,
                  n: int, d: int) -> bytes:
    """Uniform b-bit payload: 8 B f32 (lo, scale) + packed codes."""
    c = np.asarray(codes).reshape(-1).astype(np.int64)
    if c.size != n * d:
        raise ValueError(f"expected {n * d} codes, got {c.size}")
    if c.size and (c.min() < 0 or c.max() >= (1 << bits)):
        raise ValueError(f"codes out of range [0, 2^{bits})")
    header = _HEADER.pack(_MAGIC, _VERSION, _DTYPE_CODES["float32"], bits,
                          KIND_SCALAR, n, d, 0, 0, 0)
    rng = np.array([lo, scale], np.float32).tobytes()
    return _seal(header + rng + _pack_codes(c.astype(np.uint32), bits))


def decode_payload(payload: bytes) -> Decoded:
    """Parse any tagged payload (recursing into nested sparse values)."""
    ((_, _, dtype_code, bits, kind, n, d, q, r, L),
     frame) = _open_payload(payload)
    body = frame[HEADER_BYTES:]
    if kind == KIND_PQ_DELTA:
        raise WireError(
            "pq-delta payloads are not self-describing: decoding needs the "
            "acked reference codebooks — use decode_pq_delta(payload, ref)")
    if kind == KIND_PQ:
        wb = decode_bytes(payload)
        return Decoded("pq", n, d, bits,
                       {"codes": wb.codes, "codebooks": wb.codebooks})
    if kind == KIND_DENSE:
        dtype = _wire_dtype(dtype_code)
        expected = n * d * dtype.itemsize
        if len(body) != expected:
            exc = WireTruncationError if len(body) < expected \
                else WireCorruptionError
            raise exc(f"dense body is {len(body)} B, expected {expected}")
        vals = np.frombuffer(frame, dtype, count=n * d,
                             offset=HEADER_BYTES).reshape(n, d)
        return Decoded("dense", n, d, 0, {"values": vals})
    if kind == KIND_SPARSE:
        nnz = L
        idx_bytes = _code_stream_bytes(nnz, bits)
        if len(body) < idx_bytes:
            raise WireTruncationError(
                f"sparse indices are {len(body)} B, expected {idx_bytes}")
        idx = _unpack_codes(body[:idx_bytes], nnz, bits)
        rest = body[idx_bytes:]
        if dtype_code == _NESTED:
            inner = decode_payload(rest)
            return Decoded("sparse", n, d, bits, {"indices": idx},
                           inner=inner)
        dtype = _wire_dtype(dtype_code)
        if len(rest) != nnz * dtype.itemsize:
            exc = WireTruncationError if len(rest) < nnz * dtype.itemsize \
                else WireCorruptionError
            raise exc(f"sparse values are {len(rest)} B, expected "
                      f"{nnz * dtype.itemsize}")
        vals = np.frombuffer(rest, dtype, count=nnz)
        return Decoded("sparse", n, d, bits,
                       {"indices": idx, "values": vals})
    if kind == KIND_SCALAR:
        expected = 8 + _code_stream_bytes(n * d, bits)
        if len(body) != expected:
            exc = WireTruncationError if len(body) < expected \
                else WireCorruptionError
            raise exc(f"scalar body is {len(body)} B, expected {expected}")
        rng = np.frombuffer(body[:8], np.float32, count=2)
        codes = _unpack_codes(body[8:], n * d, bits)
        return Decoded("scalar", n, d, bits,
                       {"codes": codes, "lo": rng[0], "scale": rng[1]})
    # _check_header already rejects unknown kinds; this guards the dispatch
    # above staying exhaustive when the next kind is added
    raise WireError(f"no decoder arm for payload kind "
                    f"{_KIND_NAMES.get(kind, kind)!r}")


def reconstruct(dp: Decoded) -> np.ndarray:
    """Receiver-side reconstruction of a decoded payload, (n, d)."""
    if dp.kind == "pq":
        wb = WireBatch(codes=dp.arrays["codes"],
                       codebooks=dp.arrays["codebooks"], n=dp.n, d=dp.d)
        return dequantize(wb)
    if dp.kind == "dense":
        return np.asarray(dp.arrays["values"], np.float32)
    if dp.kind == "scalar":
        return (dp.arrays["lo"]
                + dp.arrays["codes"].astype(np.float32) * dp.arrays["scale"]
                ).reshape(dp.n, dp.d)
    # sparse
    vals = reconstruct(dp.inner).reshape(-1) if dp.inner is not None \
        else np.asarray(dp.arrays["values"], np.float32)
    flat = np.zeros(dp.n * dp.d, np.float32)
    flat[dp.arrays["indices"]] = vals
    return flat.reshape(dp.n, dp.d)


def encode_decoded(dp: Decoded,
                   value_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Re-serialize a decoded payload (round-trip idempotence helper).

    ``value_dtype`` applies only where the decoded arrays do not already
    carry a wire dtype (they always do after ``decode_payload``, so a
    re-encode of a decode is byte-identical)."""
    if dp.kind == "pq":
        qb = QuantizedBatch(
            dequantized=reconstruct(dp), codes=dp.arrays["codes"],
            codebooks=dp.arrays["codebooks"],
            distortion=np.zeros(()), residual=np.zeros(()))
        return encode_bytes(qb, dp.arrays["codebooks"].dtype)
    if dp.kind == "dense":
        return encode_dense(dp.arrays["values"], dp.n, dp.d,
                            dp.arrays["values"].dtype)
    if dp.kind == "scalar":
        return encode_scalar(dp.arrays["codes"], dp.arrays["lo"],
                             dp.arrays["scale"], dp.bits, dp.n, dp.d)
    if dp.inner is not None:
        return encode_sparse(dp.arrays["indices"], dp.n, dp.d,
                             inner=encode_decoded(dp.inner, value_dtype))
    return encode_sparse(dp.arrays["indices"], dp.n, dp.d,
                         values=dp.arrays["values"],
                         value_dtype=dp.arrays["values"].dtype)


# ---------------------------------------------------------------------------
# compressor -> wire bytes (the `CutCompressor.wire_payload` backend)
# ---------------------------------------------------------------------------

def _geometry(comp: comps.Compressed):
    d = int(comp.recon.shape[-1])
    return int(comp.recon.size // d), d


def encode_compressed(compressor: "comps.CutCompressor",
                      comp: comps.Compressed,
                      value_dtype: Union[str, np.dtype] = "float16") -> bytes:
    """Serialize a `Compressed` result to its tagged wire payload.

    Dense payloads keep the tensor's native dtype (lossless — they ARE the
    uncompressed baseline); sparse/pq values ride at ``value_dtype``.
    """
    n, d = _geometry(comp)
    if isinstance(compressor, comps.ChainCompressor):
        payloads = comp.payload
        executed = compressor.stages[:len(payloads)]
        # each stage's payload is encoded against the stage's OWN input
        # geometry: the full tensor for stage 0, the previous stage's
        # carrier (a flat (k, 1) vector) for every later stage
        geoms = []
        cur = (n, d)
        for payload in payloads:
            geoms.append(cur)
            if isinstance(payload, comps.SparsePayload):
                cur = (int(np.asarray(payload.values).size), 1)
            # dense (identity) stages pass their input through unchanged;
            # terminal payloads (pq/scalar) end the walk with the loop
        inner: Optional[bytes] = None
        for stage, payload, (sn, sd) in zip(reversed(executed),
                                            reversed(payloads),
                                            reversed(geoms)):
            inner = _encode_stage(stage, payload, sn, sd, inner, value_dtype)
        return inner
    return _encode_stage(compressor, comp.payload, n, d, None, value_dtype)


def _encode_stage(stage, payload, n, d, inner, value_dtype) -> bytes:
    if isinstance(payload, comps.DensePayload):
        if inner is not None:
            return inner   # the identity stage adds nothing to the wire
        vals = np.asarray(payload.values)
        return encode_dense(vals, n, d, vals.dtype)
    if isinstance(payload, QuantizedBatch):
        if inner is not None:
            raise ValueError("pq payloads are terminal; nothing may nest")
        return encode_bytes(payload, value_dtype)
    if isinstance(payload, comps.SparsePayload):
        if inner is not None:
            return encode_sparse(np.asarray(payload.indices), n, d,
                                 inner=inner)
        return encode_sparse(np.asarray(payload.indices), n, d,
                             values=np.asarray(payload.values),
                             value_dtype=value_dtype)
    if isinstance(payload, comps.ScalarPayload):
        if inner is not None:
            raise ValueError("scalar payloads are terminal; nothing may nest")
        # geometry comes from the stage's OWN input (the chain carrier when
        # nested, the full tensor when standalone), i.e. the codes shape
        codes = np.asarray(payload.codes)
        sd = codes.shape[-1] if codes.ndim >= 2 else 1
        return encode_scalar(codes, float(np.asarray(payload.lo)),
                             float(np.asarray(payload.scale)),
                             stage.bits, codes.size // sd, sd)
    raise TypeError(f"no wire encoding for payload type {type(payload)!r}")


# ---------------------------------------------------------------------------
# analytic size accounting (must match len(encode_...) exactly)
# ---------------------------------------------------------------------------

def wire_bits(cfg: PQConfig, n: int, d: int,
              codebook_dtype: Union[str, np.dtype] = "float16") -> int:
    """Exact pq payload size in bits for an (n, d) batch under ``cfg``.

    ``tests/test_wire.py`` asserts this equals ``8 * len(encode_bytes(...))``
    and stays within ``HEADER_BYTES*8 + 7`` bits of
    ``cfg.message_bits(n, d, phi_bits=<wire width>)``.
    """
    w = _np_dtype(_dtype_name(codebook_dtype)).itemsize * 8
    r, num_clusters, dsub = cfg.codebook_shape(d)
    cb_bits = r * num_clusters * dsub * w
    code_bits = 8 * _code_stream_bytes(cfg.num_codes(n), cfg.bits_per_code)
    return HEADER_BYTES * 8 + cb_bits + code_bits + CRC_BYTES * 8


# ---------------------------------------------------------------------------
# observability
# ---------------------------------------------------------------------------
# Spans around the public codec entry points, applied by REASSIGNMENT rather
# than decorators: the encode_* function bodies (including decorator lists)
# are pinned by AST hash in repro/lint/wire_manifest.json, so a decorator
# would read as an encode-body change without a version bump. Wrapping the
# module attributes leaves the pinned FunctionDefs byte-identical; internal
# callers resolve the module globals at call time, so nested stages record
# nested spans. All wrappers are no-ops until `repro.obs.configure` runs.
from repro import obs as _obs

encode_bytes = _obs.instrument("wire.encode_bytes", cat="wire")(encode_bytes)
decode_bytes = _obs.instrument("wire.decode_bytes", cat="wire")(decode_bytes)
encode_pq_delta = _obs.instrument("wire.encode_pq_delta",
                                  cat="wire")(encode_pq_delta)
decode_pq_delta = _obs.instrument("wire.decode_pq_delta",
                                  cat="wire")(decode_pq_delta)
encode_compressed = _obs.instrument("wire.encode_compressed",
                                    cat="wire")(encode_compressed)
decode_payload = _obs.instrument("wire.decode_payload",
                                 cat="wire")(decode_payload)
