"""Deterministic fault injection for the federated runtime (chaos layer).

A `FaultPlan` is a frozen, seeded description of every failure the
simulator should inject — wire corruption, mid-round client crashes,
async arrival jitter, edge-aggregator outage windows, a server kill, and
poisoned (non-finite) client updates. A `FaultInjector` turns the plan
into concrete decisions.

Determinism is the whole design:

* Every decision is a **stateless hash** (splitmix64 finalizer over
  ``np.uint64``) of ``(plan.seed, salt, context keys...)`` — the
  injector never touches the scheduler's ``numpy`` RNG stream. A run
  with an all-zero-rate plan is therefore *bitwise identical* to a run
  with no plan at all, and the vector/heapq scheduler backends stay
  parity-exact under faults: both recompute the same decision from the
  same keys instead of sharing a consumable stream.
* Crash decisions key on ``(round, client, attempt)`` for sync rounds
  and ``(stream seq, client, attempt)`` for async dispatches, so a
  client's fate is a pure function of *where* in the run it happens —
  independent of cohort order, backend, or checkpoint/resume splits.
* The scalar path is the vectorized path on singleton arrays; there is
  no separately-maintained scalar implementation to drift.

Failure semantics implemented by the runtime around this module:

* **Crash + retry**: a crashed client re-dispatches after an exponential
  backoff (``backoff_base_s * backoff_factor**attempt`` in *virtual*
  time); after ``max_retries`` failed retries it is permanently dropped
  for the round. Every retry re-sends the downlink, and those wasted
  bytes hit the byte ledger under ``retry_downlink/<kind>``.
* **Corruption / poison**: flagged uplink contributions are screened at
  aggregation — corrupt payloads must raise a typed `WireError`
  (CRC32-backed for wire v4), non-finite updates are caught by a real
  finiteness check — and quarantined; the eq.-5 λ-correction and
  staleness weights renormalize over survivors. A round whose surviving
  fraction falls below ``quorum_fraction`` is **voided** (no update).
* **Edge outage**: clients homed to a down edge re-home to the
  next-nearest live edge for the window (`TwoTierTopology`).
* **Server kill**: `ServerKilled` is raised at the top of the configured
  round; ``federated.recovery.run_with_recovery`` restores the latest
  crash-consistent checkpoint and replays, bitwise-identically.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = [
    "FaultPlan", "FaultInjector", "ServerKilled", "DEFAULT_CHAOS",
    "make_injector",
]


class ServerKilled(RuntimeError):
    """The injected server failure: raised between rounds, caught by
    ``run_with_recovery`` which restores the latest checkpoint."""

    def __init__(self, round_index: int):
        super().__init__(f"server killed at round {round_index}")
        self.round_index = int(round_index)


# ---------------------------------------------------------------------------
# stateless hashing (splitmix64 finalizer over uint64)
# ---------------------------------------------------------------------------

_GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_U64 = np.uint64

# decision domains — distinct salts keep draws independent per site
SALT_CRASH = 1         # sync crash: (round, client, attempt)
SALT_CRASH_ASYNC = 2   # async crash: (stream seq, client, attempt)
SALT_REORDER = 3       # async jitter gate: (client, seq)
SALT_REORDER_MAG = 4   # async jitter magnitude: (client, seq)
SALT_CORRUPT = 5       # uplink corruption gate: (round, client)
SALT_CORRUPT_MODE = 6  # corruption mode pick: (round, client)
SALT_CORRUPT_POS = 7   # corruption position: (round, client)
SALT_POISON = 8        # poisoned update gate: (round, client)


def _mix64(z: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer (vectorized; uint64 wraparound is the point)."""
    with np.errstate(over="ignore"):
        z = (z ^ (z >> _U64(30))) * _U64(0xBF58476D1CE4E5B9)
        z = (z ^ (z >> _U64(27))) * _U64(0x94D049BB133111EB)
    return z ^ (z >> _U64(31))


def _hash_keys(seed: int, keys) -> np.ndarray:
    """Fold ``keys`` (scalars or broadcastable uint arrays) into one
    uint64 hash; pure function of the values, so scalar and vectorized
    call sites agree bit-for-bit."""
    h = _U64(seed)
    for k in keys:
        k = np.asarray(k, np.uint64)
        with np.errstate(over="ignore"):
            h = _mix64((h + _GOLDEN) ^ k)
    return h


def _uniform_from(h: np.ndarray) -> np.ndarray:
    """Map uint64 hashes to doubles in [0, 1) (53 mantissa bits)."""
    return (h >> _U64(11)).astype(np.float64) * (2.0 ** -53)


# ---------------------------------------------------------------------------
# the plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Seeded, declarative description of every fault to inject.

    All rates are per-decision probabilities in [0, 1]; zero disables the
    fault entirely (and leaves the run bitwise-identical to a no-plan
    run). ``edge_outages`` entries are ``(edge_index, t0, t1)`` windows
    in scheduler virtual time, half-open ``[t0, t1)`` against the round's
    start time. ``server_kill_rounds`` are absolute round indices;
    ``poison_clients`` are always-poisoned client ids on top of the
    rate-drawn ones."""

    seed: int = 0
    # client mid-round crashes + bounded retry
    crash_rate: float = 0.0
    max_retries: int = 2
    backoff_base_s: float = 0.5
    backoff_factor: float = 2.0
    # uplink payload corruption (bit-flip / truncate / duplicate)
    corrupt_rate: float = 0.0
    corrupt_modes: Tuple[str, ...] = ("bitflip", "truncate", "duplicate")
    # poisoned (non-finite) client updates
    poison_rate: float = 0.0
    poison_clients: Tuple[int, ...] = ()
    # async arrival reordering
    reorder_rate: float = 0.0
    reorder_max_s: float = 0.0
    # edge-aggregator outage windows (TwoTierTopology)
    edge_outages: Tuple[Tuple[int, float, float], ...] = ()
    # server kill between rounds
    server_kill_rounds: Tuple[int, ...] = ()
    # aggregation quorum: void the round below this surviving fraction
    quorum_fraction: float = 0.5

    def __post_init__(self):
        for name in ("crash_rate", "corrupt_rate", "poison_rate",
                     "reorder_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} outside [0, 1]")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not 0.0 <= self.quorum_fraction <= 1.0:
            raise ValueError("quorum_fraction outside [0, 1]")
        if not self.corrupt_modes:
            raise ValueError("corrupt_modes must be non-empty")

    @property
    def any_faults(self) -> bool:
        """Whether the plan can inject anything at all — the runtime uses
        this to keep zero-fault code paths byte-identical to PR 8."""
        return bool(self.crash_rate > 0 or self.corrupt_rate > 0
                    or self.poison_rate > 0 or self.poison_clients
                    or self.reorder_rate > 0 or self.edge_outages
                    or self.server_kill_rounds)

    def disarm_kills_through(self, round_index: int) -> "FaultPlan":
        """The plan after a recovery at ``round_index``: kills at or
        before that round have fired (a restarted server does not re-die
        on the same round)."""
        return dataclasses.replace(
            self, server_kill_rounds=tuple(
                k for k in self.server_kill_rounds if k > round_index))


DEFAULT_CHAOS = FaultPlan(
    seed=0, crash_rate=0.05, corrupt_rate=0.05, poison_rate=0.03,
    reorder_rate=0.2, reorder_max_s=2.0, quorum_fraction=0.5)
"""The fixed-seed default schedule CI's chaos-smoke step runs."""


# ---------------------------------------------------------------------------
# the injector
# ---------------------------------------------------------------------------

class FaultInjector:
    """Turns a `FaultPlan` into concrete per-site decisions.

    Stateless by construction (every method is a pure function of the
    plan and its arguments); safe to recreate at any point — including
    after a checkpoint restore — without changing any decision."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan

    def _uniform(self, *keys) -> np.ndarray:
        return _uniform_from(_hash_keys(self.plan.seed, keys))

    # -- client crashes + bounded retry ----------------------------------
    def _crash_attempts(self, salt: int, key, cids) -> np.ndarray:
        """Number of *leading* crashed attempts per client, in
        ``[0, max_retries + 1]``; a value above ``max_retries`` means the
        retry budget is exhausted (permanent drop for this round)."""
        cids = np.asarray(cids)
        crashes = np.zeros(cids.shape, np.int64)
        leading = np.ones(cids.shape, bool)
        for a in range(self.plan.max_retries + 1):
            u = self._uniform(salt, key, cids, a)
            crashed = leading & (u < self.plan.crash_rate)
            crashes += crashed
            leading = crashed
        return crashes

    def crash_attempts_sync(self, round_index: int, cids) -> np.ndarray:
        return self._crash_attempts(SALT_CRASH, round_index, cids)

    def crash_attempts_async(self, seqs, cids) -> np.ndarray:
        """Async crashes key on the dispatch stream index, which is
        identical across backends (heap seq == vector stream index)."""
        seqs = np.asarray(seqs)
        cids = np.asarray(cids)
        crashes = np.zeros(cids.shape, np.int64)
        leading = np.ones(cids.shape, bool)
        for a in range(self.plan.max_retries + 1):
            u = self._uniform(SALT_CRASH_ASYNC, seqs, cids, a)
            crashed = leading & (u < self.plan.crash_rate)
            crashes += crashed
            leading = crashed
        return crashes

    def retry_overhead(self, crashes: np.ndarray,
                       dl_comp: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Virtual-time overhead of the crashed attempts.

        ``dl_comp`` is each client's (downlink + compute) seconds — the
        time a crashed attempt wastes before the crash is noticed and the
        retry dispatched after backoff. Returns ``(extra_seconds, gone)``
        where ``gone`` marks clients whose retry budget is exhausted.
        The accumulation order ``(extra + dl_comp) + backoff_a`` is fixed
        so both scheduler backends produce bit-identical doubles."""
        crashes = np.asarray(crashes)
        dl_comp = np.asarray(dl_comp, np.float64)
        extra = np.zeros(np.broadcast(crashes, dl_comp).shape, np.float64)
        for a in range(self.plan.max_retries + 1):
            backoff = self.plan.backoff_base_s * self.plan.backoff_factor ** a
            extra = np.where(crashes > a, (extra + dl_comp) + backoff, extra)
        return extra, crashes > self.plan.max_retries

    @staticmethod
    def extra_downlinks(crashes: np.ndarray, gone: np.ndarray) -> np.ndarray:
        """Downlink re-sends beyond the first dispatch: one per crash,
        except the terminal crash of a budget-exhausted client (no retry
        follows it)."""
        crashes = np.asarray(crashes)
        return np.where(np.asarray(gone), crashes - 1, crashes)

    # -- uplink corruption / poisoning -----------------------------------
    def corrupt_mask(self, round_index: int, cids) -> np.ndarray:
        if self.plan.corrupt_rate <= 0:
            return np.zeros(np.asarray(cids).shape, bool)
        return self._uniform(SALT_CORRUPT, round_index, cids) \
            < self.plan.corrupt_rate

    def poison_mask(self, round_index: int, cids) -> np.ndarray:
        cids = np.asarray(cids)
        mask = np.zeros(cids.shape, bool)
        if self.plan.poison_rate > 0:
            mask |= self._uniform(SALT_POISON, round_index, cids) \
                < self.plan.poison_rate
        if self.plan.poison_clients:
            mask |= np.isin(cids, np.asarray(self.plan.poison_clients))
        return mask

    def corrupt_payload(self, payload: bytes, round_index: int,
                        cid: int) -> bytes:
        """Deterministically damage a wire payload (the decode side must
        raise a typed ``WireError`` — asserted by the canary check)."""
        modes = self.plan.corrupt_modes
        mode = modes[int(_hash_keys(self.plan.seed,
                                    (SALT_CORRUPT_MODE, round_index, cid))
                         % np.uint64(len(modes)))]
        pos = int(_hash_keys(self.plan.seed,
                             (SALT_CORRUPT_POS, round_index, cid)))
        if mode == "bitflip":
            buf = bytearray(payload)
            bit = pos % (len(buf) * 8)
            buf[bit // 8] ^= 1 << (bit % 8)
            return bytes(buf)
        if mode == "truncate":
            return payload[:pos % max(len(payload), 1)]
        if mode == "duplicate":
            return payload + payload
        raise ValueError(f"unknown corrupt mode {mode!r}")

    # -- async arrival reordering ----------------------------------------
    def reorder_jitter(self, cids, seqs) -> np.ndarray:
        """Per-dispatch relay jitter in seconds (0 where the gate does
        not fire). Adding 0.0 to a positive arrival time is bitwise-safe,
        so the zero-rate case stays parity-exact without branching."""
        cids = np.asarray(cids)
        if self.plan.reorder_rate <= 0 or self.plan.reorder_max_s <= 0:
            return np.zeros(cids.shape, np.float64)
        gate = self._uniform(SALT_REORDER, cids, seqs) \
            < self.plan.reorder_rate
        mag = self._uniform(SALT_REORDER_MAG, cids, seqs)
        return np.where(gate, mag * self.plan.reorder_max_s, 0.0)

    # -- topology / server -----------------------------------------------
    def down_edges(self, t_start: float) -> Tuple[int, ...]:
        """Edges inside an outage window at the round's start time."""
        return tuple(int(e) for (e, t0, t1) in self.plan.edge_outages
                     if t0 <= t_start < t1)

    def server_killed(self, round_index: int) -> bool:
        return round_index in self.plan.server_kill_rounds


def make_injector(plan: Optional[FaultPlan]) -> Optional[FaultInjector]:
    """None-propagating constructor: no plan (or an all-quiet plan) means
    no injector, which keeps every fault branch in the scheduler and
    trainer byte-identical to the pre-chaos code path."""
    if plan is None or not plan.any_faults:
        return None
    return FaultInjector(plan)
