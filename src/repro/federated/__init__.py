"""Heterogeneous federated simulation subsystem.

The paper's value proposition is what FedLite saves on the client->server
uplink; this package *measures* it instead of only asserting it
analytically. Four layers, composed by `FederatedTrainer`:

  runtime.py    — the algorithm drivers (FedAvg / SplitFed / FedLite round
                  logic, cohort sampling — uniform or p_i-weighted — and
                  weighted aggregation). `FederatedTrainer.run` executes
                  training rounds through the scheduler below.
  wire.py       — the bit-packed wire codec for the cut-layer payload: a
                  `QuantizedBatch` becomes header + fp16 codebooks +
                  ceil(log2 L)-bit packed codes. Bit-exact round-trip;
                  measured byte counts validate `PQConfig.message_bits`.
  network.py    — `ClientProfile` (asymmetric bandwidth, latency, compute
                  multiplier, dropout) and fleet samplers: `uniform_fleet`
                  (the IDEAL pre-subsystem clients), `lognormal_fleet`
                  (heavy-tailed broadband), `mobile_fleet` (flaky mobile
                  mixture).
  scheduler.py  — a virtual-clock event loop dispatching rounds under a
                  participation policy: `FullSync`, `DropSlowestK`,
                  `Deadline`, or FedBuff-style `AsyncBuffer` with
                  staleness-weighted aggregation.
  trace.py      — per-round `RoundRecord`s (simulated wall-clock, measured
                  uplink/downlink bytes, stragglers dropped, staleness)
                  collected into a `Trace` with time-to-target /
                  bytes-to-target reductions.

The ideal fleet + `FullSync` reproduces the original synchronous
simulation bitwise (tests/test_scheduler.py); heterogeneous fleets turn
the same trainer into the paper-§5 trade-off harness driven by
``benchmarks/bench_network.py``.
"""

from repro.federated.network import (
    IDEAL,
    ClientProfile,
    lognormal_fleet,
    mobile_fleet,
    uniform_fleet,
)
from repro.federated.runtime import (
    FederatedTrainer,
    fedavg_round,
    run_fedavg,
    sample_clients,
    weighted_average,
)
from repro.federated.scheduler import (
    AsyncBuffer,
    Deadline,
    DropSlowestK,
    FullSync,
    Scheduler,
)
from repro.federated.trace import RoundRecord, Trace
from repro.federated import wire

__all__ = [
    "AsyncBuffer", "ClientProfile", "Deadline", "DropSlowestK",
    "FederatedTrainer", "FullSync", "IDEAL", "RoundRecord", "Scheduler",
    "Trace", "fedavg_round", "lognormal_fleet", "mobile_fleet",
    "run_fedavg", "sample_clients", "uniform_fleet", "weighted_average",
    "wire",
]
