"""Heterogeneous federated simulation subsystem.

The paper's value proposition is what FedLite saves on the wire; this
package *measures* it — in BOTH directions — instead of only asserting it
analytically. Compression is direction-agnostic: each side of the cut runs
a codec from the `core/compressors.py` registry (``none`` | ``pq`` |
``topk`` | ``scalarq`` | ``chain:...``), configured per direction on
`FederatedTrainer` (``uplink_compressor`` / ``downlink_compressor`` spec
strings) or on `ArchConfig` for the big archs. The uplink default is the
paper's grouped PQ; the downlink default is dense — the measured traffic
that motivated the stack, since the cut-layer *gradient* dominates
bytes-on-the-wire once the uplink is PQ-compressed.

Eight layers, composed by `FederatedTrainer`:

  runtime.py    — the algorithm drivers (FedAvg / SplitFed / FedLite round
                  logic, cohort sampling — uniform or p_i-weighted — and
                  weighted aggregation). `FederatedTrainer.run` executes
                  training rounds through the scheduler below; it installs
                  the downlink codec into the model's VJP and measures
                  both directions' payloads through the wire codec.
  wire.py       — the versioned tagged wire codec: every payload is a 24 B
                  header + a kind-specific body (``pq`` codebooks+packed
                  codes, ``dense`` tensors, ``sparse`` top-k indices with
                  optionally *nested* values, ``scalar`` b-bit packed
                  codes). Bit-exact round-trips; unknown versions/kinds are
                  rejected loudly; measured bytes validate the compressors'
                  ``analytic_bits``.
  network.py    — `ClientProfile` (asymmetric bandwidth, latency, compute
                  multiplier, dropout), the struct-of-arrays `ClientFleet`
                  population (one float64 column per field — the
                  representation the vectorized scheduler core runs on),
                  and fleet samplers (all returning `ClientFleet`):
                  `uniform_fleet` (the IDEAL pre-subsystem clients),
                  `lognormal_fleet` (heavy-tailed broadband),
                  `mobile_fleet` (flaky mobile mixture).
  scheduler.py  — a virtual-clock round core dispatching rounds under a
                  participation policy: `FullSync`, `DropSlowestK`,
                  `Deadline`, or FedBuff-style `AsyncBuffer` whose
                  staleness weights are applied per contribution
                  (``core/fedlite.make_weighted_step``). Two backends —
                  the vectorized array core and the per-arrival heapq
                  reference — produce bitwise-identical traces (see
                  "Scaling fleets" below).
  topology.py   — `TwoTierTopology`: a hierarchical aggregation tier
                  (clients -> edge aggregators -> server) with clients
                  k-means-clustered by simulated location; edges
                  pre-combine their cluster's uplinks so the
                  parameter-server link carries one payload per edge.
  trace.py      — per-round `RoundRecord`s (simulated wall-clock, measured
                  uplink AND downlink bytes, stragglers dropped, staleness,
                  per-participant shard placement) collected into a `Trace`
                  with per-direction time/bytes-to-target reductions,
                  windowed controller signals (straggler ``tail_ratio``,
                  ``drop_rate``, ``bytes_per_round``, ``loss_slope``) and
                  run-level codec metadata in ``Trace.meta``.
  executor.py   — the cohort execution engine (see "Scaling cohorts across
                  devices" below): ``stacked`` | ``mesh`` backends mapping
                  each server update's per-client math onto devices.
  autoscale.py  — `TraceAutoscaler`: a deterministic controller that turns
                  the trace's windowed signals into (cohort, policy,
                  downlink codec) moves, plus ``autoscale_run`` driving a
                  training run in plan-sized segments.
  faults.py     — the chaos layer: a seeded, declarative `FaultPlan`
                  (client crashes, wire corruption, poisoned gradients,
                  arrival reordering, edge outages, server kills) whose
                  draws come from a stateless hash stream — never the
                  training or scheduler RNGs (see "Fault tolerance").
  recovery.py   — crash-consistent runtime snapshots + `run_with_recovery`,
                  the segmented driver that survives `ServerKilled` by
                  restoring the latest snapshot from disk.

Scaling cohorts across devices
------------------------------
The scheduler decides WHO participates; the `CohortExecutor` decides WHERE
their math runs. ``FederatedTrainer(executor="stacked")`` (default) is the
historical single-device path — synchronous cohorts fuse into one stacked
batch, async flushes run the per-contribution weighted step — and stays
bitwise-identical to the pre-engine trainer. ``executor="mesh"`` (or
``"mesh(shards=N)"``) shards the cohort over the ``clients`` axis of a 1-D
device mesh (``launch/mesh.make_clients_mesh``; on CPU CI a real 2-4-shard
mesh via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``):
client-major batches, PRNG keys, error-feedback memories and `CutState`s
are placed with ``NamedSharding(mesh, P("clients"))``, each shard computes
its local clients' gradients, and the weighted combine crosses shards once
as an explicit psum (``core/fedlite.make_mesh_step``). All four policies
execute unchanged on either backend; traces record every participant's
shard. Round wall-clock then scales with the shard count
(``benchmarks/bench_network.py --executor mesh`` measures it), which is
what lets cohort size become an autoscaler knob rather than a hardware
ceiling.

Scaling fleets (the vectorized scheduler core)
----------------------------------------------
The executor scales WHERE cohort math runs; the vectorized scheduler core
scales HOW MANY clients the simulation can hold. Populations are
struct-of-arrays (`ClientFleet`: one float64 column per profile field), so
a million-client fleet is five arrays, not 10^6 boxed Python objects, and
a round is a handful of whole-cohort array ops: one gather-and-add chain
for every participant's ``downlink + compute + uplink`` round trip, one
vectorized Bernoulli draw for dropouts, one stable argsort of arrival
times, and a policy *prefix cut* on the sorted vector
(``Policy.split_vector``). Python touches a round only at its boundary.
``Scheduler(backend=...)`` selects the core: ``"vector"``, ``"heapq"``
(the original per-arrival event loop, kept as the reference
implementation), or ``"auto"`` (vector whenever the policy supports it —
all four built-ins do; custom split-only policies fall back to heapq).
Both backends evaluate the same IEEE-double expressions in the same
association order and share one RNG draw sequence, so their traces are
*bitwise identical* — asserted across fleet x policy x cohort in
tests/test_fleet_scale.py, which makes the heapq backend a standing
parity oracle for the array core. At 10^6 clients / 10^4-client cohorts
the vector core runs a round in tens of milliseconds
(``benchmarks/bench_network.py --fleet-scale`` measures it, and CI
asserts the budget).

Hierarchical aggregation rides the same scale: ``TwoTierTopology``
(``topology.py``) k-means-clusters clients by simulated location into
edge aggregators; each edge pre-combines its cluster's surviving uplinks
(aggregation is linear, so sync-policy pre-combination is semantically
free) and ships ONE edge payload over the edge->server hop, decongesting
the parameter-server link. Round end under a topology is when the last
participating edge's payload lands. Async buffers relay store-and-forward
(per-contribution staleness must survive, so no pre-combination — every
contribution pays the edge hop instead). The trace's byte ledger splits
tiers — ``edge_uplink/<kind>`` vs ``server_uplink/<kind>`` — and
`Trace.tier_totals` / `Trace.tier_bytes_per_round` expose where bytes
flow; `TraceAutoscaler` observes both tier signals.

Cross-round state (all default-off): `FederatedTrainer` can additionally
carry cut-layer state across scheduler rounds — PQ codebook warm-start
(``warm_start=True``: Lloyd resumes from last round's codebook at
``PQConfig.warm_iters`` iterations; cohort-global under the stacked
policies, per-client under `AsyncBuffer`), per-client error-feedback
memory (``error_feedback=True``), stochastic downlink rounding
(``stochastic_downlink=True``) and ``pq-delta`` codebook wire encoding
(``codebook_delta_bits``: the uplink ships b-bit quantized codebook deltas
against the acked reference; ``wire.encode_pq_delta``).

Fault tolerance
---------------
`faults.py` turns the simulation into a chaos harness: a frozen
`FaultPlan` declares per-round fault rates and the `FaultInjector` draws
every fault from a stateless splitmix64 hash keyed on (plan seed, fault
kind, round/stream-seq, client) — never from the training or scheduler
RNGs, so a zero-fault plan is bitwise-identical to no plan at all and
backend trace parity holds under any plan. What the runtime survives:

  * **Client crashes mid-round** — the scheduler retries with
    exponential backoff in virtual time (both backends, identical
    IEEE association); each retry re-pays the downlink, ledgered under
    ``retry_downlink/<kind>``; past ``max_retries`` the client is
    permanently dropped from the round.
  * **Wire corruption** — every v4 frame carries a CRC32 trailer, and
    ANY malformed payload raises from the typed `WireError` hierarchy
    (``WireTruncationError`` / ``WireCorruptionError`` /
    ``WireVersionError`` / ``WireResyncError``; fuzzed in
    tests/test_wire.py). The server decodes a per-round canary through
    the real codec and quarantines corrupt contributions; the
    ``corrupt_undetected`` counter must stay 0 (canary assertion).
  * **Poisoned gradients** — non-finite contributions are quarantined by
    a finiteness screen before aggregation; eq.-5 λ-correction and
    staleness weights renormalize over the survivors. A round whose
    survivor fraction falls below ``quorum_fraction`` is VOIDED (no
    server update).
  * **pq-delta lineage breaks** — delta codebook payloads carry an epoch
    word; an epoch or reference-geometry mismatch raises
    `WireResyncError` and `wire.DeltaCodebookLink` falls back to a full
    codebook resync handshake.
  * **Edge-aggregator outages** — `TwoTierTopology.rehome` re-homes a
    down edge's clients to the next-nearest live edge for the outage
    window (``rehomed``/``edges_down`` counters).
  * **Server kills between rounds** — `ServerKilled` unwinds the run;
    `recovery.run_with_recovery` restores the latest crash-consistent
    snapshot FROM DISK (atomic tmp+rename writes, sha256 manifest
    written last, verified on restore — `checkpointing/checkpoint.py`)
    and resumes from the scheduler cursor bitwise-identically
    (tests/test_faults.py pins final params AND trace).

Every fault and recovery lands in the observability stack: per-round
``RoundRecord.faults`` counters (``Trace.fault_totals()`` for the run),
``fault.*`` events on the obs log, and the run inspector's ``--faults``
table. ``benchmarks/bench_network.py --chaos`` sweeps fault rate x
policy and asserts graceful degradation: target loss still reached at
the baseline fault rate, retry byte inflation bounded, canary clean.

The ideal fleet + `FullSync` + dense downlink reproduces the original
synchronous simulation bitwise (tests/test_scheduler.py,
tests/test_compressors.py); heterogeneous fleets and per-direction codecs
turn the same trainer into the paper-§5 trade-off harness driven by
``benchmarks/bench_network.py`` (``--downlink`` sweeps the gradient codec).

Observability
-------------
The whole subsystem is permanently instrumented through `repro.obs`,
organized as three layers — each built on the one below, all free when
no recorder is configured:

  * **Layer 1 — spans + sync-free metrics (how long, how often).**
    ``obs.configure(run=...)`` installs a recorder; from then on
    `Scheduler.run` records every round twice — once on the *host
    wall-clock* lane (what the process spent, jit dispatch only, never a
    device sync) and once on the *scheduler virtual-clock* lane (what
    the simulated fleet spent) — alongside executor place/execute
    phases, wire encode/decode, Lloyd/kmeans and checkpoint I/O spans;
    autoscaler plan moves and straggler cuts are instant events on the
    same log. Jitted steps return metrics as device arrays through aux
    pytrees (``obs.counter`` / ``obs.gauge`` / ``obs.histogram`` are
    jit-safe) into an `obs.MetricsBuffer`, converted with ONE
    ``jax.device_get`` at the end of the run — tests/test_obs.py counts
    transfers to hold instrumented runs to "no more than
    uninstrumented". Export with ``Recorder.write_jsonl`` (append-only
    JSONL, the durable artifact; ``obs.read_jsonl_tolerant`` re-reads
    logs whose writer was killed mid-line) and ``Recorder.write_perfetto``
    (Chrome trace_event JSON; the two lanes render as two processes at
    https://ui.perfetto.dev).
  * **Layer 2 — the byte ledger (how many bytes, which wire).** Each
    `RoundRecord` carries a ``ledger`` mapping
    ``"<direction>/<wire-kind>"`` to measured bytes
    (``Trace.ledger_totals()`` for whole-run totals), including
    fault-attributed entries like ``retry_downlink/dense``, so "how many
    bytes were pq vs dense" and "what did crashes cost" are first-class
    queries.
  * **Layer 3 — contribution flights + SLO health (what happened to
    each update, and was the run OK).** Every sampled cohort
    contribution gets a stable flight id (``r{round}-c{client}-s{seq}``)
    and a `repro.obs.FlightFrame` row tracing its causal lifecycle —
    sampled → placed (executor shard, edge) → uplink (crash retries,
    re-homes) → terminal state (aggregated / policy-cut / dropped /
    quarantined / voided) — recorded identically by the heapq and
    vectorized scheduler backends (asserted in tests), persisted through
    kill-and-resume snapshots, and kept O(cohort) at 1M clients via
    per-round rollup histograms plus reservoir-sampled exemplar
    lifecycles; in Perfetto, flow arrows link each exemplar's spans
    across the two lanes. On top of the same reductions,
    `repro.obs.HealthMonitor` grades declarative windowed SLO rules
    (``tail_ratio<=3``, ``quarantine_rate<=0.25``, ...) — pass one as
    ``FederatedTrainer(slo_monitor=...)`` and failures land as
    ``slo_violation`` events in the run's own log; `TraceAutoscaler`
    consumes the same signals.

``python -m repro.obs <run.jsonl>`` prints round tables, duration
percentiles, the ledger and bytes/time-to-target; ``--faults`` the
fault ledger; ``--flight <id-or-client>`` reconstructs a recorded
flight's lifecycle; ``--health`` / ``--slo "sig<=thr[@win]"`` the SLO
grade. ``benchmarks/bench_network.py --emit-trace`` (defaulting into
gitignored ``benchmarks/out/``) and the femnist example's
``--emit-trace`` produce such logs end-to-end; ``benchmarks/common``
appends every bench row to ``BENCH_history.jsonl`` and
``benchmarks/sentinel.py`` gates committed snapshots against a baseline
in CI.

Static analysis
---------------
This subsystem concentrates the repo's classic silent-failure modes: a
host sync inside a per-arrival scheduler callback serializes every round,
a jit closure rebuilt per round retraces the step each call, a typo'd
mesh axis explodes only at trace time on a real mesh, and a wire kind
without an explicit decoder arm mis-decodes the *next* kind added. The
`repro.lint` package (``python -m repro.lint src benchmarks examples``)
checks all of these statically — eight AST/jaxpr passes (fleet-scale,
host-sync, custom-vjp, mesh-axes, obs-events, pallas, wire-format,
wire-decode; catalogue in the ``repro.lint`` docstring, ``--list-rules``
for the full list). The obs-events pass cross-checks every literal
``obs.event`` name emitted from the federated hot paths against the
`repro.obs.schema` registry, so a typo'd event name (invisible to every
dashboard filtering on the real one) is a lint error. CI's
``static-analysis`` job fails on any finding, and
``python -m benchmarks.run --preflight`` runs the identical gate before a
benchmark spend. Intentional syncs (e.g. the once-per-``log_every``
trainer log line) carry an inline ``# fedlint: disable=<rule>`` so the
decision is visible in review. The host-sync pass additionally bans
hand-rolled ``time.perf_counter()``/``print()`` instrumentation in the
``repro/federated`` and ``repro/core`` hot paths
(``raw-timing-in-hot-path``): measurements belong in `repro.obs`
spans/events so they land in the run's exportable two-lane log, and the
fleet-scale pass (``python-loop-over-fleet``) bans per-client Python
loops in ``repro/federated`` hot paths — fleet-sized iteration belongs
on `ClientFleet` columns; the heapq reference backend's per-arrival code
carries reviewed suppressions. ``wire.py``'s encoder bodies are pinned by
AST hash in ``repro/lint/wire_manifest.json``: editing an encode body
without bumping its version literal (and re-running ``python -m
repro.lint --update-wire-manifest``) is a lint error, so old decoders can
never silently accept payloads they cannot parse.
"""

from repro.federated.autoscale import (
    AutoscalePlan,
    TraceAutoscaler,
    autoscale_run,
    make_policy,
)
from repro.federated.executor import (
    CohortExecutor,
    MeshExecutor,
    StackedExecutor,
    available_executors,
    make_executor,
    register_executor,
)
from repro.federated.faults import (
    DEFAULT_CHAOS,
    FaultInjector,
    FaultPlan,
    ServerKilled,
    make_injector,
)
from repro.federated.network import (
    IDEAL,
    ClientFleet,
    ClientProfile,
    lognormal_fleet,
    mobile_fleet,
    uniform_fleet,
    validate_fleet,
)
from repro.federated.recovery import (
    restore_runtime,
    run_with_recovery,
    snapshot_runtime,
)
from repro.federated.runtime import (
    FederatedTrainer,
    fedavg_round,
    run_fedavg,
    sample_clients,
    weighted_average,
)
from repro.federated.scheduler import (
    AsyncBuffer,
    Deadline,
    DropSlowestK,
    FullSync,
    Scheduler,
)
from repro.federated.topology import TwoTierTopology
from repro.federated.trace import RoundRecord, Trace
from repro.federated import wire

__all__ = [
    "AsyncBuffer", "AutoscalePlan", "ClientFleet", "ClientProfile",
    "CohortExecutor", "DEFAULT_CHAOS", "Deadline", "DropSlowestK",
    "FaultInjector", "FaultPlan", "FederatedTrainer", "FullSync", "IDEAL",
    "MeshExecutor", "RoundRecord", "Scheduler", "ServerKilled",
    "StackedExecutor", "Trace", "TraceAutoscaler", "TwoTierTopology",
    "autoscale_run", "available_executors", "fedavg_round",
    "lognormal_fleet", "make_executor", "make_injector", "make_policy",
    "mobile_fleet", "register_executor", "restore_runtime", "run_fedavg",
    "run_with_recovery", "sample_clients", "snapshot_runtime",
    "uniform_fleet", "validate_fleet", "weighted_average", "wire",
]
