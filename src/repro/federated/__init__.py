from repro.federated.runtime import (
    FederatedTrainer,
    fedavg_round,
    sample_clients,
    weighted_average,
)

__all__ = ["FederatedTrainer", "fedavg_round", "sample_clients",
           "weighted_average"]
