"""Heterogeneous federated simulation subsystem.

The paper's value proposition is what FedLite saves on the wire; this
package *measures* it — in BOTH directions — instead of only asserting it
analytically. Compression is direction-agnostic: each side of the cut runs
a codec from the `core/compressors.py` registry (``none`` | ``pq`` |
``topk`` | ``scalarq`` | ``chain:...``), configured per direction on
`FederatedTrainer` (``uplink_compressor`` / ``downlink_compressor`` spec
strings) or on `ArchConfig` for the big archs. The uplink default is the
paper's grouped PQ; the downlink default is dense — the measured traffic
that motivated the stack, since the cut-layer *gradient* dominates
bytes-on-the-wire once the uplink is PQ-compressed.

Five layers, composed by `FederatedTrainer`:

  runtime.py    — the algorithm drivers (FedAvg / SplitFed / FedLite round
                  logic, cohort sampling — uniform or p_i-weighted — and
                  weighted aggregation). `FederatedTrainer.run` executes
                  training rounds through the scheduler below; it installs
                  the downlink codec into the model's VJP and measures
                  both directions' payloads through the wire codec.
  wire.py       — the versioned tagged wire codec: every payload is a 24 B
                  header + a kind-specific body (``pq`` codebooks+packed
                  codes, ``dense`` tensors, ``sparse`` top-k indices with
                  optionally *nested* values, ``scalar`` b-bit packed
                  codes). Bit-exact round-trips; unknown versions/kinds are
                  rejected loudly; measured bytes validate the compressors'
                  ``analytic_bits``.
  network.py    — `ClientProfile` (asymmetric bandwidth, latency, compute
                  multiplier, dropout) and fleet samplers: `uniform_fleet`
                  (the IDEAL pre-subsystem clients), `lognormal_fleet`
                  (heavy-tailed broadband), `mobile_fleet` (flaky mobile
                  mixture).
  scheduler.py  — a virtual-clock event loop dispatching rounds under a
                  participation policy: `FullSync`, `DropSlowestK`,
                  `Deadline`, or FedBuff-style `AsyncBuffer` whose
                  staleness weights are applied per contribution
                  (``core/fedlite.make_weighted_step``).
  trace.py      — per-round `RoundRecord`s (simulated wall-clock, measured
                  uplink AND downlink bytes, stragglers dropped, staleness)
                  collected into a `Trace` with per-direction
                  time/bytes-to-target reductions and run-level codec
                  metadata in ``Trace.meta``.

Cross-round state (all default-off): `FederatedTrainer` can additionally
carry cut-layer state across scheduler rounds — PQ codebook warm-start
(``warm_start=True``: Lloyd resumes from last round's codebook at
``PQConfig.warm_iters`` iterations; cohort-global under the stacked
policies, per-client under `AsyncBuffer`), per-client error-feedback
memory (``error_feedback=True``), stochastic downlink rounding
(``stochastic_downlink=True``) and ``pq-delta`` codebook wire encoding
(``codebook_delta_bits``: the uplink ships b-bit quantized codebook deltas
against the acked reference; ``wire.encode_pq_delta``).

The ideal fleet + `FullSync` + dense downlink reproduces the original
synchronous simulation bitwise (tests/test_scheduler.py,
tests/test_compressors.py); heterogeneous fleets and per-direction codecs
turn the same trainer into the paper-§5 trade-off harness driven by
``benchmarks/bench_network.py`` (``--downlink`` sweeps the gradient codec).
"""

from repro.federated.network import (
    IDEAL,
    ClientProfile,
    lognormal_fleet,
    mobile_fleet,
    uniform_fleet,
)
from repro.federated.runtime import (
    FederatedTrainer,
    fedavg_round,
    run_fedavg,
    sample_clients,
    weighted_average,
)
from repro.federated.scheduler import (
    AsyncBuffer,
    Deadline,
    DropSlowestK,
    FullSync,
    Scheduler,
)
from repro.federated.trace import RoundRecord, Trace
from repro.federated import wire

__all__ = [
    "AsyncBuffer", "ClientProfile", "Deadline", "DropSlowestK",
    "FederatedTrainer", "FullSync", "IDEAL", "RoundRecord", "Scheduler",
    "Trace", "fedavg_round", "lognormal_fleet", "mobile_fleet",
    "run_fedavg", "sample_clients", "uniform_fleet", "weighted_average",
    "wire",
]
