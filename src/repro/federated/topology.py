"""Aggregation topologies: flat star vs two-tier hierarchical edges.

The flat scheduler models every uplink as one client->server hop, which
is exactly the parameter-server link Jung et al. (PAPERS.md) show
congesting first as fleets grow: a million last-mile links terminate on
one ingress. Their fix — and this module — is location-clustered
**hierarchical aggregation**: clients upload their (compressed)
cut-layer payloads to a nearby *edge aggregator*, and only the edges
talk to the server.

Why pre-combination is free for the sync policies: federated averaging
is linear in the client contributions (Konečný et al.), so an edge can
sum its cluster's dequantized payloads and forward ``(partial_sum,
count)`` — one payload-sized message plus a small count header — and the
server's weighted average is unchanged. The `AsyncBuffer` policy is the
exception: its per-contribution staleness weights are applied at *server
flush* time, when the contribution's age is known, so edges under async
act as store-and-forward relays (per-contribution hop cost, no
pre-combination) rather than combiners.

`TwoTierTopology` clusters clients by simulated geography: every client
gets a 2-D location drawn from a population-hotspot mixture (urban
concentrations, not uniform scatter), and a chunked vectorized Lloyd
k-means assigns each to its nearest of ``num_edges`` edge sites. The
scheduler consumes three things:

  * ``cluster_of``       — int array, client id -> edge id (also drives
                           cluster-aware cohort placement in
                           `executor.MeshExecutor.place`);
  * ``sync_round(...)``  — given the policy's survivors, the per-edge
                           flush times and the server-side arrival of the
                           last edge payload (the round's new ``t_end``);
  * ``relay_hop_seconds``— the async per-contribution edge->server relay
                           cost added to each dispatch round trip.

Byte accounting is per tier: the obs ledger splits uplink traffic into
``edge_uplink/<kind>`` (every client->edge payload) and
``server_uplink/<kind>`` (one combined payload + count overhead per
*participating* edge per round — the PS-link traffic the hierarchy
exists to shrink). `RoundRecord.uplink_bytes` is the sum of both tiers.

Everything here is plain numpy on the virtual clock — no device work —
and both scheduler backends call the *same* array helpers, so heapq vs
vectorized trace parity holds under a topology by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.federated.network import transfer_seconds


def simulate_locations(num_clients: int, *, hotspots: int = 12,
                       spread: float = 0.04, seed: int = 0) -> np.ndarray:
    """Sample ``(num_clients, 2)`` locations from a hotspot mixture.

    Hotspot centers are uniform in the unit square with Zipf-ish
    population weights (rank r gets weight 1/r), and clients scatter
    normally around their hotspot — a cheap stand-in for the urban
    population clustering that makes edge aggregation pay off.
    """
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.0, 1.0, size=(hotspots, 2))
    weights = 1.0 / np.arange(1, hotspots + 1)
    weights /= weights.sum()
    which = rng.choice(hotspots, size=num_clients, p=weights)
    return centers[which] + rng.normal(0.0, spread, size=(num_clients, 2))


def kmeans_points(points: np.ndarray, k: int, *, iters: int = 8,
                  seed: int = 0, chunk: int = 65536,
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Chunked vectorized Lloyd k-means over ``(n, d)`` points.

    Assignment runs in ``chunk``-sized blocks so the (chunk, k, d)
    distance tensor stays a few MB even at n = 10^6; centroid updates
    are one `np.bincount` per dimension. Empty clusters keep their old
    centroid. Returns ``(labels, centers)``.
    """
    points = np.asarray(points, dtype=np.float64)
    n, d = points.shape
    if k <= 0:
        raise ValueError("k must be positive")
    if k >= n:
        return np.arange(n, dtype=np.int64) % max(k, 1), points.copy()
    rng = np.random.default_rng(seed)
    centers = points[rng.choice(n, size=k, replace=False)]
    labels = np.zeros(n, dtype=np.int64)
    for _ in range(iters):
        for lo in range(0, n, chunk):
            block = points[lo:lo + chunk]
            d2 = ((block[:, None, :] - centers[None, :, :]) ** 2).sum(axis=-1)
            labels[lo:lo + chunk] = np.argmin(d2, axis=1)
        counts = np.bincount(labels, minlength=k)
        new = np.empty_like(centers)
        for dim in range(d):
            sums = np.bincount(labels, weights=points[:, dim], minlength=k)
            new[:, dim] = np.where(counts > 0,
                                   sums / np.maximum(counts, 1),
                                   centers[:, dim])
        centers = new
    return labels, centers


@dataclasses.dataclass
class TwoTierTopology:
    """Client -> edge -> server aggregation with per-tier virtual time.

    ``edge_uplink_bps`` / ``edge_latency_s`` describe the (uniform)
    edge->server backhaul links — provisioned infrastructure, so orders
    of magnitude faster than the last-mile client links in the fleet
    samplers. ``payload_overhead_bytes`` is the count header an edge
    attaches to its pre-combined sum (Konečný-linearity makes the sum
    itself exactly one payload wide).
    """
    num_edges: int = 16
    edge_uplink_bps: float = 10e9
    edge_latency_s: float = 0.005
    payload_overhead_bytes: int = 8
    hotspots: int = 12
    kmeans_iters: int = 8
    seed: int = 0

    kind = "two_tier"

    def __post_init__(self):
        if self.num_edges <= 0:
            raise ValueError("num_edges must be positive")
        self.cluster_of: Optional[np.ndarray] = None
        self.locations: Optional[np.ndarray] = None
        self.centers: Optional[np.ndarray] = None
        # set by sync_round: clients re-homed away from a down edge in the
        # last round (read by the scheduler's fault accounting; kept out of
        # the return triple for backward compatibility)
        self.last_rehomed: int = 0

    # ---- clustering --------------------------------------------------------
    def ensure(self, num_clients: int) -> None:
        """Cluster the fleet once; idempotent for a fixed population size."""
        if self.cluster_of is not None:
            if self.cluster_of.shape[0] != num_clients:
                raise ValueError(
                    f"topology clustered for {self.cluster_of.shape[0]} "
                    f"clients, fleet has {num_clients}")
            return
        self.locations = simulate_locations(
            num_clients, hotspots=self.hotspots, seed=self.seed)
        self.cluster_of, self.centers = kmeans_points(
            self.locations, self.num_edges, iters=self.kmeans_iters,
            seed=self.seed)

    def _require_clusters(self) -> np.ndarray:
        if self.cluster_of is None:
            raise RuntimeError("TwoTierTopology.ensure(num_clients) "
                               "must run before scheduling")
        return self.cluster_of

    # ---- virtual-clock cost model ------------------------------------------
    def edge_payload_bytes(self, uplink_bytes: int) -> int:
        """Bytes of one edge->server message: combined sum + count header."""
        return int(uplink_bytes) + self.payload_overhead_bytes

    def edge_hop_seconds(self, nbytes: int) -> float:
        """Backhaul transfer time for one edge->server message."""
        return transfer_seconds(nbytes, self.edge_uplink_bps,
                                self.edge_latency_s)

    def relay_hop_seconds(self, uplink_bytes: int) -> float:
        """Async store-and-forward relay cost per contribution.

        No pre-combination under `AsyncBuffer` (staleness weights are
        per contribution, applied at server flush), so the relayed
        payload is the client payload itself — no count overhead.
        """
        return self.edge_hop_seconds(int(uplink_bytes))

    def rehome(self, clients: np.ndarray,
               down_edges: Sequence[int]) -> np.ndarray:
        """Edge assignment with outage failover: clients homed to a down
        edge re-home to the next-nearest *live* edge center for the
        window (their k-means location distance, down edges masked out).
        With every edge down the outage is ignored — there is nowhere to
        fail over to, and stalling the whole fleet would deadlock the
        virtual clock. Returns the per-client edge ids."""
        cluster_of = self._require_clusters()
        edges = cluster_of[clients]
        down = np.asarray(sorted(set(int(e) for e in down_edges)), np.int64)
        self.last_rehomed = 0
        if down.size == 0 or down.size >= self.num_edges:
            return edges
        hit = np.isin(edges, down)
        if not hit.any():
            return edges
        # distance of each displaced client's location to every live center
        locs = self.locations[clients[hit]]                  # (h, 2)
        dist = np.linalg.norm(locs[:, None, :] - self.centers[None, :, :],
                              axis=-1)                       # (h, E)
        dist[:, down] = np.inf
        edges = edges.copy()
        edges[hit] = np.argmin(dist, axis=1)
        self.last_rehomed = int(hit.sum())
        return edges

    def sync_round(self, survivor_clients: np.ndarray,
                   survivor_t: np.ndarray, t_policy_end: float,
                   uplink_bytes: int, *,
                   down_edges: Optional[Sequence[int]] = None,
                   ) -> Tuple[float, int, int]:
        """Second-tier times + bytes for one synchronous round.

        Each participating edge flushes when its last surviving client's
        upload lands, then ships one combined payload over the backhaul;
        the round's ``t_end`` is the later of the policy's decision time
        (e.g. the `Deadline` cutoff — the server still waits out its
        budget) and the last edge payload's server-side arrival. Returns
        ``(t_end, participating_edges, server_uplink_bytes)``. Shared
        verbatim by both scheduler backends, so backend trace parity
        under a topology needs no per-backend reasoning.

        ``down_edges`` (fault injection) marks edge aggregators inside an
        outage window: their clients re-home to the next-nearest live
        edge (see ``rehome``; the count lands in ``last_rehomed``).
        """
        cluster_of = self._require_clusters()
        self.last_rehomed = 0
        if survivor_clients.shape[0] == 0:
            return float(t_policy_end), 0, 0
        if down_edges:
            edges = self.rehome(survivor_clients, down_edges)
        else:
            edges = cluster_of[survivor_clients]
        ready = np.full(self.num_edges, -np.inf)
        np.maximum.at(ready, edges, survivor_t)
        participating = int((ready > -np.inf).sum())
        hop = self.edge_hop_seconds(self.edge_payload_bytes(uplink_bytes))
        t_end = max(float(t_policy_end), float(ready.max()) + hop)
        server_bytes = participating * self.edge_payload_bytes(uplink_bytes)
        return t_end, participating, server_bytes

    def meta(self) -> dict:
        """Run-level metadata for ``Trace.meta``."""
        return {"topology": self.kind, "topology_edges": self.num_edges,
                "topology_edge_uplink_bps": self.edge_uplink_bps}
