"""Obs event schema registry.

Every structured event name the codebase emits (``obs.event(name, ...)``
or a raw ``{"type": "event", "name": ...}`` append) is declared here with
its category and the argument keys consumers may rely on. The registry
is the contract between emitters and the tooling that reads runs — the
inspector CLI, the Perfetto exporter, the SLO monitors — and fedlint's
``orphan-obs-event`` pass enforces that ``repro/federated/`` only emits
registered names, so a renamed or ad-hoc event can't silently orphan a
dashboard.

Arg lists are documentation of the stable surface, not an exhaustive
closed set: emitters may add keys, but the listed ones must keep their
meaning. Span names are not registered — spans are free-form timing
scopes; events are the queryable records.
"""

from __future__ import annotations

from typing import Dict, Tuple

__all__ = ["EVENT_SCHEMAS", "is_registered_event"]

# name -> (category, stable arg keys, one-line meaning)
EVENT_SCHEMAS: Dict[str, Tuple[str, Tuple[str, ...], str]] = {
    # -- run lifecycle (emitted by the recorder itself) --
    "run_start": ("run", (), "recorder configured; start of a run log"),
    # -- scheduler --
    "policy.cut": (
        "scheduler", ("round", "cut", "policy"),
        "straggler policy cut N arrivals this round"),
    "fault.round": (
        "faults", ("round", "crashes", "retries", "crash_dropped",
                   "edges_down", "rehomed"),
        "per-round sync fault counters from the injector"),
    "fault.flush": (
        "faults", ("round", "crashes", "retries", "crash_dropped",
                   "jittered"),
        "per-flush async fault counters from the injector"),
    # -- runtime / recovery --
    "fault.round_voided": (
        "faults", ("round", "quarantined", "cohort"),
        "server screen left the round below quorum; update voided"),
    "fault.server_restart": (
        "faults", ("round", "restarts"),
        "ServerKilled absorbed; runtime restored from snapshot"),
    # -- autoscaler --
    "autoscale.plan": (
        "autoscale", ("segment", "rounds_done", "cohort", "policy",
                      "downlink", "reason"),
        "trace-driven autoscaler chose the next segment's knobs"),
    # -- trace summary (log_trace) --
    "round": (
        "trace", ("round", "t_start", "t_end", "participants", "dropped",
                  "uplink_bytes", "downlink_bytes"),
        "one RoundRecord summarized into the event log"),
    "run": (
        "trace", ("rounds", "sim_seconds", "uplink_bytes",
                  "downlink_bytes"),
        "whole-run trace summary"),
    # -- flight recorder (repro.obs.flight) --
    "flight.rollup": (
        "flights", ("round", "kind", "flights", "states", "retries",
                    "retry_downlinks", "rehomed"),
        "per-update flight histogram: state counts + per-edge rollups"),
    "flight.sampled": (
        "flights", ("flight_id", "client", "round", "seq", "kind"),
        "exemplar flight entered the cohort"),
    "flight.placed": (
        "flights", ("flight_id", "client", "round", "edge", "shard",
                    "rehomed"),
        "exemplar flight's edge/executor-shard placement"),
    "flight.quarantined": (
        "flights", ("flight_id", "client", "round", "state"),
        "exemplar flight screened out (or voided) server-side"),
    "flight.outcome": (
        "flights", ("flight_id", "client", "round", "state"),
        "exemplar flight's terminal state"),
    # -- SLO monitors (repro.obs.slo) --
    "slo_violation": (
        "slo", ("rule", "signal", "op", "threshold", "value", "window"),
        "a declarative SLO rule failed on the run's trace reductions"),
}


def is_registered_event(name: str) -> bool:
    return name in EVENT_SCHEMAS
