"""Exporters: append-only JSONL event logs and Chrome/Perfetto traces.

Both exporters consume the plain-dict event schema documented in
``spans.py``. The JSONL log is the durable artifact (one JSON object per
line, append-only, streamable); the Perfetto export is a view of the same
events as Chrome ``trace_event`` JSON, loadable at https://ui.perfetto.dev
or chrome://tracing.

The two time lanes map to two Perfetto "processes":

  pid 1 — "host wall-clock"        (process wall time, seconds from epoch)
  pid 2 — "scheduler virtual-clock" (simulated fleet time)

within which each span category gets its own named thread row, so
scheduler rounds, executor phases, wire encode/decode and round records
render as separate, aligned tracks.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, Iterable, List

_HOST_PID = 1
_VIRTUAL_PID = 2
_LANE_NAMES = {_HOST_PID: "host wall-clock",
               _VIRTUAL_PID: "scheduler virtual-clock"}


def jsonable(value: Any) -> Any:
    """Best-effort conversion of an event payload to JSON-able builtins.

    Handles numpy/jax scalars and arrays (via ``item``/``tolist``), tuples,
    sets and nested containers; anything else falls back to ``str``."""
    if value is None or isinstance(value, (bool, int, float, str)):
        return value
    if isinstance(value, dict):
        return {str(k): jsonable(v) for k, v in value.items()}
    if isinstance(value, (list, tuple, set, frozenset)):
        return [jsonable(v) for v in value]
    if hasattr(value, "ndim") and hasattr(value, "tolist"):
        return value.item() if value.ndim == 0 else value.tolist()
    if hasattr(value, "item"):  # numpy generic scalars
        return value.item()
    return str(value)


def write_jsonl(events: Iterable[Dict[str, Any]], path,
                append: bool = False) -> int:
    """Write events as JSON Lines; returns the number of lines written."""
    path = Path(path)
    mode = "a" if append else "w"
    n = 0
    with path.open(mode, encoding="utf-8") as fh:
        for ev in events:
            fh.write(json.dumps(jsonable(ev), sort_keys=True))
            fh.write("\n")
            n += 1
    return n


def read_jsonl(path) -> List[Dict[str, Any]]:
    """Read a JSONL event log back into a list of event dicts."""
    events = []
    with Path(path).open("r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                events.append(json.loads(line))
    return events


def read_jsonl_tolerant(path) -> "tuple[List[Dict[str, Any]], int]":
    """Read a JSONL event log, skipping unparseable lines.

    A run killed mid-write leaves a truncated final line (or, with
    interleaved writers, the odd garbled one); the strict reader raises
    and the inspector showed nothing. This variant returns
    ``(events, skipped)`` — everything parseable plus how many lines were
    dropped, so callers can render the run with a clear warning instead
    of dying on the artifact that most needs inspecting."""
    events: List[Dict[str, Any]] = []
    skipped = 0
    with Path(path).open("r", encoding="utf-8", errors="replace") as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                ev = json.loads(line)
            except ValueError:
                skipped += 1
                continue
            if isinstance(ev, dict):
                events.append(ev)
            else:
                skipped += 1
    return events, skipped


def _pid(ev: Dict[str, Any]) -> int:
    return _VIRTUAL_PID if ev.get("lane") == "virtual" else _HOST_PID


def to_perfetto(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Render events as a Chrome ``trace_event`` JSON document.

    Spans (and round records) become complete "X" events with microsecond
    ts/dur; instants become "i" events; each (lane, category) pair gets a
    named thread row via "M" metadata.

    Spans whose args carry a ``flight_id`` (the contribution flight
    recorder's exemplar lifecycles, `repro.obs.flight`) are additionally
    chained with flow events ("s"/"t"/"f" keyed on the flight id), so
    Perfetto draws arrows from a flight's virtual-lane retry/uplink spans
    to its host-lane server span — one contribution's causal path across
    the two time lanes."""
    out: List[Dict[str, Any]] = []
    flows: Dict[str, List[Dict[str, Any]]] = {}
    for pid, name in _LANE_NAMES.items():
        out.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": name}})
    tids: Dict[tuple, int] = {}

    def tid_for(pid: int, cat: str) -> int:
        key = (pid, cat)
        if key not in tids:
            tids[key] = len([k for k in tids if k[0] == pid]) + 1
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tids[key], "args": {"name": cat}})
        return tids[key]

    for ev in events:
        pid = _pid(ev)
        cat = str(ev.get("cat", "app"))
        base = {"name": str(ev.get("name", "?")), "cat": cat, "pid": pid,
                "tid": tid_for(pid, cat),
                "args": jsonable(ev.get("args", {}))}
        if "t0" in ev and "t1" in ev:      # spans and round records
            base["ph"] = "X"
            base["ts"] = float(ev["t0"]) * 1e6
            base["dur"] = max(0.0, (float(ev["t1"]) - float(ev["t0"])) * 1e6)
            fid = (ev.get("args") or {}).get("flight_id")
            if fid is not None:
                flows.setdefault(str(fid), []).append(base)
        elif "t" in ev:                    # instants / run boundaries
            base["ph"] = "i"
            base["ts"] = float(ev["t"]) * 1e6
            base["s"] = "t"
        else:  # pragma: no cover - malformed event; keep the export loadable
            continue
        out.append(base)
    for fid, slices in flows.items():
        if len(slices) < 2:
            continue
        slices = sorted(slices, key=lambda s: s["ts"])
        for i, sl in enumerate(slices):
            ph = "s" if i == 0 else ("f" if i == len(slices) - 1 else "t")
            flow = {"ph": ph, "name": "flight", "cat": "flights",
                    "id": fid, "pid": sl["pid"], "tid": sl["tid"],
                    "ts": sl["ts"]}
            if ph == "f":
                flow["bp"] = "e"
            out.append(flow)
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_perfetto(events: Iterable[Dict[str, Any]], path) -> None:
    Path(path).write_text(json.dumps(to_perfetto(events)) + "\n",
                          encoding="utf-8")
