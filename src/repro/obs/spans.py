"""Structured spans: host wall-clock and scheduler virtual time as lanes.

A `Recorder` collects plain-dict events; ``span``/``virtual_span``/``event``
are the module-level entry points the hot path calls. When no recorder is
configured (the default) every entry point is a near-zero-cost no-op, so
instrumentation can live permanently in `Scheduler.run`, the executors, the
wire codec, Lloyd/kmeans and checkpoint I/O without taxing uninstrumented
runs.

Two time lanes, recorded side by side:

  * host   — ``time.perf_counter`` seconds since the recorder's epoch; what
             the process actually spent (jit *dispatch* time for device
             work — spans never block on device values, so they add zero
             device→host syncs).
  * virtual — the scheduler's simulated clock (``virtual_span``); what the
             modeled fleet spent.

Spans are trace-safe: inside jit tracing (``jax.core.trace_state_clean()``
is False) every entry point degrades to a no-op, so a span in a function
that is sometimes traced records eager calls only — it never logs
trace-time as run-time and never captures tracers. Span ``args`` must be
plain host values (ints, strs, shapes), never device arrays.

Event schema (one JSON-able dict per event; see ``export.py``):

  {"type": "span",  "lane": "host"|"virtual", "name", "cat",
   "t0", "t1", "args": {...}}                       # t in lane seconds
  {"type": "event", "lane": ..., "name", "cat", "t", "args": {...}}
  {"type": "round", "lane": "virtual", ...}         # emitted by log_trace
  {"type": "meta" | "run", ...}                     # run boundaries
"""

from __future__ import annotations

import functools
import threading
import time
from typing import Any, Callable, Dict, List, Optional

try:  # the in-trace guard; location varies across jax versions
    from jax.core import trace_state_clean as _trace_state_clean
except ImportError:  # pragma: no cover - newer jax moved it
    try:
        from jax._src.core import trace_state_clean as _trace_state_clean
    except ImportError:  # pragma: no cover - jax absent or relocated again
        def _trace_state_clean() -> bool:
            return True


class Recorder:
    """An append-only in-memory event log with a perf_counter epoch."""

    def __init__(self, run: str = "run",
                 meta: Optional[Dict[str, Any]] = None):
        self.run = run
        self.epoch = time.perf_counter()
        self._lock = threading.Lock()
        self.events: List[Dict[str, Any]] = []
        self._written = 0          # events already flushed to JSONL
        self.append({"type": "meta", "lane": "host", "cat": "obs",
                     "name": "run_start", "t": 0.0,
                     "args": dict(meta or {}, run=run)})

    # ---- recording ---------------------------------------------------------
    def now(self) -> float:
        """Host seconds since the recorder's epoch."""
        return time.perf_counter() - self.epoch

    def append(self, ev: Dict[str, Any]) -> None:
        with self._lock:
            self.events.append(ev)

    def virtual_span(self, name: str, t_start: float, t_end: float,
                     cat: str = "scheduler", **args) -> None:
        self.append({"type": "span", "lane": "virtual", "name": name,
                     "cat": cat, "t0": float(t_start), "t1": float(t_end),
                     "args": args})

    def event(self, name: str, cat: str = "app", lane: str = "host",
              t: Optional[float] = None, **args) -> None:
        self.append({"type": "event", "lane": lane, "name": name, "cat": cat,
                     "t": self.now() if t is None else float(t),
                     "args": args})

    # ---- export (delegates to export.py) -----------------------------------
    def write_jsonl(self, path, append: bool = True) -> int:
        """Flush events to an append-only JSONL log. Repeated calls write
        only the events recorded since the previous flush; returns the
        number of events written."""
        from repro.obs.export import write_jsonl
        with self._lock:
            pending = self.events[self._written:]
            wrote = write_jsonl(pending, path,
                                append=append and self._written > 0)
            self._written += len(pending)
        return wrote

    def write_perfetto(self, path) -> None:
        """Write every event so far as Chrome/Perfetto trace_event JSON."""
        from repro.obs.export import write_perfetto
        with self._lock:
            events = list(self.events)
        write_perfetto(events, path)


class _Span:
    """Host-lane span context manager (created only when recording)."""
    __slots__ = ("_rec", "name", "cat", "args", "_t0")

    def __init__(self, rec: Recorder, name: str, cat: str, args: Dict):
        self._rec = rec
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        self._t0 = self._rec.now()
        return self

    def set(self, **args) -> None:
        """Attach args discovered mid-span (host values only)."""
        self.args.update(args)

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._rec.append({"type": "span", "lane": "host", "name": self.name,
                          "cat": self.cat, "t0": self._t0,
                          "t1": self._rec.now(), "args": self.args})
        return False


class _NullSpan:
    """The disabled path: one shared, stateless, do-nothing span."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> None:
        pass


_NULL_SPAN = _NullSpan()
_RECORDER: Optional[Recorder] = None


def configure(run: str = "run",
              meta: Optional[Dict[str, Any]] = None) -> Recorder:
    """Install a fresh module-level recorder (replacing any current one)."""
    global _RECORDER
    _RECORDER = Recorder(run=run, meta=meta)
    return _RECORDER


def shutdown() -> Optional[Recorder]:
    """Uninstall and return the current recorder (None if none)."""
    global _RECORDER
    rec, _RECORDER = _RECORDER, None
    return rec


def current() -> Optional[Recorder]:
    return _RECORDER


def enabled() -> bool:
    """True when a recorder is installed and we are not inside jit tracing."""
    return _RECORDER is not None and _trace_state_clean()


def span(name: str, cat: str = "app", **args):
    """Host-lane span context manager; a no-op when disabled or tracing."""
    rec = _RECORDER
    if rec is None or not _trace_state_clean():
        return _NULL_SPAN
    return _Span(rec, name, cat, args)


def virtual_span(name: str, t_start: float, t_end: float,
                 cat: str = "scheduler", **args) -> None:
    """Record a closed span on the simulated-clock lane."""
    rec = _RECORDER
    if rec is not None:
        rec.virtual_span(name, t_start, t_end, cat=cat, **args)


def event(name: str, cat: str = "app", lane: str = "host",
          t: Optional[float] = None, **args) -> None:
    """Record an instant event (autoscale plan moves, policy cuts, ...).

    ``t`` is lane time: omit it on the host lane (now), pass the sim time
    explicitly for ``lane="virtual"``."""
    rec = _RECORDER
    if rec is None or not _trace_state_clean():
        return
    rec.event(name, cat=cat, lane=lane, t=t, **args)


def instrument(name: Optional[str] = None,
               cat: str = "app") -> Callable[[Callable], Callable]:
    """Decorator/wrapper form of ``span`` for whole-function timing."""
    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if _RECORDER is None or not _trace_state_clean():
                return fn(*args, **kwargs)
            with span(label, cat=cat):
                return fn(*args, **kwargs)
        return wrapper
    return deco
