"""The run inspector behind ``python -m repro.obs <run.jsonl>``.

Consumes a JSONL event log written by `Recorder.write_jsonl` and prints:

  * the run header (meta + end-of-run summary events),
  * a round table (virtual start/end, participants, dropped, bytes, loss),
  * round-duration percentiles (p50/p90/p99 + tail ratio) on the virtual
    lane and span-duration percentiles per (lane, name) for the host lane,
  * the per-direction, per-wire-kind byte ledger totals,
  * bytes/time-to-target when ``--target`` is given (or a target loss is
    found in the run summary),
  * fault-injection totals when the run carried a `FaultPlan`.

``--json`` emits the same summary as one JSON document for scripting;
``--faults`` prints the per-round fault table (crashes, retries,
quarantines, voided rounds) instead of the full report; ``--health``
grades the run against the SLO rule set (plus any ``--slo`` specs);
``--flight <client-or-id>`` reconstructs a recorded contribution
flight's full lifecycle from its exemplar events.

Logs are read tolerantly: a run killed mid-write leaves a truncated
final line, which is reported as a warning while everything parseable
still renders.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence

from repro.obs import slo as slo_mod
from repro.obs.export import read_jsonl_tolerant


def percentile(values: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile; q in [0, 100]; 0.0 on empty."""
    vals = sorted(float(v) for v in values)
    if not vals:
        return 0.0
    q = min(max(float(q), 0.0), 100.0)
    pos = (len(vals) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(vals) - 1)
    frac = pos - lo
    return vals[lo] * (1.0 - frac) + vals[hi] * frac


def _span_stats(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    groups: Dict[tuple, List[float]] = {}
    for ev in events:
        if ev.get("type") != "span":
            continue
        key = (ev.get("lane", "host"), ev.get("name", "?"))
        groups.setdefault(key, []).append(
            float(ev["t1"]) - float(ev["t0"]))
    rows = []
    for (lane, name), durs in sorted(groups.items()):
        rows.append({"lane": lane, "name": name, "count": len(durs),
                     "total_s": sum(durs),
                     "p50_s": percentile(durs, 50),
                     "p99_s": percentile(durs, 99)})
    rows.sort(key=lambda r: (r["lane"], -r["total_s"]))
    return rows


def _round_rows(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    rows = []
    for ev in events:
        if ev.get("type") != "round":
            continue
        args = ev.get("args", {})
        rows.append({"round": args.get("round", len(rows)),
                     "t_start": float(ev.get("t0", 0.0)),
                     "t_end": float(ev.get("t1", 0.0)),
                     "participants": args.get("participants", 0),
                     "dropped": args.get("dropped", 0),
                     "uplink_bytes": args.get("uplink_bytes", 0),
                     "downlink_bytes": args.get("downlink_bytes", 0),
                     "ledger": args.get("ledger", {}) or {},
                     "faults": args.get("faults", {}) or {},
                     "loss": (args.get("metrics", {}) or {}).get("loss")})
    return rows


def summarize(events: List[Dict[str, Any]],
              target: Optional[float] = None,
              metric: str = "loss") -> Dict[str, Any]:
    """Reduce an event log to the inspector's summary document."""
    rounds = _round_rows(events)
    durations = [r["t_end"] - r["t_start"] for r in rounds]
    ledger: Dict[str, float] = {}
    fault_totals: Dict[str, int] = {}
    for r in rounds:
        for k, v in r["ledger"].items():
            ledger[k] = ledger.get(k, 0) + v
        for k, v in r["faults"].items():
            fault_totals[k] = fault_totals.get(k, 0) + int(v)

    runs = [ev for ev in events if ev.get("type") == "run"]
    meta = [ev for ev in events if ev.get("type") == "meta"]
    p50 = percentile(durations, 50)
    summary: Dict[str, Any] = {
        "events": len(events),
        "runs": [ev.get("args", {}).get("meta", {}) for ev in runs],
        "run_meta": (meta[0].get("args", {}) if meta else {}),
        "rounds": rounds,
        "round_duration_p50_s": p50,
        "round_duration_p90_s": percentile(durations, 90),
        "round_duration_p99_s": percentile(durations, 99),
        "tail_ratio": (percentile(durations, 99) / p50) if p50 > 0 else 1.0,
        "simulated_seconds": (rounds[-1]["t_end"] if rounds else 0.0),
        "uplink_bytes": sum(r["uplink_bytes"] for r in rounds),
        "downlink_bytes": sum(r["downlink_bytes"] for r in rounds),
        "ledger": ledger,
        "fault_totals": fault_totals,
        "spans": _span_stats(events),
    }

    if target is None:  # fall back to a target recorded in the run summary
        for ev in runs:
            t = (ev.get("args", {}).get("summary", {}) or {}).get("target")
            if isinstance(t, (int, float)):
                target = float(t)
                break
    if target is not None:
        summary["target"] = {"metric": metric, "value": target}
        up = down = 0
        t_hit = bytes_hit = round_hit = None
        for r in rounds:
            up += r["uplink_bytes"]
            down += r["downlink_bytes"]
            value = r.get(metric) if metric != "loss" else r["loss"]
            if value is not None and value <= target:
                t_hit, bytes_hit, round_hit = r["t_end"], up + down, r["round"]
                break
        summary["target"].update({"reached_round": round_hit,
                                  "time_to_target_s": t_hit,
                                  "bytes_to_target": bytes_hit})
    return summary


def _fmt_bytes(n: float) -> str:
    n = float(n)
    for unit in ("B", "KiB", "MiB", "GiB"):
        if abs(n) < 1024.0 or unit == "GiB":
            return f"{n:,.1f} {unit}" if unit != "B" else f"{int(n):,} B"
        n /= 1024.0
    return f"{n:,.1f} GiB"  # pragma: no cover - unreachable


def format_faults(summary: Dict[str, Any], max_rows: int = 12) -> str:
    """Render the per-round fault table (``--faults``).

    Rows are only printed for rounds that recorded at least one fault
    counter (crashes, retries, quarantines, voids, ...); a run with no
    `FaultPlan` armed renders as a single "no fault events" line."""
    lines: List[str] = []
    faulted = [r for r in summary["rounds"] if r["faults"]]
    totals = summary.get("fault_totals", {})
    if not faulted:
        lines.append("faults: no fault events recorded")
        return "\n".join(lines)
    cols = sorted({k for r in faulted for k in r["faults"]})
    lines.append("faults (per round, zero-fault rounds omitted):")
    header = f"{'round':>5}" + "".join(f" {c:>18}" for c in cols)
    lines.append(header)
    shown = faulted if len(faulted) <= max_rows else faulted[:max_rows]
    for r in shown:
        row = f"{r['round']:>5}"
        row += "".join(f" {r['faults'].get(c, 0):>18}" for c in cols)
        lines.append(row)
    if len(faulted) > max_rows:
        lines.append(f"  ... {len(faulted) - max_rows} more faulted rounds")
    lines.append("totals: " + ", ".join(f"{k}={v}"
                                        for k, v in sorted(totals.items())))
    return "\n".join(lines)


def format_report(summary: Dict[str, Any], max_rows: int = 12) -> str:
    """Render the summary document as the human-readable report."""
    lines: List[str] = []
    run_meta = summary.get("run_meta", {})
    lines.append(f"run: {run_meta.get('run', '?')}  "
                 f"events: {summary['events']}  "
                 f"rounds: {len(summary['rounds'])}")
    extras = {k: v for k, v in run_meta.items() if k != "run"}
    if extras:
        lines.append("meta: " + ", ".join(f"{k}={v}"
                                          for k, v in sorted(extras.items())))

    rounds = summary["rounds"]
    if rounds:
        lines.append("")
        lines.append(f"{'round':>5} {'t_start':>9} {'t_end':>9} "
                     f"{'parts':>5} {'drop':>4} {'uplink':>12} "
                     f"{'downlink':>12} {'loss':>9}")
        shown = rounds if len(rounds) <= max_rows else rounds[:max_rows]
        for r in shown:
            loss = f"{r['loss']:.4f}" if r["loss"] is not None else "-"
            lines.append(f"{r['round']:>5} {r['t_start']:>9.2f} "
                         f"{r['t_end']:>9.2f} {r['participants']:>5} "
                         f"{r['dropped']:>4} "
                         f"{_fmt_bytes(r['uplink_bytes']):>12} "
                         f"{_fmt_bytes(r['downlink_bytes']):>12} {loss:>9}")
        if len(rounds) > max_rows:
            lines.append(f"  ... {len(rounds) - max_rows} more rounds")
        lines.append("")
        lines.append(
            f"virtual round duration  p50={summary['round_duration_p50_s']:.2f}s"
            f"  p90={summary['round_duration_p90_s']:.2f}s"
            f"  p99={summary['round_duration_p99_s']:.2f}s"
            f"  tail_ratio={summary['tail_ratio']:.2f}")
        lines.append(
            f"simulated {summary['simulated_seconds']:.1f}s   "
            f"uplink {_fmt_bytes(summary['uplink_bytes'])}   "
            f"downlink {_fmt_bytes(summary['downlink_bytes'])}")

    if summary["ledger"]:
        lines.append("")
        lines.append("byte ledger (direction/wire-kind):")
        for k, v in sorted(summary["ledger"].items()):
            lines.append(f"  {k:<24} {_fmt_bytes(v):>14}")

    if summary.get("fault_totals"):
        lines.append("")
        lines.append("fault totals: " +
                     ", ".join(f"{k}={v}" for k, v in
                               sorted(summary["fault_totals"].items())))

    target = summary.get("target")
    if target:
        lines.append("")
        if target.get("reached_round") is not None:
            lines.append(
                f"target {target['metric']} <= {target['value']}: reached at "
                f"round {target['reached_round']} "
                f"(t={target['time_to_target_s']:.1f}s, "
                f"{_fmt_bytes(target['bytes_to_target'])} on the wire)")
        else:
            lines.append(f"target {target['metric']} <= {target['value']}: "
                         "not reached")

    spans = summary["spans"]
    if spans:
        lines.append("")
        lines.append("spans (by total time within lane):")
        lines.append(f"  {'lane':<8} {'name':<28} {'count':>6} "
                     f"{'total':>10} {'p50':>10} {'p99':>10}")
        for row in spans[:max_rows]:
            lines.append(f"  {row['lane']:<8} {row['name']:<28} "
                         f"{row['count']:>6} {row['total_s']:>9.3f}s "
                         f"{row['p50_s'] * 1e3:>8.2f}ms "
                         f"{row['p99_s'] * 1e3:>8.2f}ms")
        if len(spans) > max_rows:
            lines.append(f"  ... {len(spans) - max_rows} more span groups")
    return "\n".join(lines)


def format_health(results: List["slo_mod.SloResult"]) -> str:
    """Render SLO results (``--health``) as a pass/fail report."""
    lines = ["SLO health:"]
    for res in results:
        lines.append("  " + res.describe())
    failed = [r for r in results if not r.ok]
    if failed:
        lines.append(f"health: FAIL ({len(failed)}/{len(results)} rules "
                     "violated)")
    else:
        lines.append(f"health: PASS ({len(results)} rules)")
    return "\n".join(lines)


def _flight_groups(events: List[Dict[str, Any]],
                   ) -> Dict[str, List[Dict[str, Any]]]:
    groups: Dict[str, List[Dict[str, Any]]] = {}
    for ev in events:
        if ev.get("cat") != "flights":
            continue
        fid = (ev.get("args") or {}).get("flight_id")
        if fid is not None:
            groups.setdefault(str(fid), []).append(ev)
    return groups


def _flight_line(ev: Dict[str, Any]) -> str:
    a = ev.get("args") or {}
    name = ev.get("name", "?")
    if "t0" in ev:
        when = f"{float(ev['t0']):>9.2f}s –{float(ev['t1']):>8.2f}s"
    else:
        when = f"{float(ev.get('t', 0.0)):>9.2f}s" + " " * 10
    if name == "flight.sampled":
        what = f"sampled into the cohort ({a.get('kind', '?')} wave, " \
               f"seq {a.get('seq', '?')})"
    elif name == "flight.placed":
        edge = a.get("edge", -1)
        where = f"edge {edge}" if edge != -1 else "server (flat star)"
        shard = a.get("shard", -1)
        if shard != -1:
            where += f", executor shard {shard}"
        what = f"placed on {where}"
        if a.get("rehomed"):
            what += "  [re-homed: nearest edge was down]"
    elif name == "flight.uplink":
        what = "uplink in flight"
    elif name == "flight.retry":
        what = (f"crash retries x{a.get('retries', '?')} "
                f"({a.get('retry_downlinks', 0)} extra model downlinks)")
    elif name == "flight.quarantined":
        what = f"server screen: {a.get('state', 'quarantined')}"
    elif name == "flight.outcome":
        what = f"outcome: {a.get('state', '?')}"
    elif name == "flight.server":
        return (f"{when}  server aggregate step "
                "(host lane; linked by Perfetto flow)")
    else:
        what = name
    return f"{when}  {what}"


def format_flight(events: List[Dict[str, Any]], query: str,
                  max_flights: int = 4) -> "tuple[str, bool]":
    """Reconstruct recorded flight lifecycles (``--flight``).

    ``query`` is a flight id (``r3-c17-s5``) or a bare client id (every
    exemplar flight of that client renders, capped). Returns
    ``(report, found)`` — only reservoir-sampled exemplars carry full
    lifecycles, so a miss lists what IS available."""
    groups = _flight_groups(events)
    sel: Dict[str, List[Dict[str, Any]]] = {}
    if query in groups:
        sel = {query: groups[query]}
    else:
        try:
            cid = int(query)
        except ValueError:
            cid = None
        if cid is not None:
            sel = {fid: evs for fid, evs in groups.items()
                   if any((e.get("args") or {}).get("client") == cid
                          for e in evs)}
    if not sel:
        lines = [f"no recorded flight matches {query!r}."]
        if groups:
            known = sorted(groups)
            shown = ", ".join(known[:12])
            more = f" (+{len(known) - 12} more)" if len(known) > 12 else ""
            lines.append(f"recorded exemplar flights: {shown}{more}")
            lines.append("(only reservoir-sampled exemplars carry full "
                         "lifecycles; rollup histograms cover the rest)")
        else:
            lines.append("this log carries no flight events — record with "
                         "flight recording enabled (the default) and "
                         "obs.log_trace.")
        return "\n".join(lines), False

    lines = []
    for fid in sorted(sel)[:max_flights]:
        evs = sorted(sel[fid],
                     key=lambda e: (float(e.get("t0", e.get("t", 0.0))),
                                    e.get("name", "")))
        head = next((e for e in evs if e.get("name") == "flight.sampled"),
                    evs[0])
        a = head.get("args") or {}
        lines.append(f"flight {fid}  (client {a.get('client', '?')}, "
                     f"update {a.get('round', '?')})")
        for ev in evs:
            lines.append("  " + _flight_line(ev))
        lines.append("")
    if len(sel) > max_flights:
        lines.append(f"... {len(sel) - max_flights} more matching flights")
    return "\n".join(lines).rstrip(), True


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a telemetry JSONL log written with "
                    "--emit-trace (round tables, percentiles, byte ledger, "
                    "bytes/time-to-target).")
    ap.add_argument("path", help="JSONL event log (Recorder.write_jsonl)")
    ap.add_argument("--target", type=float, default=None,
                    help="metric threshold for time/bytes-to-target")
    ap.add_argument("--metric", default="loss",
                    help="round metric the target applies to (default: loss)")
    ap.add_argument("--rows", type=int, default=12,
                    help="max table rows to print (default: 12)")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON instead of a report")
    ap.add_argument("--faults", action="store_true",
                    help="print the per-round fault-injection table "
                         "(crashes, retries, quarantines, voids) instead "
                         "of the full report")
    ap.add_argument("--health", action="store_true",
                    help="grade the run against the SLO rule set and "
                         "print pass/fail per rule")
    ap.add_argument("--slo", action="append", default=[],
                    metavar="SIGNAL<=THRESH[@WINDOW]",
                    help="additional SLO rule (repeatable), e.g. "
                         "'drop_rate<=0.3' or 'tail_ratio<=2.5@20'; "
                         "implies --health")
    ap.add_argument("--flight", default=None, metavar="CLIENT-OR-ID",
                    help="reconstruct a recorded contribution flight's "
                         "lifecycle (flight id like r3-c17-s5, or a "
                         "client id)")
    args = ap.parse_args(argv)

    try:
        events, skipped = read_jsonl_tolerant(args.path)
    except OSError as exc:
        print(f"error: cannot read {args.path}: {exc}", file=sys.stderr)
        return 2
    if skipped:
        print(f"warning: {args.path}: skipped {skipped} unparseable "
              f"line{'s' if skipped != 1 else ''} (truncated/partial "
              "write); rendering the rest", file=sys.stderr)
    if not events:
        print(f"error: {args.path}: no parseable events", file=sys.stderr)
        return 2
    try:
        if args.flight is not None:
            report, found = format_flight(events, args.flight,
                                          max_flights=max(args.rows // 3, 1))
            print(report)
            return 0 if found else 1
        if args.health or args.slo:
            try:
                rules = list(slo_mod.DEFAULT_SLOS) \
                    + [slo_mod.parse_rule(s) for s in args.slo]
            except ValueError as exc:
                print(f"error: {exc}", file=sys.stderr)
                return 2
            results = slo_mod.HealthMonitor(rules).evaluate_rows(
                _round_rows(events))
            print(format_health(results))
            # grading a recorded run is a report, not a gate: exit 0
            # either way so CI artifact generation never flips red here
            return 0
        summary = summarize(events, target=args.target, metric=args.metric)
        if args.json:
            print(json.dumps(summary, sort_keys=True))
        elif args.faults:
            print(format_faults(summary, max_rows=args.rows))
        else:
            print(format_report(summary, max_rows=args.rows))
    except BrokenPipeError:   # e.g. `... | head`; the report is best-effort
        return 0
    return 0
