"""CLI entry point: ``python -m repro.obs <run.jsonl>``."""

import sys

from repro.obs.inspect import main

if __name__ == "__main__":
    sys.exit(main())
