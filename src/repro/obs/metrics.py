"""Sync-free in-jit metrics: device-side accumulation, one flush per run.

The contract: jitted steps compute metrics as arrays inside the trace
(``counter``/``gauge``/``histogram`` below are jit-safe helpers) and return
them through their existing aux pytrees. The host side *records* those
device values without looking at them — `MetricsBuffer.record` is just a
list append, adding **zero** device→host syncs to the hot loop — and
converts them all at once at the end of the run with a single
``jax.device_get`` in `MetricsBuffer.flush`. tests/test_obs.py counts
transfers to hold this to "no more than the uninstrumented trainer".
"""

from __future__ import annotations

from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def counter(x) -> jnp.ndarray:
    """Sum a (possibly batched) quantity into a scalar count, in-trace."""
    return jnp.sum(jnp.asarray(x, jnp.float32))


def gauge(x) -> jnp.ndarray:
    """A point-in-time scalar reading, in-trace."""
    return jnp.asarray(x, jnp.float32).reshape(())


def histogram(x, bins: int = 16, lo: float = 0.0,
              hi: float = 1.0) -> jnp.ndarray:
    """Fixed-range histogram counts with a static shape, jit-safe.

    ``bins``/``lo``/``hi`` must be Python constants (they size the output).
    Values outside [lo, hi] clamp into the edge buckets so no sample is
    silently dropped."""
    x = jnp.asarray(x, jnp.float32).reshape(-1)
    edges = jnp.linspace(lo, hi, bins + 1)
    idx = jnp.clip(jnp.searchsorted(edges, x, side="right") - 1, 0, bins - 1)
    return jnp.zeros((bins,), jnp.float32).at[idx].add(1.0)


def _host_value(v: Any) -> Any:
    a = np.asarray(v)
    if a.ndim == 0:
        return float(a)
    return a.tolist()


class MetricsBuffer:
    """Accumulates per-round device metric pytrees; flushes in one transfer.

    ``record`` keeps device arrays as-is (no sync); ``flush`` performs the
    run's single blocking ``jax.device_get`` over everything recorded and
    returns per-round dicts of host floats (lists for vector metrics such
    as histograms)."""

    def __init__(self) -> None:
        self._pending: List[Dict[str, Any]] = []

    def __len__(self) -> int:
        return len(self._pending)

    def record(self, metrics: Dict[str, Any]) -> None:
        self._pending.append(metrics)

    def flush(self) -> List[Dict[str, Any]]:
        if not self._pending:
            return []
        pending, self._pending = self._pending, []
        host = jax.device_get(pending)  # the run's one blocking transfer
        return [{k: _host_value(v) for k, v in m.items()} for m in host]
