"""Contribution flight recorder: per-contribution causal lifecycle.

A **flight** is one cohort contribution's journey through a round:

    sampled -> placed (edge / executor shard) -> uplink in flight ->
    {retry(n) / re-home / quarantined / dropped} -> edge pre-combine ->
    server aggregate

Every flight gets a stable ``flight_id`` — ``r<round>-c<client>-s<seq>``
where ``seq`` is the cohort position (sync) or the dispatch-stream index
(async). All three components are backend-invariant (heap ties break on
seq; the vector core sorts stably; the async stream is consumed FIFO in
both backends), so the heapq and vector schedulers produce **identical
flight sets** — asserted in tests/test_fleet_scale.py.

Recording is column-oriented and O(cohort) per round: each server update
appends one `FlightFrame` (a struct-of-arrays over the round's sampled
contributions) to ``Trace.flights``. The scheduler assembles frames from
the SAME arrays its vector core already computes — no per-client Python
in the hot path (fedlint's ``python-loop-over-fleet`` stays clean) — and
the heapq reference backend scatters its per-arrival scalars into
bitwise-identical columns. Frames survive kill-and-resume: they ride the
`federated/recovery.py` snapshot meta json via `to_json`/`from_json`.

The obs event log stays *sublinear* in the fleet: `log_frames` (called
from ``obs.log_trace``) emits one ``flight.rollup`` event per frame
(state counts + per-edge histograms via ``np.bincount``) plus a
deterministic, hash-reservoir sample of **exemplar** flights whose full
lifecycle becomes linked events — fault-affected flights (retried,
re-homed, quarantined, cut, crash-dropped) are prioritized so a chaos
run always has drill-down material for ``python -m repro.obs --flight``.
Exemplar events share a ``flight_id`` arg; the Perfetto exporter turns
that into flow events linking the virtual-lane retry/uplink spans to the
host-lane server span (``repro/obs/export.py``).

Async caveat: flights enter their frame when they *terminate* (heap
pop), while the scheduler's retry counters accrue at *dispatch* time, so
per-flush retry columns reconcile with the ``retry_downlink/<kind>``
ledger only for synchronous policies (exact, tested); async runs assert
backend parity instead.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "FlightFrame", "STATE_NAMES", "S_DROPPED_OUT", "S_CRASH_DROPPED",
    "S_CUT", "S_AGGREGATED", "S_QUARANTINED", "S_VOIDED",
    "flights_enabled", "set_flights", "make_flight_id", "sync_frame",
    "async_frame", "edge_columns", "assign_shards", "apply_screening",
    "select_exemplars", "log_frames",
]

# terminal lifecycle states (int8 column codes)
S_DROPPED_OUT = np.int8(1)    # lost to the benign dropout draw
S_CRASH_DROPPED = np.int8(2)  # crash retry budget exhausted
S_CUT = np.int8(3)            # arrived, cut by the straggler policy
S_AGGREGATED = np.int8(4)     # aggregated into the server update
S_QUARANTINED = np.int8(5)    # server screen: corrupt/poisoned payload
S_VOIDED = np.int8(6)         # survived screening, round below quorum

STATE_NAMES: Dict[int, str] = {
    int(S_DROPPED_OUT): "dropped_out",
    int(S_CRASH_DROPPED): "crash_dropped",
    int(S_CUT): "cut",
    int(S_AGGREGATED): "aggregated",
    int(S_QUARANTINED): "quarantined",
    int(S_VOIDED): "voided",
}

_ENABLED = True


def flights_enabled() -> bool:
    """Whether schedulers should capture flight frames (default on —
    capture is a handful of O(cohort) array ops per round)."""
    return _ENABLED


def set_flights(on: bool) -> bool:
    """Toggle flight capture; returns the previous setting. The off mode
    exists for A/B overhead measurement (``bench_network --fleet-scale``
    asserts recording stays within 1.15x of the bare scheduler)."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def make_flight_id(rd: int, client: int, seq: int) -> str:
    return f"r{rd}-c{client}-s{seq}"


@dataclasses.dataclass(eq=False)
class FlightFrame:
    """One server update's flights as column arrays (struct-of-arrays).

    Rows are in cohort order (sync: ``seq`` == cohort position) or
    dispatch-stream order (async: ``seq`` == stream index, covering the
    flights that *terminated* in this flush window). ``t_arrival`` is
    NaN for flights that never completed an upload; ``edge`` / ``shard``
    are -1 for flat-star topologies / never-placed flights.
    """
    round: int
    kind: str                     # "sync" | "async"
    client: np.ndarray            # int64
    seq: np.ndarray               # int64 — the stable id component
    t_sampled: np.ndarray         # float64, virtual dispatch time
    t_arrival: np.ndarray         # float64, NaN = never arrived
    retries: np.ndarray           # int64, crashed attempts before success
    retry_downlinks: np.ndarray   # int64, extra model re-broadcasts
    retry_s: np.ndarray           # float64, virtual seconds of retry overhead
    edge: np.ndarray              # int64, aggregator placement (-1 = flat)
    rehomed: np.ndarray           # bool, failed over to a live edge
    shard: np.ndarray             # int64, executor shard (-1 = not placed)
    state: np.ndarray             # int8, S_* terminal state

    _FLOAT_COLS = ("t_sampled", "t_arrival", "retry_s")
    _COLS = ("client", "seq", "t_sampled", "t_arrival", "retries",
             "retry_downlinks", "retry_s", "edge", "rehomed", "shard",
             "state")

    def __len__(self) -> int:
        return int(self.client.shape[0])

    def __eq__(self, other: object) -> bool:
        if not isinstance(other, FlightFrame):
            return NotImplemented
        if (self.round, self.kind) != (other.round, other.kind):
            return False
        for c in self._COLS:
            a, b = getattr(self, c), getattr(other, c)
            eq = np.array_equal(a, b, equal_nan=c in self._FLOAT_COLS)
            if not eq:
                return False
        return True

    def flight_id(self, i: int) -> str:
        return make_flight_id(self.round, int(self.client[i]),
                              int(self.seq[i]))

    def state_counts(self) -> Dict[str, int]:
        counts = np.bincount(self.state, minlength=7)
        return {STATE_NAMES[s]: int(counts[s])
                for s in STATE_NAMES if counts[s]}

    def to_json(self) -> Dict[str, Any]:
        """JSON-safe column dict (NaN arrival -> None; checkpoint meta
        files must stay strict-JSON parseable)."""
        arr = [None if np.isnan(x) else float(x)
               for x in self.t_arrival.tolist()]
        return {
            "round": self.round, "kind": self.kind,
            "client": self.client.tolist(), "seq": self.seq.tolist(),
            "t_sampled": self.t_sampled.tolist(), "t_arrival": arr,
            "retries": self.retries.tolist(),
            "retry_downlinks": self.retry_downlinks.tolist(),
            "retry_s": self.retry_s.tolist(), "edge": self.edge.tolist(),
            "rehomed": self.rehomed.tolist(), "shard": self.shard.tolist(),
            "state": self.state.tolist(),
        }

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FlightFrame":
        arr = np.asarray([np.nan if x is None else x
                          for x in d["t_arrival"]], np.float64)
        return cls(
            round=int(d["round"]), kind=str(d["kind"]),
            client=np.asarray(d["client"], np.int64),
            seq=np.asarray(d["seq"], np.int64),
            t_sampled=np.asarray(d["t_sampled"], np.float64),
            t_arrival=arr,
            retries=np.asarray(d["retries"], np.int64),
            retry_downlinks=np.asarray(d["retry_downlinks"], np.int64),
            retry_s=np.asarray(d["retry_s"], np.float64),
            edge=np.asarray(d["edge"], np.int64),
            rehomed=np.asarray(d["rehomed"], bool),
            shard=np.asarray(d["shard"], np.int64),
            state=np.asarray(d["state"], np.int8),
        )


# ---------------------------------------------------------------------------
# frame assembly (shared by both scheduler backends)
# ---------------------------------------------------------------------------

def edge_columns(topology, ids: np.ndarray,
                 down_edges: Sequence[int] = (),
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-flight ``(edge, rehomed)`` placement columns for one cohort.

    Uses the topology's own ``rehome`` failover math under an outage
    window so the flight's recorded edge is where the upload actually
    terminated; ``last_rehomed`` (the scheduler's survivor-only fault
    counter) is saved and restored around the whole-cohort call.
    """
    n = int(ids.shape[0])
    if topology is None or getattr(topology, "cluster_of", None) is None:
        return np.full(n, -1, np.int64), np.zeros(n, bool)
    base = topology.cluster_of[ids].astype(np.int64)
    if not down_edges:
        return base, np.zeros(n, bool)
    saved = topology.last_rehomed
    eff = topology.rehome(ids, down_edges).astype(np.int64)
    topology.last_rehomed = saved
    return eff, eff != base


def sync_frame(rd: int, t_start: float, ids: np.ndarray,
               arr_by_pos: np.ndarray, agg_pos: np.ndarray,
               cut_pos: np.ndarray, *,
               live_pos: Optional[np.ndarray] = None,
               crashes: Optional[np.ndarray] = None,
               extra_downlinks: Optional[np.ndarray] = None,
               retry_seconds: Optional[np.ndarray] = None,
               gone: Optional[np.ndarray] = None,
               topology=None, down_edges: Sequence[int] = (),
               ) -> FlightFrame:
    """Assemble one synchronous round's frame from cohort-order columns.

    ``arr_by_pos`` is the per-cohort-position arrival time (NaN where the
    member dropped out or exhausted its retry budget); ``agg_pos`` /
    ``cut_pos`` are cohort positions of the policy's survivors and cuts
    in arrival order. The fault columns (``crashes`` etc., indexed over
    ``live_pos`` — the positions that survived the dropout draw) are
    None on crash-free rounds.
    """
    n = int(ids.shape[0])
    retries = np.zeros(n, np.int64)
    retry_dl = np.zeros(n, np.int64)
    retry_s = np.zeros(n, np.float64)
    state = np.full(n, S_DROPPED_OUT, np.int8)
    if live_pos is not None and crashes is not None:
        retries[live_pos] = crashes
        retry_dl[live_pos] = extra_downlinks
        retry_s[live_pos] = retry_seconds
        if gone is not None:
            state[live_pos[gone]] = S_CRASH_DROPPED
    state[cut_pos] = S_CUT
    state[agg_pos] = S_AGGREGATED
    edge, rehomed = edge_columns(topology, ids, down_edges)
    return FlightFrame(
        round=int(rd), kind="sync",
        client=ids.astype(np.int64, copy=False),
        seq=np.arange(n, dtype=np.int64),
        t_sampled=np.full(n, float(t_start)),
        t_arrival=arr_by_pos,
        retries=retries, retry_downlinks=retry_dl, retry_s=retry_s,
        edge=edge, rehomed=rehomed,
        shard=np.full(n, -1, np.int64), state=state)


def _gather(col, seqs: np.ndarray, dtype):
    """Stream-column gather by seq: O(window) for list-backed columns
    (the heapq backend's per-dispatch appends), fancy indexing for the
    vector backend's arrays. ``col=None`` means the column was never
    populated (no fault injection) -> zeros."""
    if col is None:
        return np.zeros(seqs.shape[0], dtype)
    if isinstance(col, np.ndarray):
        return col[seqs].astype(dtype, copy=False)
    return np.asarray([col[s] for s in seqs.tolist()], dtype)


def async_frame(update: int, done: Sequence[Tuple[int, float]],
                cid, t0, drop, crash, retry_dl, retry_s, gone,
                topology=None) -> FlightFrame:
    """Assemble one async flush window's frame.

    ``done`` holds ``(seq, t_pop)`` for every flight that terminated in
    this window (buffered for aggregation OR dropped), in heap-pop
    order; rows are sorted by seq so both backends emit the identical
    frame. The remaining args are per-seq stream columns (lists in the
    heapq backend, arrays in the vector backend; fault columns None when
    no injector is armed).
    """
    seqs = np.asarray([s for s, _ in done], np.int64)
    tpop = np.asarray([tp for _, tp in done], np.float64)
    order = np.argsort(seqs, kind="stable")
    seqs, tpop = seqs[order], tpop[order]
    n = int(seqs.shape[0])
    client = _gather(cid, seqs, np.int64)
    dropped = _gather(drop, seqs, bool)
    gone_m = _gather(gone, seqs, bool)
    state = np.full(n, S_AGGREGATED, np.int8)
    state[dropped] = S_DROPPED_OUT
    state[gone_m] = S_CRASH_DROPPED      # budget exhaustion wins over dropout
    edge, rehomed = edge_columns(topology, client)
    return FlightFrame(
        round=int(update), kind="async", client=client, seq=seqs,
        t_sampled=_gather(t0, seqs, np.float64), t_arrival=tpop,
        retries=_gather(crash, seqs, np.int64),
        retry_downlinks=_gather(retry_dl, seqs, np.int64),
        retry_s=_gather(retry_s, seqs, np.float64),
        edge=edge, rehomed=rehomed,
        shard=np.full(n, -1, np.int64), state=state)


def assign_shards(frame: FlightFrame, placed: Sequence[Any]) -> None:
    """Scatter the executor's shard placement onto the aggregated
    flights, matching by client id (searchsorted over the aggregated
    subset — duplicate clients in one async flush share attribution)."""
    if not placed or len(frame) == 0:
        return
    pc = np.asarray([a.client for a in placed], np.int64)
    ps = np.asarray([a.shard for a in placed], np.int64)
    agg_idx = np.nonzero(frame.state == S_AGGREGATED)[0]
    if agg_idx.shape[0] == 0:
        return
    sub = frame.client[agg_idx]
    order = np.argsort(sub, kind="stable")
    pos = np.searchsorted(sub[order], pc)
    frame.shard[agg_idx[order[pos]]] = ps


def apply_screening(frames: Sequence[FlightFrame],
                    screen_log: Dict[int, Dict[str, Any]]) -> None:
    """Patch scheduler-built frames with the runtime's server-side
    admission verdicts: quarantined clients flip AGGREGATED ->
    QUARANTINED; a voided round flips the surviving remainder to VOIDED.
    Keyed by update index (`FederatedTrainer._screen_cohort` records
    ``{"quarantined": [client ids], "voided": bool}`` per update)."""
    by_round = {fr.round: fr for fr in frames}
    for rd, entry in screen_log.items():
        fr = by_round.get(rd)
        if fr is None:
            continue
        qcids = entry.get("quarantined") or []
        if qcids:
            agg = fr.state == S_AGGREGATED
            hit = np.isin(fr.client, np.asarray(qcids, np.int64))
            fr.state[agg & hit] = S_QUARANTINED
        if entry.get("voided"):
            fr.state[fr.state == S_AGGREGATED] = S_VOIDED


def retry_downlink_total(frames: Sequence[FlightFrame]) -> int:
    """Extra model re-broadcasts across all recorded flights — for sync
    runs this reconciles exactly with the ``retry_downlink/<kind>``
    ledger entries divided by the per-client downlink payload."""
    return sum(int(fr.retry_downlinks.sum()) for fr in frames)


# ---------------------------------------------------------------------------
# event-log emission: rollups + reservoir exemplars (O(edges + k) per frame)
# ---------------------------------------------------------------------------

_MIX1 = np.uint64(0x9E3779B97F4A7C15)
_MIX2 = np.uint64(0xBF58476D1CE4E5B9)
_MIX3 = np.uint64(0x94D049BB133111EB)
_MASK = (1 << 64) - 1


def _hash01(frame: FlightFrame) -> np.ndarray:
    """Deterministic per-flight uniform in [0, 1) keyed on the stable id
    (splitmix64-style finalizer) — the reservoir's tie-breaker, so
    exemplar choice is identical across backends and resumed runs."""
    with np.errstate(over="ignore"):
        x = frame.seq.astype(np.uint64) * _MIX1
        x ^= frame.client.astype(np.uint64) * _MIX2
        x ^= np.uint64((frame.round * 0x632BE59BD9B4E019) & _MASK)
        x ^= x >> np.uint64(31)
        x *= _MIX3
        x ^= x >> np.uint64(29)
    return x.astype(np.float64) / float(2 ** 64)


def select_exemplars(frame: FlightFrame, k: int = 8) -> np.ndarray:
    """Deterministic reservoir sample of ``k`` flight rows, guaranteeing
    at least one exemplar per distinct terminal state plus one retried
    and one re-homed flight (when present); the remaining budget prefers
    fault-affected flights, hash-tie-broken."""
    n = len(frame)
    if n == 0 or k <= 0:
        return np.empty(0, np.int64)
    h = _hash01(frame)
    picked: List[int] = []
    for s in np.unique(frame.state).tolist():     # <= 6 distinct states
        m = frame.state == s
        picked.append(int(np.nonzero(m)[0][np.argmax(h[m])]))
    for m in (frame.retries > 0, frame.rehomed):
        if m.any():
            picked.append(int(np.nonzero(m)[0][np.argmax(h[m])]))
    chosen = set(picked[:k])
    budget = k - len(chosen)
    if budget > 0 and n > len(chosen):
        prio = (frame.state != S_AGGREGATED).astype(np.float64) * 2.0 \
            + (frame.retries > 0) + frame.rehomed
        key = prio + h
        key[np.asarray(sorted(chosen), np.int64)] = -np.inf
        m = min(budget, n - len(chosen))
        top = np.argpartition(key, n - m)[n - m:]
        chosen.update(int(i) for i in top if np.isfinite(key[i]))
    return np.asarray(sorted(chosen), np.int64)


def _frame_t(frame: FlightFrame) -> float:
    finite = frame.t_arrival[np.isfinite(frame.t_arrival)]
    if finite.shape[0]:
        return float(finite.max())
    return float(frame.t_sampled[0]) if len(frame) else 0.0


def _emit_rollup(rec, frame: FlightFrame) -> None:
    args: Dict[str, Any] = {
        "round": frame.round, "kind": frame.kind, "flights": len(frame),
        "states": frame.state_counts(),
        "retries": int(frame.retries.sum()),
        "retry_downlinks": int(frame.retry_downlinks.sum()),
        "rehomed": int(frame.rehomed.sum()),
    }
    m = frame.edge >= 0
    if m.any():
        e = frame.edge[m]
        n_edges = int(e.max()) + 1
        cnt = np.bincount(e, minlength=n_edges)
        rtr = np.bincount(e, weights=frame.retries[m], minlength=n_edges)
        lost = np.bincount(e, weights=(frame.state[m] != S_AGGREGATED),
                           minlength=n_edges)
        args["per_edge"] = {
            str(i): {"flights": int(cnt[i]), "retries": int(rtr[i]),
                     "lost": int(lost[i])}
            for i in range(n_edges) if cnt[i]}
    rec.append({"type": "event", "name": "flight.rollup", "cat": "flights",
                "lane": "virtual", "t": _frame_t(frame), "args": args})


def _emit_exemplar(rec, frame: FlightFrame, i: int) -> None:
    fid = frame.flight_id(i)
    state = STATE_NAMES[int(frame.state[i])]
    common = {"flight_id": fid, "client": int(frame.client[i]),
              "round": frame.round}
    t0 = float(frame.t_sampled[i])
    ta = frame.t_arrival[i]
    ta = t0 if np.isnan(ta) else float(ta)
    rec.append({"type": "event", "name": "flight.sampled", "cat": "flights",
                "lane": "virtual", "t": t0,
                "args": dict(common, seq=int(frame.seq[i]), kind=frame.kind)})
    rec.append({"type": "event", "name": "flight.placed", "cat": "flights",
                "lane": "virtual", "t": t0,
                "args": dict(common, edge=int(frame.edge[i]),
                             shard=int(frame.shard[i]),
                             rehomed=bool(frame.rehomed[i]))})
    rec.append({"type": "span", "name": "flight.uplink", "cat": "flights",
                "lane": "virtual", "t0": t0, "t1": ta,
                "args": dict(common, state=state)})
    retries = int(frame.retries[i])
    if retries:
        rec.append({"type": "span", "name": "flight.retry",
                    "cat": "flights", "lane": "virtual",
                    "t0": t0, "t1": t0 + float(frame.retry_s[i]),
                    "args": dict(common, retries=retries,
                                 retry_downlinks=int(
                                     frame.retry_downlinks[i]))})
    if frame.state[i] in (S_QUARANTINED, S_VOIDED):
        rec.append({"type": "event", "name": "flight.quarantined",
                    "cat": "flights", "lane": "virtual", "t": ta,
                    "args": dict(common, state=state)})
    rec.append({"type": "event", "name": "flight.outcome", "cat": "flights",
                "lane": "virtual", "t": ta,
                "args": dict(common, state=state)})
    # host-lane anchor: the Perfetto exporter links this zero-duration
    # server-side span to the virtual-lane flight spans via a flow chain,
    # tying the two time lanes together for one contribution
    now = rec.now()
    rec.append({"type": "span", "name": "flight.server", "cat": "flights",
                "lane": "host", "t0": now, "t1": now,
                "args": dict(common, state=state)})


def log_frames(rec, frames: Sequence[FlightFrame],
               exemplars_per_frame: int = 8) -> None:
    """Emit each frame's rollup + exemplar lifecycles into a recorder.

    Called by ``obs.log_trace`` at end of run — AFTER the runtime has
    applied its screening verdicts, so quarantined/voided flights are
    exemplar-eligible with their final states.
    """
    for fr in frames:
        _emit_rollup(rec, fr)
        for i in select_exemplars(fr, exemplars_per_frame).tolist():
            _emit_exemplar(rec, fr, i)
