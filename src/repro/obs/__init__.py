"""Run-wide telemetry: spans, sync-free in-jit metrics, exporters, inspector.

Three pillars, one event log:

  spans.py   — `span`/`virtual_span`/`event`/`instrument` record host
               wall-clock and the scheduler's simulated clock as parallel
               lanes into a module-level `Recorder` (`configure` installs
               one; everything is a no-op otherwise, and inside jit
               tracing). The hot path is permanently instrumented:
               scheduler rounds, executor execute/place, wire
               encode/decode, Lloyd/kmeans, checkpoint save/restore.
  metrics.py — `MetricsBuffer` plus jit-safe `counter`/`gauge`/`histogram`
               helpers: metrics accumulate as arrays inside jitted steps
               and ride the existing aux pytrees; the host records them
               without looking and flushes the whole run with exactly one
               ``jax.device_get`` — instrumentation adds zero host syncs.
  export.py  — append-only JSONL event logs and Chrome/Perfetto
               ``trace_event`` JSON (host and virtual lanes render as two
               processes with per-category tracks).
  inspect.py — ``python -m repro.obs <run.jsonl>``: round tables,
               duration percentiles, the per-direction/per-wire-kind byte
               ledger, bytes/time-to-target, ``--health`` SLO grading and
               ``--flight`` lifecycle drill-down.
  flight.py  — level 2: the contribution flight recorder. Every cohort
               contribution gets a stable ``flight_id`` and a recorded
               causal lifecycle (sampled → placed → uplink →
               retry/re-home/quarantine/drop → aggregate) as column-array
               `FlightFrame`s on ``Trace.flights``, emitted into the
               event log as per-update rollups + reservoir exemplars.
  slo.py     — declarative windowed SLO rules over trace reductions;
               violations become structured ``slo_violation`` events.
  schema.py  — the obs event-name registry fedlint's ``orphan-obs-event``
               pass checks `repro/federated/` emissions against.

Typical wiring (what ``bench_network.py --emit-trace`` and the femnist
example's ``--emit-trace`` flag do):

    from repro import obs
    obs.configure(run="bench", meta={"fleet": "lognormal"})
    ...  # run training; Scheduler/executor/wire spans record themselves
    rec = obs.shutdown()
    rec.write_jsonl("run.jsonl")
    rec.write_perfetto("run.perfetto.json")
"""

from repro.obs.export import (
    jsonable,
    read_jsonl,
    read_jsonl_tolerant,
    to_perfetto,
    write_jsonl,
    write_perfetto,
)
from repro.obs.flight import (
    FlightFrame,
    flights_enabled,
    log_frames,
    set_flights,
)
from repro.obs.metrics import MetricsBuffer, counter, gauge, histogram
from repro.obs.slo import (
    DEFAULT_SLOS,
    HealthMonitor,
    SloRule,
    parse_rule,
)
from repro.obs.spans import (
    Recorder,
    configure,
    current,
    enabled,
    event,
    instrument,
    shutdown,
    span,
    virtual_span,
)


def log_trace(trace, run=None) -> None:
    """Append a finished `repro.federated.Trace` to the event log.

    Each `RoundRecord` becomes a ``type: "round"`` event on the virtual
    lane carrying participants, per-direction bytes, the wire-kind ledger
    and the round's (already host-side) metrics; the run's meta + summary
    close it out as a ``type: "run"`` event. Duck-typed on the record
    fields so this package never imports the federated layer."""
    rec = current()
    if rec is None:
        return
    for r in trace:
        rec.append({
            "type": "round", "lane": "virtual", "cat": "rounds",
            "name": f"round {r.round}",
            "t0": float(r.t_start), "t1": float(r.t_end),
            "args": {"round": r.round,
                     "participants": len(r.participants),
                     "dropped": len(r.dropped),
                     "uplink_bytes": r.uplink_bytes,
                     "downlink_bytes": r.downlink_bytes,
                     "staleness": list(r.staleness),
                     "ledger": dict(r.ledger),
                     "faults": dict(getattr(r, "faults", {}) or {}),
                     "metrics": dict(r.metrics)}})
    # the contribution flight layer: per-update rollup histograms plus
    # reservoir-sampled exemplar lifecycles (called after the runtime has
    # applied screening verdicts, so exemplars carry final states)
    frames = getattr(trace, "flights", None)
    if frames:
        log_frames(rec, frames)
    rec.append({"type": "run", "lane": "host", "cat": "obs",
                "name": run or rec.run, "t": rec.now(),
                "args": {"meta": jsonable(dict(trace.meta)),
                         "summary": jsonable(trace.summary())}})


__all__ = [
    "DEFAULT_SLOS", "FlightFrame", "HealthMonitor", "MetricsBuffer",
    "Recorder", "SloRule", "configure", "counter", "current", "enabled",
    "event", "flights_enabled", "gauge", "histogram", "instrument",
    "jsonable", "log_frames", "log_trace", "parse_rule", "read_jsonl",
    "read_jsonl_tolerant", "set_flights", "shutdown", "span",
    "to_perfetto", "virtual_span", "write_jsonl", "write_perfetto",
]
