"""Declarative SLO health monitors over trace reductions.

A `SloRule` names a windowed signal (round-duration percentiles, drop
rate, quarantine rate, retry-byte overhead, per-tier bytes budgets...),
a comparison, and a threshold. `HealthMonitor.check(trace)` evaluates a
rule set against a finished `repro.federated.Trace` (duck-typed — this
package never imports the federated layer), emits one structured
``slo_violation`` obs event per failing rule, and returns the full
result list; `FederatedTrainer(slo_monitor=...)` runs it automatically
at end of run, and the same signal set feeds `TraceAutoscaler.observe`.

The inspector consumes the second entry point: `signals_from_rows`
rebuilds the signal dict from a run log's ``type: "round"`` rows, so
``python -m repro.obs <run.jsonl> --health`` grades a *recorded* run
with the identical rules — including ad-hoc ones parsed from
``--slo "drop_rate<=0.3"`` specs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Sequence

from repro.obs.spans import event as _obs_event

__all__ = ["SloRule", "SloResult", "HealthMonitor", "DEFAULT_SLOS",
           "parse_rule", "trace_signals", "signals_from_rows"]

#: signal names `trace_signals` / `signals_from_rows` always populate
SIGNALS = (
    "rounds", "round_duration_p50_s", "round_duration_p99_s", "tail_ratio",
    "drop_rate", "quarantine_rate", "retry_byte_overhead",
    "corrupt_undetected", "uplink_bytes_per_round",
    "downlink_bytes_per_round", "edge_uplink_bytes_per_round",
    "server_uplink_bytes_per_round",
)


@dataclasses.dataclass(frozen=True)
class SloRule:
    """``signal op threshold`` over the last ``window`` updates (all
    when None). ``op`` is "<=" (budget) or ">=" (floor)."""
    name: str
    signal: str
    op: str = "<="
    threshold: float = 0.0
    window: Optional[int] = None

    def ok(self, value: float) -> bool:
        if self.op == "<=":
            return value <= self.threshold
        if self.op == ">=":
            return value >= self.threshold
        raise ValueError(f"unknown SLO op {self.op!r}")


@dataclasses.dataclass(frozen=True)
class SloResult:
    rule: SloRule
    value: Optional[float]   # None = signal not measurable on this run
    ok: bool

    def describe(self) -> str:
        status = "PASS" if self.ok else "FAIL"
        val = "n/a" if self.value is None else f"{self.value:.6g}"
        win = f" (last {self.rule.window})" if self.rule.window else ""
        return (f"{status}  {self.rule.name}: {self.rule.signal}={val} "
                f"{self.rule.op} {self.rule.threshold:g}{win}")


# permissive run-health defaults: generous enough that a healthy chaos
# run passes, tight enough that a pathological one (storm-level drop /
# quarantine, runaway retry bytes) trips
DEFAULT_SLOS = (
    SloRule("straggler-tail", "tail_ratio", "<=", 3.0),
    SloRule("drop-rate", "drop_rate", "<=", 0.5),
    SloRule("quarantine-rate", "quarantine_rate", "<=", 0.25),
    SloRule("retry-byte-overhead", "retry_byte_overhead", "<=", 0.5),
    SloRule("corruption-detected", "corrupt_undetected", "<=", 0.0),
)

_RULE_RE = re.compile(
    r"^\s*([A-Za-z0-9_.]+)\s*(<=|>=)\s*([-+0-9.eE]+)"
    r"(?:\s*@\s*(\d+))?\s*$")


def parse_rule(spec: str) -> SloRule:
    """Parse ``"signal<=threshold"`` / ``"signal>=threshold@window"``
    (the ``--slo`` CLI syntax) into a rule named after the spec."""
    m = _RULE_RE.match(spec)
    if m is None:
        raise ValueError(
            f"bad SLO spec {spec!r}; expected e.g. 'drop_rate<=0.3' or "
            f"'rounds>=5@20'")
    sig, op, thr, win = m.groups()
    return SloRule(name=spec.strip(), signal=sig, op=op,
                   threshold=float(thr),
                   window=int(win) if win else None)


def _fault_ledger_signals(recs, sig: Dict[str, float]) -> None:
    participants = sum(len(r.participants) for r in recs)
    quarantined = sum(r.faults.get("quarantined", 0) for r in recs)
    sig["quarantine_rate"] = \
        quarantined / participants if participants else 0.0
    sig["corrupt_undetected"] = float(
        sum(r.faults.get("corrupt_undetected", 0) for r in recs))
    retry = sum(v for r in recs for k, v in r.ledger.items()
                if k.startswith("retry_downlink/"))
    down = sum(v for r in recs for k, v in r.ledger.items()
               if k.startswith("downlink/"))
    sig["retry_byte_overhead"] = retry / down if down else 0.0


def trace_signals(trace, window: Optional[int] = None) -> Dict[str, float]:
    """The SLO signal dict from a live `Trace` (duck-typed reductions)."""
    recs = list(trace.window(window))
    sig: Dict[str, float] = {
        "rounds": float(len(recs)),
        "round_duration_p50_s": trace.duration_percentile(50.0, window),
        "round_duration_p99_s": trace.duration_percentile(99.0, window),
        "tail_ratio": trace.tail_ratio(window),
        "drop_rate": trace.drop_rate(window),
        "uplink_bytes_per_round": trace.bytes_per_round(window, "uplink"),
        "downlink_bytes_per_round":
            trace.bytes_per_round(window, "downlink"),
        "edge_uplink_bytes_per_round":
            trace.tier_bytes_per_round("edge_uplink", window),
        "server_uplink_bytes_per_round":
            trace.tier_bytes_per_round("server_uplink", window),
    }
    _fault_ledger_signals(recs, sig)
    return sig


def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    pos = (len(xs) - 1) * min(max(q, 0.0), 100.0) / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


def signals_from_rows(rows: Sequence[Dict[str, Any]],
                      window: Optional[int] = None) -> Dict[str, float]:
    """The same signal dict rebuilt from a run log's ``type: "round"``
    events (`repro.obs.inspect` row dicts) — the offline --health path."""
    rows = list(rows)[-window:] if window else list(rows)
    durs = [float(r["t_end"]) - float(r["t_start"]) for r in rows]
    n = len(rows) or 1
    p50 = _percentile(durs, 50.0)
    participants = sum(int(r.get("participants", 0)) for r in rows)
    dropped = sum(int(r.get("dropped", 0)) for r in rows)
    quarantined = sum(int((r.get("faults") or {}).get("quarantined", 0))
                      for r in rows)
    retry = sum(v for r in rows
                for k, v in (r.get("ledger") or {}).items()
                if k.startswith("retry_downlink/"))
    down = sum(v for r in rows
               for k, v in (r.get("ledger") or {}).items()
               if k.startswith("downlink/"))

    def tier(prefix: str) -> float:
        total = sum(v for r in rows
                    for k, v in (r.get("ledger") or {}).items()
                    if k.startswith(prefix + "/"))
        return total / n

    return {
        "rounds": float(len(rows)),
        "round_duration_p50_s": p50,
        "round_duration_p99_s": _percentile(durs, 99.0),
        "tail_ratio": _percentile(durs, 95.0) / p50 if p50 > 0 else 1.0,
        "drop_rate": dropped / (dropped + participants)
        if dropped + participants else 0.0,
        "quarantine_rate":
            quarantined / participants if participants else 0.0,
        "corrupt_undetected": float(
            sum(int((r.get("faults") or {}).get("corrupt_undetected", 0))
                for r in rows)),
        "retry_byte_overhead": retry / down if down else 0.0,
        "uplink_bytes_per_round":
            sum(int(r.get("uplink_bytes", 0)) for r in rows) / n,
        "downlink_bytes_per_round":
            sum(int(r.get("downlink_bytes", 0)) for r in rows) / n,
        "edge_uplink_bytes_per_round": tier("edge_uplink"),
        "server_uplink_bytes_per_round": tier("server_uplink"),
    }


class HealthMonitor:
    """Evaluate a rule set; `check` additionally emits ``slo_violation``
    obs events so failures land in the run's own event log."""

    def __init__(self, rules: Sequence[SloRule] = DEFAULT_SLOS):
        self.rules = tuple(rules)

    def _evaluate(self, signal_fn) -> List[SloResult]:
        by_window: Dict[Optional[int], Dict[str, float]] = {}
        out: List[SloResult] = []
        for rule in self.rules:
            if rule.window not in by_window:
                by_window[rule.window] = signal_fn(rule.window)
            sig = by_window[rule.window]
            value = sig.get(rule.signal)
            if value is None:
                # unknown/unmeasurable signal: not a violation, but
                # visible as value=n/a in the report
                out.append(SloResult(rule, None, True))
            else:
                out.append(SloResult(rule, float(value),
                                     rule.ok(float(value))))
        return out

    def evaluate(self, trace) -> List[SloResult]:
        return self._evaluate(lambda w: trace_signals(trace, w))

    def evaluate_rows(self, rows: Sequence[Dict[str, Any]],
                      ) -> List[SloResult]:
        return self._evaluate(lambda w: signals_from_rows(rows, w))

    def check(self, trace) -> List[SloResult]:
        results = self.evaluate(trace)
        for res in results:
            if not res.ok:
                _obs_event("slo_violation", cat="slo",
                           rule=res.rule.name, signal=res.rule.signal,
                           op=res.rule.op, threshold=res.rule.threshold,
                           value=res.value, window=res.rule.window)
        return results
