"""Pallas TPU kernel: K-means assignment (the PQ hot spot).

For a block of subvectors X ∈ R^{BN×D} and a codebook C ∈ R^{L×D}, computes

    codes[i]  = argmin_l ‖x_i − c_l‖²  = argmax_l (2·x_i·c_l − ‖c_l‖²)
    sqdist[i] = ‖x_i‖² − max_l (...)

Design for v5e:
  * the codebook lives in VMEM for the whole grid (L ≤ 1024, D = d/q ≤ 128
    for every paper/assigned config -> ≤ 512 KiB, well under ~16 MiB VMEM);
  * X is streamed through VMEM in (BLOCK_N, D) tiles — one HBM pass;
  * the distance cross-term rides the MXU as a (BLOCK_N×D)·(D×L) matmul in
    fp32 (``preferred_element_type``), argmax happens in VREGs;
  * BLOCK_N is a multiple of 8 sublanes; L and D are zero-padded to lane
    multiples by the ops.py wrapper, padding columns masked with -inf.

Validated against ``ref.py`` in interpret mode (CPU container; TPU is the
compile target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _assign_kernel(x_ref, c_ref, cnorm_ref, lmask_ref, codes_ref, dist_ref):
    x = x_ref[...].astype(jnp.float32)            # (BN, D)
    c = c_ref[...].astype(jnp.float32)            # (L, D)
    cnorm = cnorm_ref[...]                        # (1, L)
    lmask = lmask_ref[...]                        # (1, L) 1.0 = valid centroid
    # scores[i,l] = 2·x_i·c_l − ‖c_l‖²   (MXU matmul)
    scores = 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) - cnorm
    scores = jnp.where(lmask > 0, scores, NEG)
    codes_ref[...] = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    xnorm = jnp.sum(x * x, axis=-1)
    dist_ref[...] = jnp.maximum(xnorm - jnp.max(scores, axis=-1), 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_kernel(x: jax.Array, centroids: jax.Array, lmask: jax.Array,
                         *, block_n: int = 512, interpret: bool = False):
    """x: (N, D) with N % block_n == 0; centroids: (L, D); lmask: (L,).

    Returns (codes (N,) int32, sqdist (N,) f32).
    """
    n, d = x.shape
    l = centroids.shape[0]
    cnorm = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=-1)[None, :]
    grid = (n // block_n,)
    codes, dist = pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # stream X tiles
            pl.BlockSpec((l, d), lambda i: (0, 0)),         # codebook resident
            pl.BlockSpec((1, l), lambda i: (0, 0)),
            pl.BlockSpec((1, l), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=interpret,
    )(x, centroids, cnorm, lmask[None, :].astype(jnp.float32))
    return codes, dist
