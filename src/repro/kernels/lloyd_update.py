"""Pallas TPU kernel: fused Lloyd *update* (assign + deviation-accumulate).

The Lloyd iteration is the per-step K-means tax FedLite pays at the cut
layer: for every train step, every iteration re-reads the activations,
assigns them, and accumulates centroid statistics. The PR 1 jnp path fuses
the assign into the scan body, but XLA still materializes a ``(chunk, L)``
one-hot and issues a second centroid read (the ``cents[codes]`` gather) per
scan step. This kernel does the whole iteration in ONE HBM sweep over X:

    codes[i]   = argmin_l ‖x_i − c_l‖²                      (MXU matmul)
    dsums[l]  += Σ_{i: codes_i=l} w_i · (x_i − c_l)         (MXU matmul)
    counts[l] += Σ_{i: codes_i=l} w_i

The one-hot exists only in VREGs/VMEM; the codebook is VMEM-resident for
the whole grid; the accumulators are a single (L, D) + (1, L) output block
revisited by every grid step (TPU grids are sequential, so the constant
``index_map`` makes the output an accumulator — zeroed at ``program_id 0``).
HBM traffic per iteration: one read of X (+ the (N,) weights) and O(L·D)
accumulator writes, vs the scan's X read + one-hot materialization + second
centroid read.

Numerics: statistics are accumulated as *deviations from the current
centroid* (``x − c_old``), matching the jnp scan bit-for-bit in structure —
a cluster whose members all equal its centroid contributes an exactly-zero
update (products of exact one-hot rows with an exactly-zero delta), which
the FedLite ≡ SplitFed gradient-equivalence test depends on. Rows with
weight 0 (padding) contribute exactly nothing. Empty clusters report
``counts == 0`` and the caller keeps the previous centroid.

Validated against ``ref.lloyd_update_ref`` in interpret mode (CPU
container); compiled Mosaic on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _update_kernel(x_ref, w_ref, c_ref, cnorm_ref, lmask_ref,
                   dsums_ref, counts_ref):
    # zero the accumulators once; later grid steps revisit the same block
    @pl.when(pl.program_id(0) == 0)
    def _():
        dsums_ref[...] = jnp.zeros_like(dsums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    x = x_ref[...].astype(jnp.float32)              # (BN, D)
    w = w_ref[...].astype(jnp.float32)              # (BN,)
    c = c_ref[...].astype(jnp.float32)              # (L, D)
    # scores[i,l] = 2·x_i·c_l − ‖c_l‖²   (MXU; ‖x‖² is constant over l)
    scores = 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) - cnorm_ref[...]
    scores = jnp.where(lmask_ref[...] > 0, scores, NEG)
    codes = jnp.argmax(scores, axis=-1)
    # one-hot lives only in VREGs; the gather is a one-hot matmul (MXU)
    onehot = (codes[:, None] == jnp.arange(c.shape[0])[None, :]
              ).astype(jnp.float32)
    zt = jax.lax.dot_general(onehot, c, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    delta = x - zt                                  # exact 0 on exact cover
    ohw = onehot * w[:, None]                       # padded rows weigh 0
    dsums_ref[...] += jax.lax.dot_general(
        ohw, delta, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(ohw, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_update_kernel(x: jax.Array, weights: jax.Array,
                        centroids: jax.Array, lmask: jax.Array, *,
                        block_n: int = 512, interpret: bool = False):
    """x: (N, D) with N % block_n == 0; weights: (N,); centroids: (L, D);
    lmask: (L,) 1.0 = valid centroid.

    Returns (dsums (L, D) f32 = Σ onehot·(x − c_old), counts (L,) f32).
    """
    n, d = x.shape
    l = centroids.shape[0]
    cnorm = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=-1)[None, :]
    dsums, counts = pl.pallas_call(
        _update_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),   # stream X tiles
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((l, d), lambda i: (0, 0)),         # codebook resident
            pl.BlockSpec((1, l), lambda i: (0, 0)),
            pl.BlockSpec((1, l), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((l, d), lambda i: (0, 0)),         # accumulators:
            pl.BlockSpec((1, l), lambda i: (0, 0)),         # same block ∀ i
        ],
        out_shape=[
            jax.ShapeDtypeStruct((l, d), jnp.float32),
            jax.ShapeDtypeStruct((1, l), jnp.float32),
        ],
        interpret=interpret,
    )(x, weights.astype(jnp.float32), centroids, cnorm,
      lmask[None, :].astype(jnp.float32))
    return dsums, counts[0]
