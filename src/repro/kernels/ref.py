"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_ref(x: jax.Array, centroids: jax.Array, lmask: jax.Array):
    """codes + squared distances; invalid centroids (lmask==0) excluded."""
    xf = x.astype(jnp.float32)
    cf = centroids.astype(jnp.float32)
    d2 = (jnp.sum(xf * xf, -1)[:, None] - 2.0 * xf @ cf.T
          + jnp.sum(cf * cf, -1)[None, :])
    d2 = jnp.where(lmask[None, :] > 0, d2, jnp.inf)
    codes = jnp.argmin(d2, axis=-1).astype(jnp.int32)
    return codes, jnp.maximum(jnp.min(d2, axis=-1), 0.0)


def pq_quantize_ref(x: jax.Array, centroids: jax.Array, lmask: jax.Array):
    codes, _ = kmeans_assign_ref(x, centroids, lmask)
    zt = centroids.astype(jnp.float32)[codes]
    resid = x.astype(jnp.float32) - zt
    return zt.astype(x.dtype), resid, codes


def lloyd_update_ref(x: jax.Array, weights: jax.Array, centroids: jax.Array,
                     lmask: jax.Array):
    """One Lloyd iteration's statistics, deviation-accumulated:
    dsums[l] = Σ_i w_i·1[codes_i = l]·(x_i − c_l), counts[l] = Σ_i w_i."""
    codes, _ = kmeans_assign_ref(x, centroids, lmask)
    cf = centroids.astype(jnp.float32)
    onehot = jax.nn.one_hot(codes, cf.shape[0], dtype=jnp.float32) \
        * weights.astype(jnp.float32)[:, None]
    delta = x.astype(jnp.float32) - cf[codes]
    return onehot.T @ delta, onehot.sum(axis=0)
