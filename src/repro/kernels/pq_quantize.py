"""Pallas TPU kernel: fused PQ quantize-forward (assign + gather + residual).

The naive forward does three HBM sweeps over the activations: (1) distance/
argmin, (2) centroid gather to build z̃, (3) residual z − z̃ for the
gradient-correction term. This kernel fuses them: for each (BLOCK_N, D) tile
the codebook is VMEM-resident, the assignment is computed on the MXU, and z̃
and (z − z̃) are emitted from the same registers — one read + two writes per
element total.

The gather from the VMEM codebook is expressed as a one-hot (BLOCK_N, L) @
(L, D) matmul — on TPU this is far faster than a row-gather because it rides
the MXU and avoids scalar addressing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _fused_kernel(x_ref, c_ref, cnorm_ref, lmask_ref,
                  zt_ref, resid_ref, codes_ref):
    x = x_ref[...].astype(jnp.float32)              # (BN, D)
    c = c_ref[...].astype(jnp.float32)              # (L, D)
    scores = 2.0 * jax.lax.dot_general(
        x, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) - cnorm_ref[...]
    scores = jnp.where(lmask_ref[...] > 0, scores, NEG)
    codes = jnp.argmax(scores, axis=-1)
    codes_ref[...] = codes.astype(jnp.int32)
    # one-hot matmul gather (MXU-friendly; no scalar addressing)
    onehot = (codes[:, None] == jnp.arange(c.shape[0])[None, :]
              ).astype(jnp.float32)
    zt = jax.lax.dot_general(onehot, c, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    zt_ref[...] = zt.astype(zt_ref.dtype)
    resid_ref[...] = (x - zt).astype(resid_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_quantize_kernel(x: jax.Array, centroids: jax.Array, lmask: jax.Array,
                       *, block_n: int = 512, interpret: bool = False):
    """x: (N, D), N % block_n == 0; centroids (L, D); lmask (L,).

    Returns (z_tilde (N, D) x.dtype, residual (N, D) f32, codes (N,) int32).
    """
    n, d = x.shape
    l = centroids.shape[0]
    cnorm = jnp.sum(centroids.astype(jnp.float32) ** 2, axis=-1)[None, :]
    zt, resid, codes = pl.pallas_call(
        _fused_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((l, d), lambda i: (0, 0)),
            pl.BlockSpec((1, l), lambda i: (0, 0)),
            pl.BlockSpec((1, l), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), x.dtype),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(x, centroids, cnorm, lmask[None, :].astype(jnp.float32))
    return zt, resid, codes
