"""Pallas TPU kernel: causal flash attention (forward).

The §Perf analysis of llama3 × train_4k showed the memory roofline term is
dominated by attention score/prob traffic (O(S²) HBM bytes at S=4096 per
layer even with row-block chunking). Flash attention keeps the running
(m, l, acc) online-softmax state in VMEM scratch so probabilities NEVER
visit HBM: per layer traffic drops from O(S²) to O(S·d).

Kernel layout (v5e):
  * grid = (B·H, n_q_blocks, n_kv_blocks); the last grid dim iterates
    sequentially on TPU, so the kv loop accumulates into VMEM scratch;
  * q/k/v stream as (BLOCK_Q, hd) / (BLOCK_K, hd) VMEM tiles; GQA is
    expressed in the k/v BlockSpec index_map (query head -> kv head =
    head // group), so kv heads are never materialized per-query-head;
  * both matmuls ride the MXU in fp32; masking is block-index arithmetic
    (causal + optional sliding window);
  * the output tile is written once per (bh, q-block), on the last kv step.

Backward uses the pure-JAX path (row_block_attention + jax.checkpoint) —
this kernel is the serving/prefill fast path. Interpret-mode parity with
the pure-jnp oracle is tested in tests/test_flash.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int,
                  window, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                   # (BQ, hd)
    k = k_ref[0].astype(jnp.float32)                   # (BK, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    keep = qpos >= kpos
    if window is not None:
        keep &= (qpos - kpos) < window
    s = jnp.where(keep, s, NEG)

    m_prev = m_ref[...]                                # (BQ, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                             # (BQ, BK)
    l_ref[...] = alpha * l_prev + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("num_q_heads", "num_kv_heads",
                                             "scale", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    num_q_heads: int, num_kv_heads: int, scale: float,
                    window=None, block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Causal flash attention with GQA-aware kv indexing.

    q: (B·H, S, hd); k/v: (B·Kv, S, hd). Requires S % block == 0 (the
    ops-level wrapper in repro.kernels.ops pads). Returns (B·H, S, hd).
    """
    BH, S, hd = q.shape
    H, Kv = num_q_heads, num_kv_heads
    G = H // Kv
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    n_q = S // block_q
    n_kv = S // block_k

    def kv_index(bh, qi, ki):
        return ((bh // H) * Kv + (bh % H) // G, ki, 0)

    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_k=block_k, window=window, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd),
                               lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum l
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
