"""jit'd public wrappers around the Pallas kernels.

Handles padding (N to a block multiple, L to a lane-friendly multiple) and
interpret-mode selection: ``interpret=True`` on non-TPU backends so the CPU
container executes the kernel bodies in Python for validation, compiled
Mosaic kernels on real TPUs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kmeans_assign import kmeans_assign_kernel
from repro.kernels.pq_quantize import pq_quantize_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_rows(x, block):
    n = x.shape[0]
    pad = (-n) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def _pad_centroids(c, lane: int = 8):
    l = c.shape[0]
    pad = (-l) % lane
    lmask = jnp.concatenate([jnp.ones(l, jnp.float32),
                             jnp.zeros(pad, jnp.float32)])
    if pad:
        c = jnp.concatenate([c, jnp.zeros((pad, c.shape[1]), c.dtype)])
    return c, lmask


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign(x: jax.Array, centroids: jax.Array, *,
                  block_n: int = 512, interpret: bool | None = None):
    """codes[i] = argmin_l ‖x_i − c_l‖²; also returns squared distances.

    x: (N, D) any float dtype; centroids: (L, D). Arbitrary N, L (padded
    internally).
    """
    interpret = _interpret_default() if interpret is None else interpret
    block_n = min(block_n, max(8, x.shape[0]))
    xp, n = _pad_rows(x, block_n)
    cp, lmask = _pad_centroids(centroids)
    codes, dist = kmeans_assign_kernel(xp, cp, lmask, block_n=block_n,
                                       interpret=interpret)
    return codes[:n], dist[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def pq_quantize(x: jax.Array, centroids: jax.Array, *,
                block_n: int = 512, interpret: bool | None = None):
    """Fused assign + dequantize + residual. Returns (z̃, residual, codes)."""
    interpret = _interpret_default() if interpret is None else interpret
    block_n = min(block_n, max(8, x.shape[0]))
    xp, n = _pad_rows(x, block_n)
    cp, lmask = _pad_centroids(centroids)
    zt, resid, codes = pq_quantize_kernel(xp, cp, lmask, block_n=block_n,
                                          interpret=interpret)
    return zt[:n], resid[:n], codes[:n]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def lloyd_update(x: jax.Array, centroids: jax.Array,
                 weights: jax.Array | None = None, *,
                 block_n: int = 512, interpret: bool | None = None):
    """Fused Lloyd-iteration statistics: assign + deviation-accumulate in one
    HBM sweep (``kernels/lloyd_update.py``).

    x: (N, D) any float dtype; centroids: (L, D); weights: optional (N,)
    per-row weights (padding rows carry 0). Arbitrary N, L (padded
    internally; padded rows weigh zero, padded centroids are masked).
    Returns (dsums (L, D) f32 = Σ onehot·(x − c_old), counts (L,) f32).
    """
    from repro.kernels.lloyd_update import lloyd_update_kernel
    interpret = _interpret_default() if interpret is None else interpret
    l = centroids.shape[0]
    if weights is None:
        weights = jnp.ones((x.shape[0],), jnp.float32)
    block_n = min(block_n, max(8, x.shape[0]))
    xp, n = _pad_rows(x, block_n)
    wp, _ = _pad_rows(weights.astype(jnp.float32), block_n)
    cp, lmask = _pad_centroids(centroids)
    dsums, counts = lloyd_update_kernel(xp, wp, cp, lmask, block_n=block_n,
                                        interpret=interpret)
    return dsums[:l], counts[:l]


@functools.partial(jax.jit, static_argnames=("bits", "block_n", "interpret"))
def scalar_quantize(x: jax.Array, lo: jax.Array, scale: jax.Array,
                    bits: int, *, block_n: int = 512,
                    interpret: bool | None = None):
    """Fused uniform b-bit quantize + dequantize (scalarq compressor hot
    loop). x: (N, D) any float dtype; lo/scale: () tensor-wide range.
    Returns (codes (N, D) int32, recon (N, D) f32)."""
    from repro.kernels.scalar_quant import scalar_quantize_kernel
    interpret = _interpret_default() if interpret is None else interpret
    block_n = min(block_n, max(8, x.shape[0]))
    xp, n = _pad_rows(x, block_n)
    codes, recon = scalar_quantize_kernel(xp, lo, scale, bits=bits,
                                          block_n=block_n,
                                          interpret=interpret)
    return codes[:n], recon[:n]


@functools.partial(jax.jit, static_argnames=("bits", "block_n", "interpret"))
def pack_codes(codes: jax.Array, bits: int, *, block_n: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """Pack flat int32 codes at ``bits`` bits each into little-endian uint32
    words (32 % bits == 0). Bit-identical to the LSB-first numpy stream
    ``federated/wire.py`` writes. Returns (ceil(N·bits/32),) uint32."""
    from repro.kernels.scalar_quant import pack_codes_kernel
    assert 32 % bits == 0, "device packing needs bits in {1, 2, 4, 8, 16}"
    interpret = _interpret_default() if interpret is None else interpret
    per_word = 32 // bits
    flat = codes.reshape(-1)
    pad = (-flat.shape[0]) % per_word
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    mat = flat.reshape(-1, per_word)
    block_n = min(block_n, max(8, mat.shape[0]))
    matp, n = _pad_rows(mat, block_n)
    return pack_codes_kernel(matp, bits=bits, block_n=block_n,
                             interpret=interpret)[:n]


@functools.partial(jax.jit, static_argnames=("count", "bits", "block_n",
                                             "interpret"))
def unpack_codes(words: jax.Array, count: int, bits: int, *,
                 block_n: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """Inverse of ``pack_codes``: (N_words,) uint32 -> (count,) int32."""
    from repro.kernels.scalar_quant import unpack_codes_kernel
    assert 32 % bits == 0, "device unpacking needs bits in {1, 2, 4, 8, 16}"
    interpret = _interpret_default() if interpret is None else interpret
    block_n = min(block_n, max(8, words.shape[0]))
    wp, n = _pad_rows(words, block_n)
    codes = unpack_codes_kernel(wp, bits=bits, block_n=block_n,
                                interpret=interpret)
    return codes.reshape(-1)[:count]


def assign_impl_for_kmeans(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """Adapter matching the ``Backend.assign`` signature in
    ``repro.core.kmeans`` (used by the built-in "pallas" backend)."""
    codes, _ = kmeans_assign(x, centroids)
    return codes


@functools.partial(jax.jit, static_argnames=("num_q_heads", "num_kv_heads",
                                             "scale", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, num_q_heads: int, num_kv_heads: int,
                    scale: float, window=None, block_q: int = 256,
                    block_k: int = 256, interpret: bool | None = None):
    """Padded wrapper for the flash kernel: accepts any S (pads to the block
    multiple with masked tail — causal masking already zeroes the padding's
    influence on real rows). Layout: q (B·H, S, hd), k/v (B·Kv, S, hd)."""
    from repro.kernels.flash_attention import flash_attention as _fa
    interpret = _interpret_default() if interpret is None else interpret
    s = q.shape[1]
    blk = max(block_q, block_k)
    pad = (-s) % min(blk, max(s, 1))
    if pad:
        zq = jnp.zeros((q.shape[0], pad, q.shape[2]), q.dtype)
        zk = jnp.zeros((k.shape[0], pad, k.shape[2]), k.dtype)
        q = jnp.concatenate([q, zq], axis=1)
        k = jnp.concatenate([k, zk], axis=1)
        v = jnp.concatenate([v, zk], axis=1)
    out = _fa(q, k, v, num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
              scale=scale, window=window,
              block_q=min(block_q, q.shape[1]),
              block_k=min(block_k, q.shape[1]), interpret=interpret)
    return out[:, :s]
