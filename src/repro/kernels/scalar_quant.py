"""Pallas TPU kernels: fused uniform scalar quantize + b-bit code pack/unpack.

The scalarq compressor's hot loop is three elementwise sweeps in the naive
path: (1) normalize + round to codes, (2) dequantize to the reconstruction,
(3) pack codes into b-bit words for the wire. The quantize kernel fuses
(1)+(2) — one HBM read of the activations, codes and reconstruction emitted
from the same registers — and the pack/unpack kernels turn the bit-twiddling
into a single VPU multiply-accumulate over a (BLOCK_N, 32/b) tile.

Packing layout: 32/b codes per little-endian uint32 word, code j occupying
bits [j·b, (j+1)·b). For b ∈ {1, 2, 4, 8, 16} (32 % b == 0) this is exactly
the LSB-first bit stream ``federated/wire.py`` writes with numpy, so device
packing and host packing are interchangeable (asserted in tests).

``lo``/``scale`` are whole-tensor reduction outputs computed by XLA outside
the kernel (a (1, 1) SMEM-friendly operand); the kernel matches the jnp
reference formula ``clip(round((x − lo)/scale), 0, 2^b − 1)`` exactly, so
interpret-mode parity with the "jnp" backend is bitwise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(levels, x_ref, lo_ref, scale_ref, codes_ref, recon_ref):
    x = x_ref[...].astype(jnp.float32)              # (BN, D)
    lo = lo_ref[0, 0]
    scale = scale_ref[0, 0]
    codes = jnp.clip(jnp.round((x - lo) / scale), 0, levels)
    codes_ref[...] = codes.astype(jnp.int32)
    recon_ref[...] = (lo + codes * scale).astype(recon_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block_n", "interpret"))
def scalar_quantize_kernel(x: jax.Array, lo: jax.Array, scale: jax.Array,
                           *, bits: int, block_n: int = 512,
                           interpret: bool = False):
    """x: (N, D), N % block_n == 0; lo/scale: () f32 tensor-wide range.

    Returns (codes (N, D) int32 in [0, 2^bits), recon (N, D) f32).
    """
    n, d = x.shape
    levels = (1 << bits) - 1
    codes, recon = pl.pallas_call(
        functools.partial(_quantize_kernel, float(levels)),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.int32),
            jax.ShapeDtypeStruct((n, d), jnp.float32),
        ],
        interpret=interpret,
    )(x, lo.reshape(1, 1).astype(jnp.float32),
      scale.reshape(1, 1).astype(jnp.float32))
    return codes, recon


def _pack_kernel(bits, codes_ref, words_ref):
    codes = codes_ref[...].astype(jnp.uint32)       # (BN, 32/b)
    per_word = codes.shape[-1]
    weights = (jnp.uint32(1) << (jnp.arange(per_word, dtype=jnp.uint32)
                                 * jnp.uint32(bits)))
    words_ref[...] = jnp.sum(codes * weights[None, :], axis=-1,
                             dtype=jnp.uint32)


@functools.partial(jax.jit, static_argnames=("bits", "block_n", "interpret"))
def pack_codes_kernel(codes: jax.Array, *, bits: int, block_n: int = 512,
                      interpret: bool = False) -> jax.Array:
    """codes: (N_words, 32/bits) int32 -> (N_words,) uint32 packed words."""
    n, per_word = codes.shape
    assert per_word * bits == 32, "pack kernel needs 32 % bits == 0"
    return pl.pallas_call(
        functools.partial(_pack_kernel, bits),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n, per_word), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(codes)


def _unpack_kernel(bits, words_ref, codes_ref):
    words = words_ref[...].astype(jnp.uint32)       # (BN,)
    per_word = codes_ref.shape[-1]
    shifts = (jnp.arange(per_word, dtype=jnp.uint32) * jnp.uint32(bits))
    mask = jnp.uint32((1 << bits) - 1)
    codes_ref[...] = ((words[:, None] >> shifts[None, :]) & mask
                      ).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bits", "block_n", "interpret"))
def unpack_codes_kernel(words: jax.Array, *, bits: int, block_n: int = 512,
                        interpret: bool = False) -> jax.Array:
    """words: (N_words,) uint32 -> (N_words, 32/bits) int32 codes."""
    n = words.shape[0]
    per_word = 32 // bits
    return pl.pallas_call(
        functools.partial(_unpack_kernel, bits),
        grid=(n // block_n,),
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n, per_word), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, per_word), jnp.int32),
        interpret=interpret,
    )(words)
