"""Direction-agnostic cut-layer compressor stack.

FedLite (§4.1) compresses only the *uplink* activations with grouped PQ;
PR 2's measured wire accounting showed the uncompressed cut-layer *gradient*
downlink then dominates bytes-on-the-wire. This module turns the implicit
"compression == uplink PQ" assumption into one explicit abstraction used by
core, federated, launch and benchmarks alike: a `CutCompressor` with
registered implementations

  * ``none``    — identity (dense wire payload; the SplitFed baseline).
  * ``pq``      — FedLite's grouped product quantizer (wraps
                  ``core/quantizer.py`` — behavior-preserving, including the
                  fused Pallas encode and the residual the corrected VJP
                  reuses).
  * ``topk``    — magnitude sparsification keeping a fraction ``k`` of
                  entries; optional error-feedback memory via the
                  `ErrorFeedback` wrapper (Konečný et al. 2016).
  * ``scalarq`` — uniform ``bits``-bit scalar quantization (stochastic
                  rounding when a PRNG key is supplied, nearest otherwise);
                  the quantize/dequantize hot loop has a Pallas kernel
                  (``repro.kernels.scalar_quant``) selected by the same
                  backend registry as the PQ encode.
  * ``chain``   — sequential composition: each stage compresses the dense
                  value *carrier* of the previous stage's payload, e.g.
                  ``chain:topk(k=0.1)+scalarq(bits=8)`` sends bit-packed
                  top-k indices plus 8-bit codes for the survivors.

Every compressor answers three questions:

  * math   — ``compress(z) -> Compressed`` (in-jit; recon + residual +
             payload arrays) and ``decompress``;
  * bits   — ``analytic_bits(n, d, phi)`` (the paper-style cost model,
             decomposed into ``overhead_bits`` + ``carrier_elems`` so chains
             account exactly);
  * wire   — ``wire_payload(comp) -> bytes`` via the versioned tagged codec
             in ``federated/wire.py`` (bit-exact round-trips, measured
             bytes validate the analytic model).

Direction hooks (``jax.custom_vjp``):

  * ``compress_with_correction(_stats)`` — the uplink: forward emits the
    reconstruction, backward adds FedLite's λ·(z − z̃) correction (eq. 5)
    using the residual fused with the forward compress.
  * ``compress_with_correction_carry`` — the state-carrying uplink: same
    correction, but a `CutState` threads cross-round carry through the
    round — PQ codebook warm-start (``compress_stateful`` /
    `core/quantizer.QuantizerState`) and per-client `ErrorFeedback` memory
    — returning ``(recon, distortion, new_state)``.
  * ``compress_downlink`` — the downlink: forward is the identity, backward
    passes the activation COTANGENT through the configured compressor
    before it reaches the client submodel. ``none`` reproduces the
    uncompressed backward pass bitwise (asserted in tests).
  * ``compress_downlink_keyed`` — same, with a per-step PRNG key threaded
    to the backward codec: ``scalarq`` downlinks round stochastically
    (unbiased) instead of to-nearest.

Spec strings (``ArchConfig.uplink_compressor`` / ``downlink_compressor``,
`FederatedTrainer` fields) are parsed by ``make_compressor``:
``"none"``, ``"pq"``, ``"topk(k=0.1)"``, ``"scalarq(bits=8)"``,
``"chain:topk(k=0.1)+scalarq(bits=8)"``.
"""

from __future__ import annotations

import ast
import dataclasses
import functools
import math
import re
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

import numpy as np

from repro.core import kmeans as _km
from repro.core.quantizer import (PQConfig, QuantizedBatch, QuantizerState,
                                  quantize, quantize_stateful)


# ---------------------------------------------------------------------------
# payloads (all-array NamedTuples: vmappable, jit-transparent)
# ---------------------------------------------------------------------------

class DensePayload(NamedTuple):
    values: jax.Array          # the tensor itself (identity compressor)


class SparsePayload(NamedTuple):
    indices: jax.Array         # (k,) int32 into the flattened tensor
    values: jax.Array          # (k,) surviving magnitudes (the carrier)


class ScalarPayload(NamedTuple):
    codes: jax.Array           # int32, input shape, values in [0, 2^bits)
    lo: jax.Array              # () f32 dequant offset
    scale: jax.Array           # () f32 dequant step


class Compressed(NamedTuple):
    """In-jit result of one compress: what the other side reconstructs,
    the residual the corrected VJP consumes, and the wire-able pieces."""
    recon: jax.Array           # decompressed tensor, input shape + dtype
    residual: jax.Array        # z − recon, input shape + dtype
    payload: Any               # DensePayload | QuantizedBatch | SparsePayload
    #                            | ScalarPayload | tuple of stage payloads


class CutState(NamedTuple):
    """Cross-round carry for one cut-layer direction.

    Both fields are optional pytrees; ``None`` means the corresponding
    mechanism is off and its trace never changes:

      * ``quantizer`` — `core/quantizer.QuantizerState`: the previous
        round's PQ codebooks (warm-started Lloyd; also the ``pq-delta``
        wire reference).
      * ``ef_memory`` — error-feedback memory, same shape as the cut
        tensor: the accumulated compression error re-added to the next
        round's input (`ErrorFeedback` semantics, exact telescoping).

    Passing a ``CutState`` (even one with both fields ``None``) to the
    state-aware hooks requests a new state back — the bootstrap round.
    """
    quantizer: Any = None
    ef_memory: Any = None


def index_bits(num_slots: int) -> int:
    """Packed index width for a flattened tensor of ``num_slots`` entries."""
    return max(math.ceil(math.log2(max(num_slots, 2))), 1)


# ---------------------------------------------------------------------------
# the compressor protocol
# ---------------------------------------------------------------------------

class CutCompressor:
    """Base class: a direction-agnostic cut-layer codec.

    Subclasses are frozen dataclasses (hashable → usable as jit statics and
    as fields of the frozen model dataclasses). The default ``analytic_bits``
    composes ``overhead_bits`` (structure the stage transmits itself) with
    ``carrier_elems`` (dense values left for a later stage — or for the wire
    at φ bits when the stage is terminal), which is what makes chained
    accounting exact.
    """
    name: str = "base"

    @property
    def spec(self) -> str:
        """Round-trippable spec string (parameters included) — what traces
        and benchmark rows record as the codec identity."""
        return self.name

    # ---- math (in-jit) ----------------------------------------------------
    def compress(self, z: jax.Array, *,
                 key: Optional[jax.Array] = None) -> Compressed:
        raise NotImplementedError

    def compress_stateful(self, z: jax.Array, state: Any = None, *,
                          key: Optional[jax.Array] = None
                          ) -> Tuple[Compressed, Any]:
        """Warm-start-aware compress: (Compressed, next-round codec state).

        The base implementation is stateless (returns ``None`` state);
        `PQCompressor` overrides it with the cross-round codebook
        warm-start (`core/quantizer.quantize_stateful`)."""
        del state
        return self.compress(z, key=key), None

    def decompress(self, comp: Compressed) -> jax.Array:
        return comp.recon

    def carrier(self, comp: Compressed) -> Optional[jax.Array]:
        """Dense value vector a downstream chain stage may compress further
        (None: the payload is terminal, e.g. pq codebooks+codes)."""
        return None

    def recompose(self, comp: Compressed, carrier_recon: jax.Array,
                  z: jax.Array) -> Compressed:
        """Rebuild ``comp`` after a downstream stage lossily reconstructed
        its carrier. ``z`` is the stage input (for the residual)."""
        raise NotImplementedError(f"{self.name} has no carrier to recompose")

    # ---- analytic accounting ---------------------------------------------
    def overhead_bits(self, n: int, d: int, phi_bits: int) -> int:
        """Bits of structure this stage transmits (indices, scales, ...)."""
        raise NotImplementedError

    def carrier_elems(self, n: int, d: int) -> int:
        """Dense float values this stage leaves for the next one."""
        raise NotImplementedError

    def analytic_bits(self, n: int, d: int, phi_bits: int = 32) -> int:
        """Message bits for an (n, d) batch when this stage is terminal."""
        return self.overhead_bits(n, d, phi_bits) \
            + self.carrier_elems(n, d) * phi_bits

    # ---- wire -------------------------------------------------------------
    def wire_payload(self, comp: Compressed,
                     value_dtype: str = "float16") -> bytes:
        """Serialize to the tagged wire format (``federated/wire.py``)."""
        from repro.federated import wire  # deferred: federated imports core
        return wire.encode_compressed(self, comp, value_dtype=value_dtype)


# ---------------------------------------------------------------------------
# implementations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class NoneCompressor(CutCompressor):
    """Identity: dense payload, ``compress_downlink`` is a bitwise no-op."""
    name: str = dataclasses.field(default="none", init=False)

    def compress(self, z, *, key=None) -> Compressed:
        return Compressed(recon=z, residual=jnp.zeros_like(z),
                          payload=DensePayload(values=z))

    def carrier(self, comp):
        return comp.payload.values

    def recompose(self, comp, carrier_recon, z):
        recon = carrier_recon.reshape(z.shape).astype(z.dtype)
        return Compressed(recon=recon, residual=z - recon,
                          payload=DensePayload(values=recon))

    def overhead_bits(self, n, d, phi_bits):
        return 0

    def carrier_elems(self, n, d):
        return n * d


@dataclasses.dataclass(frozen=True)
class PQCompressor(CutCompressor):
    """FedLite's grouped PQ (§4.1) behind the compressor protocol.

    Delegates to ``core/quantizer.quantize`` — same fused backend encode,
    same ``QuantizedBatch`` (which doubles as the wire payload), so the
    pre-refactor uplink path is preserved exactly."""
    cfg: PQConfig
    name: str = dataclasses.field(default="pq", init=False)

    @property
    def spec(self) -> str:
        return (f"pq(q={self.cfg.num_subvectors},L={self.cfg.num_clusters},"
                f"R={self.cfg.num_groups})")

    def compress(self, z, *, key=None) -> Compressed:
        qb = quantize(z, self.cfg, key=key)
        return Compressed(recon=qb.dequantized, residual=qb.residual,
                          payload=qb)

    def compress_stateful(self, z, state: Optional[QuantizerState] = None, *,
                          key=None) -> Tuple[Compressed, QuantizerState]:
        """Cross-round warm-start: a prior `QuantizerState` makes Lloyd
        resume from last round's codebooks at ``cfg.effective_warm_iters``
        iterations; ``None`` runs the cold path and bootstraps the state."""
        qb, new_state = quantize_stateful(z, self.cfg, state, key)
        return Compressed(recon=qb.dequantized, residual=qb.residual,
                          payload=qb), new_state

    def overhead_bits(self, n, d, phi_bits):
        return self.cfg.message_bits(n, d, phi_bits=phi_bits)

    def carrier_elems(self, n, d):
        return 0


@dataclasses.dataclass(frozen=True)
class TopKCompressor(CutCompressor):
    """Magnitude sparsification: keep the largest-|z| fraction ``k``.

    The payload is (indices, values) over the flattened tensor; the values
    vector is the carrier a chained stage (e.g. ``scalarq``) compresses
    further. Error feedback is NOT applied here — wrap with `ErrorFeedback`
    where the caller owns the memory state."""
    k: float = 0.1
    name: str = dataclasses.field(default="topk", init=False)

    @property
    def spec(self) -> str:
        return f"topk(k={self.k})"

    def __post_init__(self):
        if not 0.0 < self.k <= 1.0:
            raise ValueError(f"topk fraction k={self.k} must be in (0, 1]")

    def k_count(self, num_elems: int) -> int:
        return max(int(round(self.k * num_elems)), 1)

    def compress(self, z, *, key=None) -> Compressed:
        flat = z.reshape(-1)
        kc = self.k_count(flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat.astype(jnp.float32)), kc)
        idx = jnp.sort(idx).astype(jnp.int32)   # canonical order for the wire
        vals = flat[idx]
        recon = jnp.zeros_like(flat).at[idx].set(vals).reshape(z.shape)
        return Compressed(recon=recon, residual=z - recon,
                          payload=SparsePayload(indices=idx, values=vals))

    def carrier(self, comp):
        return comp.payload.values

    def recompose(self, comp, carrier_recon, z):
        flat = jnp.zeros(z.size, z.dtype).at[comp.payload.indices].set(
            carrier_recon.astype(z.dtype))
        recon = flat.reshape(z.shape)
        return Compressed(recon=recon, residual=z - recon,
                          payload=SparsePayload(indices=comp.payload.indices,
                                                values=carrier_recon))

    def overhead_bits(self, n, d, phi_bits):
        return self.k_count(n * d) * index_bits(n * d)

    def carrier_elems(self, n, d):
        return self.k_count(n * d)


@dataclasses.dataclass(frozen=True)
class ScalarQuantCompressor(CutCompressor):
    """Uniform b-bit scalar quantization over the tensor's [min, max] range.

    ``codes = round((z − lo)/scale)`` with ``scale = (hi − lo)/(2^b − 1)``;
    stochastic rounding (unbiased, Caldas et al. 2018) when a PRNG ``key``
    is passed to ``compress``, nearest rounding otherwise — the downlink
    VJP hook runs keyless, hence deterministic. The quantize/dequantize hot
    loop runs through the same backend registry as the PQ encode: the
    Pallas kernel (``repro.kernels.scalar_quant``) on "pallas"/"auto"-on-TPU,
    pure jnp elsewhere."""
    bits: int = 8
    backend: str = "auto"
    name: str = dataclasses.field(default="scalarq", init=False)

    @property
    def spec(self) -> str:
        return f"scalarq(bits={self.bits})"

    def __post_init__(self):
        if not 1 <= self.bits <= 16:
            raise ValueError(f"scalarq bits={self.bits} must be in [1, 16]")
        if self.backend not in _km.available_backends():
            raise ValueError(f"backend={self.backend!r} not one of "
                             f"{_km.available_backends()}")

    def compress(self, z, *, key=None) -> Compressed:
        zf = z.astype(jnp.float32)
        lo = jnp.min(zf)
        hi = jnp.max(zf)
        levels = (1 << self.bits) - 1
        scale = (hi - lo) / levels
        scale = jnp.where(scale > 0, scale, 1.0).astype(jnp.float32)
        t = (zf - lo) / scale
        if key is not None:   # stochastic rounding: E[codes·scale] = z − lo
            t = jnp.floor(t + jax.random.uniform(key, t.shape))
        use_kernel = key is None and \
            _km.resolve_backend(self.backend) == "pallas"
        if use_kernel:
            from repro.kernels import ops
            codes, recon = ops.scalar_quantize(
                zf.reshape(-1, z.shape[-1]) if z.ndim > 1 else zf.reshape(1, -1),
                lo, scale, self.bits)
            codes = codes.reshape(z.shape)
            recon = recon.reshape(z.shape).astype(z.dtype)
        else:
            codes = jnp.clip(jnp.round(t), 0, levels).astype(jnp.int32)
            recon = (lo + codes.astype(jnp.float32) * scale).astype(z.dtype)
        return Compressed(recon=recon, residual=z - recon,
                          payload=ScalarPayload(codes=codes, lo=lo,
                                                scale=scale))

    def overhead_bits(self, n, d, phi_bits):
        return 2 * 32 + n * d * self.bits   # lo + scale at f32, packed codes

    def carrier_elems(self, n, d):
        return 0


@dataclasses.dataclass(frozen=True)
class ChainCompressor(CutCompressor):
    """Sequential composition: stage i+1 compresses stage i's carrier.

    Only the first stage sees the (n, d) tensor; later stages see the dense
    value vector the previous payload still carries (e.g. top-k survivor
    values). A stage with no carrier (pq, scalarq) terminates the chain."""
    stages: Tuple[CutCompressor, ...]
    name: str = dataclasses.field(default="chain", init=False)

    def __post_init__(self):
        if len(self.stages) < 2:
            raise ValueError("chain needs at least two stages")
        for s in self.stages[:-1]:
            if s.carrier_elems(1, 1) == 0 and not isinstance(s, NoneCompressor):
                raise ValueError(
                    f"chain stage {s.name!r} is terminal (no carrier); "
                    f"only the last stage may be")

    @property
    def spec(self) -> str:
        return "chain:" + "+".join(s.spec for s in self.stages)

    def compress(self, z, *, key=None) -> Compressed:
        keys = [None] * len(self.stages) if key is None else \
            list(jax.random.split(key, len(self.stages)))
        comps = []
        inputs = []
        x = z
        for stage, k in zip(self.stages, keys):
            inputs.append(x)
            comp = stage.compress(x, key=k)
            comps.append(comp)
            x = stage.carrier(comp)
            if x is None:
                break
        # fold the last stage's lossy reconstruction back up the chain
        recon = comps[-1].recon
        executed = self.stages[:len(comps)]
        for stage, comp, x_in in zip(reversed(executed[:-1]),
                                     reversed(comps[:-1]),
                                     reversed(inputs[:-1])):
            comp = stage.recompose(comp, recon, x_in)
            recon = comp.recon
        return Compressed(recon=recon, residual=z - recon,
                          payload=tuple(c.payload for c in comps))

    def overhead_bits(self, n, d, phi_bits):
        total, elems = 0, n * d
        nn, dd = n, d
        for stage in self.stages:
            total += stage.overhead_bits(nn, dd, phi_bits)
            elems = stage.carrier_elems(nn, dd)
            if elems == 0:
                break
            nn, dd = elems, 1   # downstream stages see a flat carrier
        return total

    def carrier_elems(self, n, d):
        nn, dd = n, d
        for stage in self.stages:
            elems = stage.carrier_elems(nn, dd)
            if elems == 0:
                return 0
            nn, dd = elems, 1
        return nn * dd


# ---------------------------------------------------------------------------
# error feedback (memory owned by the caller — host loop or scan carry)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ErrorFeedback:
    """Error-feedback wrapper (Seide et al. 2014; Karimireddy et al. 2019):
    the compression error is remembered and re-added to the next input, so
    any contractive compressor transmits the full signal *eventually*.

        comp = c.compress(z + mem);   mem' = (z + mem) − comp.recon

    The memory is explicit state: callers thread it through rounds (it is a
    per-client tensor in a real deployment). ``init_memory`` gives the
    zero state."""
    compressor: CutCompressor

    def init_memory(self, z: jax.Array) -> jax.Array:
        return jnp.zeros_like(z)

    def step(self, z: jax.Array, memory: jax.Array, *,
             key: Optional[jax.Array] = None
             ) -> Tuple[Compressed, jax.Array]:
        corrected = z + memory
        comp = self.compressor.compress(corrected, key=key)
        return comp, corrected - comp.recon


# ---------------------------------------------------------------------------
# registry + spec parsing
# ---------------------------------------------------------------------------

_FACTORIES: Dict[str, Callable[..., CutCompressor]] = {}


def register_compressor(name: str,
                        factory: Callable[..., CutCompressor]) -> None:
    """Register (or replace) a named compressor factory."""
    _FACTORIES[name] = factory


register_compressor("none", lambda **kw: NoneCompressor(**kw))
register_compressor("pq", lambda **kw: PQCompressor(**kw))
register_compressor("topk", lambda **kw: TopKCompressor(**kw))
register_compressor("scalarq", lambda **kw: ScalarQuantCompressor(**kw))


def available_compressors() -> Tuple[str, ...]:
    return tuple(sorted(_FACTORIES)) + ("chain",)


_CALL_RE = re.compile(r"^(?P<name>[a-zA-Z_][\w]*)(?:\((?P<args>.*)\))?$")


def _parse_one(spec: str, pq: Optional[PQConfig]) -> CutCompressor:
    m = _CALL_RE.match(spec.strip())
    if not m:
        raise ValueError(f"malformed compressor spec {spec!r}")
    name, args = m.group("name"), m.group("args")
    if name not in _FACTORIES:
        raise ValueError(f"unknown compressor {name!r}; registered: "
                         f"{available_compressors()}")
    kwargs: Dict[str, Any] = {}
    for part in (args or "").split(","):
        part = part.strip()
        if not part:
            continue
        if "=" not in part:
            raise ValueError(f"compressor arg {part!r} must be key=value")
        k, v = part.split("=", 1)
        try:
            kwargs[k.strip()] = ast.literal_eval(v.strip())
        except (ValueError, SyntaxError):
            kwargs[k.strip()] = v.strip()   # bare strings, e.g. backend=jnp
    if name == "pq" and "cfg" not in kwargs:
        if pq is None:
            raise ValueError(
                "spec 'pq' needs a PQConfig: pass make_compressor(..., pq=...)")
        kwargs["cfg"] = pq
    return _FACTORIES[name](**kwargs)


def make_compressor(spec, *, pq: Optional[PQConfig] = None
                    ) -> Optional[CutCompressor]:
    """Build a compressor from a spec string (see module docstring).

    Accepts an already-built `CutCompressor` (returned as-is) and ``None``
    (returns None, meaning "direction not configured"). ``pq`` supplies the
    PQConfig a bare ``"pq"`` spec wraps."""
    if spec is None or isinstance(spec, CutCompressor):
        return spec
    spec = spec.strip()
    if spec.startswith("chain:"):
        stages = tuple(_parse_one(s, pq) for s in spec[len("chain:"):]
                       .split("+"))
        return ChainCompressor(stages=stages)
    return _parse_one(spec, pq)


# ---------------------------------------------------------------------------
# direction hooks (custom VJPs)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def compress_with_correction(z: jax.Array, lam,
                             compressor: CutCompressor) -> jax.Array:
    """Uplink hook: forward emits the compressed reconstruction, backward
    adds FedLite's λ·(z − z̃) correction (eq. 5) using the residual the
    forward compress already produced. Generalizes
    ``core/correction.quantize_with_correction`` to any registered codec."""
    return compressor.compress(z).recon


def _cwc_fwd(z, lam, compressor):
    comp = compressor.compress(z)
    return comp.recon, (comp.residual, jnp.asarray(lam, jnp.float32))


def _cwc_bwd(compressor, res, g):
    residual, lam = res
    return (g + lam.astype(g.dtype) * residual.astype(g.dtype),
            jnp.zeros_like(lam))


compress_with_correction.defvjp(_cwc_fwd, _cwc_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def compress_with_correction_stats(z: jax.Array, lam,
                                   compressor: CutCompressor):
    """Like ``compress_with_correction`` but also returns the mean ‖z − z̃‖²
    per vector as a second, non-differentiable output."""
    comp = compressor.compress(z)
    return comp.recon, _distortion(comp.residual)


def _distortion(residual: jax.Array) -> jax.Array:
    r = residual.astype(jnp.float32)
    n = max(int(residual.size // residual.shape[-1]), 1)
    return jnp.sum(r * r) / n


def _cwcs_fwd(z, lam, compressor):
    comp = compressor.compress(z)
    return ((comp.recon, _distortion(comp.residual)),
            (comp.residual, jnp.asarray(lam, jnp.float32)))


def _cwcs_bwd(compressor, res, g):
    gz, _ = g   # the distortion output is a metric: its cotangent is dropped
    residual, lam = res
    return (gz + lam.astype(gz.dtype) * residual.astype(gz.dtype),
            jnp.zeros_like(lam))


compress_with_correction_stats.defvjp(_cwcs_fwd, _cwcs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def compress_downlink(z: jax.Array, compressor: CutCompressor) -> jax.Array:
    """Downlink hook: identity forward; the backward pass sends the
    activation COTANGENT through ``compressor`` before it reaches the
    client submodel — the server→client gradient message becomes a
    compressed payload. With `NoneCompressor` the backward pass returns the
    cotangent unchanged, bitwise-reproducing the uncompressed path
    (asserted in tests/test_compressors.py)."""
    return z


def _dl_fwd(z, compressor):
    return z, None


def _dl_bwd(compressor, _, g):
    if isinstance(compressor, NoneCompressor):
        return (g,)
    return (compressor.compress(g).recon.astype(g.dtype),)


compress_downlink.defvjp(_dl_fwd, _dl_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def compress_downlink_keyed(z: jax.Array, key: jax.Array,
                            compressor: CutCompressor) -> jax.Array:
    """``compress_downlink`` with a per-step PRNG key threaded into the
    backward codec: ``scalarq`` (standalone or as a chain stage) then uses
    *stochastic* rounding on the gradient cotangent — unbiased,
    E[recon] = g (Caldas et al. 2018) — instead of round-to-nearest.

    ``key`` is a raw uint32 PRNG key (``jax.random.PRNGKey`` /
    ``fold_in``); its cotangent is the symbolic float0 zero. The keyless
    ``compress_downlink`` remains the deterministic path and is
    bitwise-unchanged."""
    return z


def _dlk_fwd(z, key, compressor):
    return z, key


def _dlk_bwd(compressor, key, g):
    if isinstance(compressor, NoneCompressor):
        gz = g
    else:
        gz = compressor.compress(g, key=key).recon.astype(g.dtype)
    # integer-dtype primals take float0 cotangents
    return (gz, np.zeros(key.shape, jax.dtypes.float0))


compress_downlink_keyed.defvjp(_dlk_fwd, _dlk_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def compress_downlink_stateful(z: jax.Array, state: Any,
                               compressor: CutCompressor) -> jax.Array:
    """``compress_downlink`` with cross-round codec state threaded IN.

    ``state`` (e.g. a `core/quantizer.QuantizerState` from the previous
    round, or ``None`` for a cold round) reaches the backward codec via
    ``compressor.compress_stateful``: a ``pq`` downlink then warm-starts
    Lloyd on the gradient cotangent from last round's gradient codebooks —
    ``cfg.effective_warm_iters`` iterations instead of a cold
    ``kmeans_iters`` recluster — exactly mirroring the uplink's
    ``compress_with_correction_carry`` warm start. It is also what the
    ``pq-delta`` wire kind diffs against, so the downlink codebook message
    shrinks to b-bit deltas versus the acked reference
    (``FederatedTrainer.codebook_delta_bits`` measures it;
    ``bench_comm.py`` asserts the reduction).

    The state is an auxiliary INPUT only — a VJP's backward pass cannot
    emit new primal state, so the refreshed reference lineage is owned by
    the measurement/trainer layer (the same split the uplink uses: warm
    math in-jit, acked wire references host-side). ``state`` receives a
    zero cotangent; ``None`` state runs the cold path, bitwise-identical
    to ``compress_downlink``.
    """
    return z


def _dls_fwd(z, state, compressor):
    return z, state


def _dls_bwd(compressor, state, g):
    if isinstance(compressor, NoneCompressor):
        gz = g
    else:
        comp, _ = compressor.compress_stateful(g, state)
        gz = comp.recon.astype(g.dtype)
    return (gz, _zero_state_cotangent(state))


compress_downlink_stateful.defvjp(_dls_fwd, _dls_bwd)


# ---------------------------------------------------------------------------
# the state-carrying uplink hook (warm-start + error feedback)
# ---------------------------------------------------------------------------

def _zero_state_cotangent(state):
    """Cotangent pytree for a `CutState` primal: zeros for float leaves,
    float0 for integer leaves (the round counter). The state is auxiliary
    carry — no gradient may flow into last round's codebooks or memory."""
    return jax.tree.map(
        lambda x: np.zeros(jnp.shape(x), jax.dtypes.float0)
        if jnp.issubdtype(jnp.asarray(x).dtype, jnp.integer)
        else jnp.zeros_like(x), state)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def compress_with_correction_carry(z: jax.Array, lam, state: CutState,
                                   compressor: CutCompressor):
    """State-carrying uplink hook: like ``compress_with_correction_stats``
    but threading a `CutState` across rounds. Returns
    ``(recon, distortion, new_state)``.

    Forward:
      1. error feedback (iff ``state.ef_memory`` is not None):
         ``z_in = z + memory`` — the accumulated compression error is
         re-added before compressing (`ErrorFeedback` semantics); the new
         memory is ``z_in − recon`` (== the compress residual), so the
         telescoped sum of transmissions recovers the full signal.
      2. warm-started compress: ``compressor.compress_stateful`` resumes
         from ``state.quantizer`` (PQ codebook warm-start; stateless
         codecs ignore it and return ``None``).

    Backward: FedLite's eq.-5 correction ``g + λ·(z_in − recon)`` on the
    activation cotangent, reusing the residual fused with the forward
    compress; ``lam`` and the state get zero cotangents (the state is
    auxiliary carry, not a differentiable input).
    """
    recon, dist, new_state, _ = _cwcarry(z, state, compressor)
    return recon, dist, new_state


def _cwcarry(z, state, compressor):
    z_in = z if state.ef_memory is None \
        else z + state.ef_memory.astype(z.dtype)
    comp, new_q = compressor.compress_stateful(z_in, state.quantizer)
    new_ef = None if state.ef_memory is None else comp.residual
    new_state = CutState(quantizer=new_q, ef_memory=new_ef)
    return comp.recon, _distortion(comp.residual), new_state, comp.residual


def _cwcarry_fwd(z, lam, state, compressor):
    recon, dist, new_state, residual = _cwcarry(z, state, compressor)
    return ((recon, dist, new_state),
            (residual, jnp.asarray(lam, jnp.float32), state))


def _cwcarry_bwd(compressor, res, g):
    gz = g[0]   # distortion and state outputs are carry/metrics: dropped
    residual, lam, state = res
    return (gz + lam.astype(gz.dtype) * residual.astype(gz.dtype),
            jnp.zeros_like(lam), _zero_state_cotangent(state))


compress_with_correction_carry.defvjp(_cwcarry_fwd, _cwcarry_bwd)
