"""FedLite's grouped product quantizer (paper §4.1).

Given a batch of activation vectors Z ∈ R^{N×d}:

  (i)   divide each vector into ``q`` subvectors of size d/q;
  (ii)  stack subvectors into ``R`` groups by subvector index — group ``r``
        holds subvector positions [r·q/R, (r+1)·q/R) of every example, so all
        positions in a group share one codebook;
  (iii) K-means with ``L`` centroids per group; each subvector is represented
        by the index of its nearest centroid.

Uplink message = codebooks (φ·(d/q)·L·R bits) + codes (N·q·⌈log2 L⌉ bits),
vs. φ·d·N uncompressed — the paper's φdRL/q + Bq·log2 L with N playing B.

Special cases recovered exactly:
  * q = 1             → vanilla K-means on whole vectors
  * R = q  (q > 1)    → vanilla product quantization (codebook per position)
  * R = 1  (default)  → the paper's best trade-off: one shared codebook

Where this sits in the compressor stack
---------------------------------------
This module is the PQ *math*; it is one codec among several. The
direction-agnostic registry in ``core/compressors.py`` wraps it as the
``"pq"`` `CutCompressor` (the uplink default — what runs at the cut in
`TransformerLM.cut_activation` and the paper models), next to ``none``,
``topk``, ``scalarq`` and ``chain`` (the downlink gradient codecs). Analytic
bits come from ``PQConfig.message_bits`` here (the paper's §4.1 cost model,
φdRL/q + Bq·log2 L); *measured* bits come from the tagged wire codec in
``federated/wire.py``, which serializes the `QuantizedBatch` produced here
as a ``pq`` payload (fp16 codebooks + ceil(log2 L)-bit packed codes) and
must agree with the analytic count to within the 24 B header.

Cross-round codebook warm-start
-------------------------------
FedLite's stateless-client story rebuilds codebooks from scratch every
round; in the simulation (and in any deployment where a client persists a
few KB between rounds) the previous round's codebook is an excellent
initializer, because activation distributions drift slowly. `QuantizerState`
carries the per-group fp32 codebooks plus a round counter across rounds:

  * cold round (``state is None`` / ``quantize``'s default): FPS/kmeans++
    seeding + ``kmeans_iters`` Lloyd iterations — the paper's behavior.
  * warm round (``quantize_stateful`` with a prior state): Lloyd resumes
    from ``state.codebooks`` and runs only ``PQConfig.warm_iters``
    iterations (default ``kmeans_iters // 2``), roughly halving the
    steady-state per-step K-means cost.

The state is threaded by the callers that own round boundaries —
``core/compressors.PQCompressor.compress_stateful`` inside the train step
and ``federated/runtime.FederatedTrainer`` across scheduler rounds — and it
is also what the ``pq-delta`` wire kind (``federated/wire.py``) diffs
against to shrink the codebook component of the uplink message.

Selecting a quantizer backend
-----------------------------
``PQConfig.backend`` picks the compute backend for the Lloyd iterations
(assign + the fused deviation-accumulate update, ``repro.kernels.
lloyd_update``) and the final encode (assignment + dequantize + residual):

  * ``"auto"`` (default) — the fused Pallas kernel (compiled Mosaic) on TPU,
    pure-jnp elsewhere. This is what production configs should use.
  * ``"jnp"``  — pure-jnp everywhere; the reference/CPU path.
  * ``"pallas"`` — force the Pallas kernels; off-TPU they run in interpret
    mode, which is for parity validation, not speed.

The final encode is *fused*: one pass produces the dequantized activations
z̃, the residual z − z̃ (consumed by the gradient-corrected VJP in
``core/correction.py`` and ``core/compressors.compress_with_correction`` —
it is NOT recomputed there), and the integer codes. On TPU this is one HBM
read + two writes per element instead of the three sweeps (assign, gather,
subtract) of the naive path. Backends live in a registry
(``repro.core.kmeans.register_backend``) so new substrates can be added
without touching this module; the scalarq compressor's quantize/pack
kernels (``repro.kernels.scalar_quant``) ride the same registry resolution.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as _km


def bits_per_code(num_clusters: int) -> int:
    """Packed index width b = ceil(log2 L); a single cluster needs no codes.

    The one formula both the analytic accounting (`PQConfig`) and the wire
    codec (`federated/wire.py`) use."""
    return 0 if num_clusters <= 1 else \
        max(math.ceil(math.log2(num_clusters)), 1)


@dataclasses.dataclass(frozen=True)
class PQConfig:
    """Static quantizer hyperparameters (hashable: usable as a jit static)."""
    num_subvectors: int          # q — subvectors per activation vector
    num_clusters: int            # L — centroids per group
    num_groups: int = 1          # R — codebook groups (R=1 is the paper default)
    kmeans_iters: int = 8
    phi_bits: int = 64           # float width used for *accounting* (paper: 64)
    kmeans_chunk: int = 4096
    backend: str = "auto"        # "jnp" | "pallas" | "auto" (see module doc)
    warm_iters: Optional[int] = None  # Lloyd iters on warm rounds
    #                                   (None = kmeans_iters // 2)

    def __post_init__(self):
        if self.num_subvectors % self.num_groups != 0:
            raise ValueError(
                f"q={self.num_subvectors} must be divisible by R={self.num_groups}")
        if self.num_clusters < 1:
            raise ValueError("L must be >= 1")
        if self.backend not in _km.available_backends():
            raise ValueError(
                f"backend={self.backend!r} not one of {_km.available_backends()}")
        if self.warm_iters is not None and self.warm_iters < 0:
            raise ValueError(f"warm_iters={self.warm_iters} must be >= 0")

    @property
    def q(self) -> int:
        return self.num_subvectors

    @property
    def r(self) -> int:
        return self.num_groups

    @property
    def l(self) -> int:
        return self.num_clusters

    @property
    def effective_warm_iters(self) -> int:
        """Lloyd iterations on a warm-started round (see module docstring)."""
        return self.kmeans_iters // 2 if self.warm_iters is None \
            else self.warm_iters

    def subvector_dim(self, d: int) -> int:
        if d % self.num_subvectors != 0:
            raise ValueError(f"d={d} not divisible by q={self.num_subvectors}")
        return d // self.num_subvectors

    # ---- wire-layout metadata (consumed by federated/wire.py) ----------
    @property
    def bits_per_code(self) -> int:
        """Packed index width b = ceil(log2 L); L=1 transmits no codes."""
        return bits_per_code(self.num_clusters)

    def codebook_shape(self, d: int) -> tuple:
        """(R, L, d/q) — the centroid tensor the uplink carries."""
        return (self.num_groups, self.num_clusters, self.subvector_dim(d))

    def num_codes(self, n: int) -> int:
        """Total cluster indices for n activation vectors (= R·(q/R)·n)."""
        return n * self.num_subvectors

    # ---- communication accounting (paper §4.1) -------------------------
    def codebook_bits(self, d: int, phi_bits: Optional[int] = None) -> int:
        # R groups × L centroids × (d/q) dims × φ bits  ==  φ·d·R·L/q
        phi = self.phi_bits if phi_bits is None else phi_bits
        return phi * self.subvector_dim(d) * self.num_clusters * self.num_groups

    def codes_bits(self, n: int) -> int:
        return self.num_codes(n) * self.bits_per_code

    def message_bits(self, n: int, d: int, phi_bits: Optional[int] = None) -> int:
        return self.codebook_bits(d, phi_bits) + self.codes_bits(n)

    def uncompressed_bits(self, n: int, d: int,
                          phi_bits: Optional[int] = None) -> int:
        phi = self.phi_bits if phi_bits is None else phi_bits
        return phi * d * n

    def compression_ratio(self, n: int, d: int,
                          phi_bits: Optional[int] = None) -> float:
        return self.uncompressed_bits(n, d, phi_bits) / \
            max(self.message_bits(n, d, phi_bits), 1)


class QuantizedBatch(NamedTuple):
    dequantized: jax.Array   # (N, d) — z̃, same dtype as input
    codes: jax.Array         # (R, q/R·N) int32 cluster assignments
    codebooks: jax.Array     # (R, L, d/q)
    distortion: jax.Array    # () mean ‖z − z̃‖² per vector
    residual: jax.Array      # z − z̃, input shape + dtype (fused with encode;
                             # distortion is accumulated in fp32 before the cast)


class QuantizerState(NamedTuple):
    """Cross-round quantizer carry: the per-group codebooks of the last
    round (kept in fp32 — the Lloyd compute dtype) and a round counter.

    An all-array NamedTuple: jit/vmap-transparent, so trainers thread it
    through jitted steps and stack it per client. ``rounds`` counts how many
    quantizes contributed to ``codebooks`` (0-based warm lineage length)."""
    codebooks: jax.Array     # (R, L, d/q) fp32
    rounds: jax.Array        # () int32


def init_quantizer_state(qb: QuantizedBatch) -> QuantizerState:
    """Bootstrap a warm-start state from a cold round's output."""
    return QuantizerState(codebooks=qb.codebooks.astype(jnp.float32),
                          rounds=jnp.ones((), jnp.int32))


def _to_groups(z: jax.Array, cfg: PQConfig) -> jax.Array:
    """(N, d) -> (R, (q/R)·N, d/q) grouping consecutive subvector positions."""
    n, d = z.shape
    dsub = cfg.subvector_dim(d)
    # (N, q, dsub) -> (q, N, dsub): group r = positions [r·q/R, (r+1)·q/R)
    sub = z.reshape(n, cfg.q, dsub).transpose(1, 0, 2)
    return sub.reshape(cfg.r, (cfg.q // cfg.r) * n, dsub)


def _from_groups(groups: jax.Array, n: int, d: int, cfg: PQConfig) -> jax.Array:
    dsub = cfg.subvector_dim(d)
    sub = groups.reshape(cfg.q, n, dsub).transpose(1, 0, 2)
    return sub.reshape(n, d)


def quantize(z: jax.Array, cfg: PQConfig,
             key: Optional[jax.Array] = None, *,
             state: Optional[QuantizerState] = None) -> QuantizedBatch:
    """Quantize a batch of activation vectors with the grouped PQ scheme.

    ``z`` may have any leading shape; it is flattened to (N, d) where d is the
    trailing dim. The returned ``dequantized`` has the original shape.

    K-means (Lloyd) runs exactly once; the final dequantize + residual step is
    the backend's fused encode (``repro.kernels.pq_quantize`` under the
    Pallas backend), so callers that need the residual — the gradient
    correction — get it for free instead of re-deriving it from z̃.

    ``state`` (a previous round's `QuantizerState`) switches Lloyd to the
    warm-start path: seeding is skipped and only ``cfg.effective_warm_iters``
    iterations run from ``state.codebooks``. Callers that carry state across
    rounds should use ``quantize_stateful``, which also returns the updated
    state.
    """
    orig_shape = z.shape
    d = orig_shape[-1]
    z2 = z.reshape(-1, d)
    n = z2.shape[0]

    groups = _to_groups(z2.astype(jnp.float32), cfg)  # (R, M, dsub)
    if state is None:
        cents = _km.batched_lloyd(
            groups, cfg.num_clusters, cfg.kmeans_iters, key=key,
            chunk=cfg.kmeans_chunk, backend=cfg.backend)
    else:
        cents = _km.batched_lloyd(
            groups, cfg.num_clusters, cfg.effective_warm_iters, key=None,
            chunk=cfg.kmeans_chunk, backend=cfg.backend,
            init_centroids=state.codebooks.astype(jnp.float32))
    # fused final pass per group: z̃ + residual + codes in one sweep
    enc = _km.get_backend(cfg.backend).encode
    recon, resid, codes = jax.vmap(
        lambda xg, cg: enc(xg, cg, cfg.kmeans_chunk))(groups, cents)
    z_tilde = _from_groups(recon, n, d, cfg).astype(z.dtype)
    # keep the stored residual in z.dtype: it is saved by the correction VJP
    # for the backward pass, and an fp32 copy would double that residency
    # for bf16 activations (distortion still accumulates in fp32 first)
    residual = _from_groups(resid, n, d, cfg).astype(z.dtype)
    per_vec = jnp.sum(resid * resid) / jnp.maximum(n, 1)
    return QuantizedBatch(z_tilde.reshape(orig_shape), codes,
                          cents.astype(z.dtype), per_vec,
                          residual.reshape(orig_shape))


def quantize_stateful(z: jax.Array, cfg: PQConfig,
                      state: Optional[QuantizerState] = None,
                      key: Optional[jax.Array] = None
                      ) -> Tuple[QuantizedBatch, QuantizerState]:
    """Warm-start-aware quantize: returns (batch, next round's state).

    ``state=None`` runs the cold path (full seeding + ``kmeans_iters``) and
    bootstraps the state; a prior state runs ``effective_warm_iters`` Lloyd
    iterations from its codebooks. The returned state's codebooks are the
    fp32 Lloyd output (the wire's acked copy is the fp16/delta-reconstructed
    view — see ``federated/wire.encode_pq_delta``)."""
    qb = quantize(z, cfg, key, state=state)
    rounds = jnp.zeros((), jnp.int32) if state is None else state.rounds
    new_state = QuantizerState(codebooks=qb.codebooks.astype(jnp.float32),
                               rounds=rounds + 1)
    return qb, new_state


def quantization_error(z: jax.Array, cfg: PQConfig) -> jax.Array:
    """Mean relative quantization error ‖z−z̃‖/‖z‖ over the batch (for Fig. 3)."""
    resid = quantize(z, cfg).residual
    z2 = z.reshape(-1, z.shape[-1]).astype(jnp.float32)
    r2 = resid.reshape(z2.shape).astype(jnp.float32)
    num = jnp.linalg.norm(r2, axis=-1)
    den = jnp.maximum(jnp.linalg.norm(z2, axis=-1), 1e-12)
    return jnp.mean(num / den)


def vanilla_kmeans_config(num_clusters: int, **kw) -> PQConfig:
    """q=1: quantize whole vectors (paper's 'K-means' baseline)."""
    return PQConfig(num_subvectors=1, num_clusters=num_clusters, num_groups=1, **kw)


def vanilla_pq_config(num_subvectors: int, num_clusters: int, **kw) -> PQConfig:
    """R=q: per-position codebooks (paper's 'vanilla PQ' baseline)."""
    return PQConfig(num_subvectors=num_subvectors, num_clusters=num_clusters,
                    num_groups=num_subvectors, **kw)
