"""Model-splitting helpers: parameter accounting for the client/server split.

Models built by ``TransformerLM`` are split by construction
(params = {"client": ..., "server": ...}); these helpers quantify the split —
the paper's Table 1 compares algorithms by |w|, |w_c| and message sizes.
"""

from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp


def tree_size(tree) -> int:
    """Total number of parameters in a pytree."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bits(tree, phi_bits: int = 64) -> int:
    """Parameter payload in bits at the paper's accounting float width φ."""
    return tree_size(tree) * phi_bits


def split_summary(params: Dict[str, Any], phi_bits: int = 64) -> Dict[str, Any]:
    n_client = tree_size(params["client"])
    n_server = tree_size(params["server"])
    total = n_client + n_server
    return {
        "client_params": n_client,
        "server_params": n_server,
        "total_params": total,
        "client_fraction": n_client / max(total, 1),
        "client_bits": n_client * phi_bits,
        "server_bits": n_server * phi_bits,
    }
