"""Model-splitting helpers: parameter accounting for the client/server split.

Models built by ``TransformerLM`` are split by construction
(params = {"client": ..., "server": ...}); these helpers quantify the split —
the paper's Table 1 compares algorithms by |w|, |w_c| and message sizes.

Accounting width φ: by default (``phi_bits=None``) bit counts are derived
from each leaf's *actual dtype* (fp32 params count 32 bits, bf16 count 16).
Pass an explicit ``phi_bits`` to reproduce a fixed-width cost model — the
paper's §5 worked example uses φ=64.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def dtype_bits(dtype) -> int:
    """Bits per element of a dtype (bf16 -> 16, fp32 -> 32, ...)."""
    return jnp.dtype(dtype).itemsize * 8


def tree_size(tree) -> int:
    """Total number of parameters in a pytree."""
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bits(tree, phi_bits: Optional[int] = None) -> int:
    """Parameter payload in bits.

    ``phi_bits=None`` (default) counts each leaf at its actual dtype width;
    an explicit value applies one accounting float width φ to every leaf.
    """
    if phi_bits is None:
        return sum(x.size * dtype_bits(x.dtype) for x in jax.tree.leaves(tree))
    return tree_size(tree) * phi_bits


def split_summary(params: Dict[str, Any],
                  phi_bits: Optional[int] = None) -> Dict[str, Any]:
    n_client = tree_size(params["client"])
    n_server = tree_size(params["server"])
    total = n_client + n_server
    return {
        "client_params": n_client,
        "server_params": n_server,
        "total_params": total,
        "client_fraction": n_client / max(total, 1),
        "client_bits": tree_bits(params["client"], phi_bits),
        "server_bits": tree_bits(params["server"], phi_bits),
    }
