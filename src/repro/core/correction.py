"""FedLite's gradient-corrected quantization layer (paper §4.2, eq. 5).

The quantizer is non-differentiable; the server returns ∂h/∂z̃ — the gradient
at the *quantized* activation. FedLite approximates the true ∂h/∂z with a
first-order correction, replacing the (expensive) Hessian with λ·I:

    g̃_z  =  ∂h/∂z̃  +  λ·(z − z̃)                                   (eq. 5)

which, per Appendix A, is exactly the gradient of the surrogate loss
‖z − ẑ‖² + (λ/2)‖z − z̃‖² — i.e. λ adds a regularizer pulling the client-side
model toward activations with low quantization error.

Implemented as a ``jax.custom_vjp``: the forward pass runs the grouped PQ and
emits z̃; the backward pass adds λ·(z − z̃) to the incoming cotangent. λ = 0
recovers the naive straight-through estimator the paper ablates against.

This module is the PQ-specialized fast path; the direction-agnostic
generalization (same VJP structure over any registered codec, the downlink
hooks, and the state-carrying variant that threads codebook warm-start +
error-feedback memory across rounds) lives in ``core/compressors.py``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.quantizer import PQConfig, quantize


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def quantize_with_correction(z: jax.Array, lam, cfg: PQConfig) -> jax.Array:
    """Quantize ``z`` (any shape, trailing dim = d); STE + λ-correction VJP.

    ``lam`` may be a Python float or a traced scalar — scheduled λ (e.g. the
    beyond-paper warm-up, see core/fedlite.py) works without recompilation.

    K-means runs exactly once per forward+backward: the forward emits the
    residual fused with the encode (``QuantizedBatch.residual``) and the VJP
    reuses it — no re-quantize, no extra z − z̃ sweep.
    """
    return quantize(z, cfg).dequantized


def _fwd(z, lam, cfg):
    qb = quantize(z, cfg)
    # the fused encode already produced the residual the backward pass needs
    return qb.dequantized, (qb.residual, jnp.asarray(lam, jnp.float32))


def _bwd(cfg, res, g):
    residual, lam = res
    # eq. (5): corrected activation cotangent; λ itself gets no gradient
    return (g + lam.astype(g.dtype) * residual.astype(g.dtype),
            jnp.zeros_like(lam))


quantize_with_correction.defvjp(_fwd, _bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def quantize_with_correction_stats(z: jax.Array, lam, cfg: PQConfig):
    """Like ``quantize_with_correction`` but also returns the quantizer's
    distortion (mean ‖z − z̃‖² per vector) as a second, non-differentiable
    output — so metric consumers reuse the fused encode's residual instead
    of re-deriving z − z̃ with another sweep over the activations."""
    qb = quantize(z, cfg)
    return qb.dequantized, qb.distortion


def _sfwd(z, lam, cfg):
    qb = quantize(z, cfg)
    return ((qb.dequantized, qb.distortion),
            (qb.residual, jnp.asarray(lam, jnp.float32)))


def _sbwd(cfg, res, g):
    gz, _ = g  # the distortion output is a metric: its cotangent is dropped
    residual, lam = res
    return (gz + lam.astype(gz.dtype) * residual.astype(gz.dtype),
            jnp.zeros_like(lam))


quantize_with_correction_stats.defvjp(_sfwd, _sbwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def quantize_downlink(z: jax.Array, cfg: PQConfig) -> jax.Array:
    """Beyond-paper: compress the *downlink* (server -> client gradient)
    with the grouped PQ.

    Kept for backward compatibility; the general mechanism is
    ``core/compressors.compress_downlink``, which accepts ANY registered
    `CutCompressor` (topk, scalarq, chains, ...) — this function is the
    ``compressor=PQCompressor(cfg)`` special case. Identity in the forward
    pass; the backward pass applies the codec to the activation COTANGENT,
    so the client receives a compressed payload instead of raw gradients.
    Same per-client (vmap-outside) usage as quantize_with_correction.
    """
    return z


def _dl_fwd(z, cfg):
    return z, None


def _dl_bwd(cfg, _, g):
    return (quantize(g, cfg).dequantized.astype(g.dtype),)


quantize_downlink.defvjp(_dl_fwd, _dl_bwd)


def quantize_with_stats(z: jax.Array, lam: float, cfg: PQConfig,
                        key: Optional[jax.Array] = None):
    """Like quantize_with_correction but also returns (non-differentiable)
    quantization stats for logging: distortion and message bits."""
    del key  # codebook init is deterministic inside the step
    z_tilde, distortion = quantize_with_correction_stats(z, lam, cfg)
    n = int(z.size // z.shape[-1])
    stats = {
        "pq_distortion": distortion,
        "pq_message_bits": cfg.message_bits(n, z.shape[-1]),
        "pq_compression_ratio": cfg.compression_ratio(n, z.shape[-1]),
    }
    return z_tilde, stats
