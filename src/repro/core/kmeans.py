"""Batched K-means in pure JAX, usable *inside* a jitted train step.

FedLite rebuilds codebooks from the current mini-batch at every iteration
(stateless clients, non-IID data), so K-means must be a fixed-shape,
fixed-iteration-count program: ``lax.fori_loop`` over Lloyd iterations,
``lax.scan`` over chunks of points so the one-hot statistics never
materialize an (N, L) tensor for the full batch at once.

Distance computation is expressed as ``‖x‖² − 2·x·Cᵀ + ‖c‖²`` so the inner
product rides the MXU on TPU; the Pallas kernel in
``repro.kernels.kmeans_assign`` implements the same contraction with explicit
VMEM tiling and can be swapped in via ``set_assign_impl``.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (L, D)
    codes: jax.Array      # (N,) int32
    distortion: jax.Array  # () mean squared quantization error per point


def _assign_jnp(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """codes[i] = argmin_l ‖x_i − c_l‖².  x: (n, D), centroids: (L, D)."""
    # ‖x‖² is constant across l — only the cross term and ‖c‖² matter.
    scores = 2.0 * (x @ centroids.T) - jnp.sum(centroids * centroids, axis=-1)
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


# Swappable assignment implementation (pure-jnp default; Pallas kernel opt-in).
_ASSIGN: Callable[[jax.Array, jax.Array], jax.Array] = _assign_jnp


def set_assign_impl(fn: Optional[Callable]) -> None:
    global _ASSIGN
    _ASSIGN = fn if fn is not None else _assign_jnp


def get_assign_impl() -> Callable:
    return _ASSIGN


def _init_centroids(x: jax.Array, num_clusters: int,
                    key: Optional[jax.Array]) -> jax.Array:
    """Farthest-point / k-means++ seeding on a strided subsample.

    Plain strided or uniform-random seeding regularly drops a true cluster and
    Lloyd cannot recover (empty-cluster local minimum). FPS guarantees spread
    seeds at O(L·M·D) cost on an M = O(L) subsample — negligible next to one
    Lloyd iteration over the full batch. With a PRNG key the selection becomes
    kmeans++ (D² sampling); without, it is deterministic farthest-point.
    """
    n, d = x.shape
    L = num_clusters
    m = min(n, max(4 * L, 256))
    xs = x[:: max(n // m, 1)][:m]
    m = xs.shape[0]

    cents0 = jnp.zeros((L, d), x.dtype).at[0].set(xs[0])
    mind0 = jnp.sum(jnp.square(xs - xs[0]), axis=-1)

    if key is None:
        def body(l, state):
            cents, mind = state
            idx = jnp.argmax(mind)
            c = xs[idx]
            cents = cents.at[l].set(c)
            mind = jnp.minimum(mind, jnp.sum(jnp.square(xs - c), axis=-1))
            return cents, mind
        cents, _ = jax.lax.fori_loop(1, L, body, (cents0, mind0))
    else:
        keys = jax.random.split(key, L)

        def body(l, state):
            cents, mind = state
            logits = jnp.log(jnp.maximum(mind, 1e-30))
            idx = jax.random.categorical(keys[l], logits)
            c = xs[idx]
            cents = cents.at[l].set(c)
            mind = jnp.minimum(mind, jnp.sum(jnp.square(xs - c), axis=-1))
            return cents, mind
        cents, _ = jax.lax.fori_loop(1, L, body, (cents0, mind0))
    return cents


def kmeans(x: jax.Array, num_clusters: int, num_iters: int = 8, *,
           key: Optional[jax.Array] = None, chunk: int = 4096) -> KMeansResult:
    """Lloyd's algorithm with a fixed iteration count.

    Args:
      x: (N, D) points. Computation runs in fp32 regardless of input dtype.
      num_clusters: L.
      num_iters: Lloyd iterations (static).
      key: optional PRNG key for random init; None = deterministic strided.
      chunk: points per scan step for the assign/accumulate pass.
    Returns:
      KMeansResult(centroids (L, D) in x.dtype, codes (N,) int32, distortion).
    """
    in_dtype = x.dtype
    x = x.astype(jnp.float32)
    n, d = x.shape
    L = num_clusters

    # pad N up to a multiple of chunk; padded rows carry zero weight
    chunk = min(chunk, max(n, 1))
    n_pad = (-n) % chunk
    if n_pad:
        xp = jnp.concatenate([x, jnp.zeros((n_pad, d), jnp.float32)], axis=0)
    else:
        xp = x
    weights = jnp.concatenate(
        [jnp.ones((n,), jnp.float32), jnp.zeros((n_pad,), jnp.float32)])
    n_chunks = xp.shape[0] // chunk
    xc = xp.reshape(n_chunks, chunk, d)
    wc = weights.reshape(n_chunks, chunk)

    cents0 = _init_centroids(x, L, key)

    def lloyd_iter(_, cents):
        def acc(carry, inp):
            sums, counts = carry
            xb, wb = inp
            codes = _ASSIGN(xb, cents)
            onehot = jax.nn.one_hot(codes, L, dtype=jnp.float32) * wb[:, None]
            return (sums + onehot.T @ xb, counts + onehot.sum(axis=0)), None

        (sums, counts), _ = jax.lax.scan(
            acc, (jnp.zeros((L, d), jnp.float32), jnp.zeros((L,), jnp.float32)),
            (xc, wc))
        # empty clusters keep their previous centroid
        return jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1.0), cents)

    cents = jax.lax.fori_loop(0, num_iters, lloyd_iter, cents0)

    def assign_chunk(carry, inp):
        xb, wb = inp
        codes = _ASSIGN(xb, cents)
        err = jnp.sum(jnp.square(xb - cents[codes]), axis=-1) * wb
        return carry + err.sum(), codes

    sq_err, codes = jax.lax.scan(assign_chunk, jnp.zeros((), jnp.float32), (xc, wc))
    codes = codes.reshape(-1)[:n]
    distortion = sq_err / jnp.maximum(n, 1)
    return KMeansResult(cents.astype(in_dtype), codes, distortion)


@functools.partial(jax.jit, static_argnums=(1, 2))
def kmeans_jit(x, num_clusters, num_iters):
    return kmeans(x, num_clusters, num_iters)


def batched_kmeans(x: jax.Array, num_clusters: int, num_iters: int = 8, *,
                   key: Optional[jax.Array] = None, chunk: int = 4096):
    """vmapped kmeans over a leading group axis.  x: (G, N, D)."""
    keys = None if key is None else jax.random.split(key, x.shape[0])
    fn = functools.partial(kmeans, num_clusters=num_clusters,
                           num_iters=num_iters, chunk=chunk)
    if keys is None:
        return jax.vmap(lambda g: fn(g))(x)
    return jax.vmap(lambda g, k: fn(g, key=k))(x, keys)
