"""Batched K-means in pure JAX, usable *inside* a jitted train step.

FedLite rebuilds codebooks from the current mini-batch at every iteration
(stateless clients, non-IID data), so K-means must be a fixed-shape,
fixed-iteration-count program: ``lax.fori_loop`` over Lloyd iterations,
``lax.scan`` over chunks of points so the one-hot statistics never
materialize an (N, L) tensor for the full batch at once.

Distance computation is expressed as ``‖x‖² − 2·x·Cᵀ + ‖c‖²`` so the inner
product rides the MXU on TPU.

Backend registry
----------------
The assignment / encode primitives are pluggable via a named registry:

  * ``"jnp"``    — pure-jnp ops (XLA fusion; the CPU/testing substrate).
  * ``"pallas"`` — the Pallas kernels in ``repro.kernels``: compiled Mosaic
                   on TPU, interpret mode elsewhere (parity validation).
  * ``"auto"``   — ``"pallas"`` when running on a TPU backend, ``"jnp"``
                   otherwise (interpret-mode Pallas is for correctness, not
                   speed, so it is never auto-selected off-TPU).

A backend bundles the quantizer's compute primitives:

  * ``assign(x, cents) -> codes`` — nearest-centroid assignment, used inside
    the Lloyd iterations (``x`` is a (chunk, D) tile).
  * ``encode(x, cents, chunk) -> (z̃, residual, codes)`` — the fused final
    pass: assignment + centroid gather + residual in one sweep. The Pallas
    implementation (``repro.kernels.pq_quantize``) does one HBM read and two
    writes per element instead of the three separate sweeps the naive path
    takes.
  * ``update(x, weights, cents, chunk) -> (dsums, counts)`` — one Lloyd
    iteration's statistics: assign + deviation-accumulate fused in a single
    HBM sweep (``repro.kernels.lloyd_update`` under the Pallas backend).
    ``None`` (the jnp default, and any backend registered without one) falls
    back to a ``lax.scan`` over chunks built on ``assign``, which
    materializes a (chunk, L) one-hot and re-reads the centroids per step —
    the structure the fused kernel eliminates.

Warm-start: ``lloyd``/``kmeans`` accept ``init_centroids`` to resume from a
previous round's codebook instead of re-seeding — the cross-round codebook
reuse ``core/quantizer.QuantizerState`` builds on (steady-state rounds run
``PQConfig.warm_iters`` ≈ half the cold-start Lloyd iterations).

Numerics: the Lloyd centroid update accumulates *deviations from the current
centroid* (``Σ onehot·(x − c_old)``, then ``c_new = c_old + Σ/count``) rather
than raw coordinate sums. This is algebraically the same mean but loses far
less precision in fp32 — in particular, a cluster whose members all equal its
centroid gets an exactly-zero update, so exact-reconstruction inputs yield an
exactly-zero quantization residual (required by the FedLite → SplitFed
gradient-equivalence property, tests/test_fedlite.py). Empty clusters keep
their previous centroid exactly (``counts == 0`` gates the update). Both
properties hold on every backend: the fused update kernel preserves the
deviation accumulation bit-structure (tests/test_lloyd_update.py).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import obs


class KMeansResult(NamedTuple):
    centroids: jax.Array  # (L, D)
    codes: jax.Array      # (N,) int32
    distortion: jax.Array  # () mean squared quantization error per point


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------

class Backend(NamedTuple):
    """A quantizer compute backend (see module docstring)."""
    name: str
    assign: Callable[[jax.Array, jax.Array], jax.Array]
    encode: Callable[[jax.Array, jax.Array, int],
                     Tuple[jax.Array, jax.Array, jax.Array]]
    # (x, cents, chunk) -> (codes, sqdist); None = derive from encode
    assign_dist: Optional[Callable] = None
    # (x, weights, cents, chunk) -> (dsums, counts); None = scan over assign
    update: Optional[Callable] = None


def _pad_chunks(x: jax.Array, chunk: int):
    """Zero-pad rows to a multiple of ``chunk`` and split into scan tiles.

    Returns ((n_chunks, chunk, D) tiles, real row count n, pad count)."""
    n, d = x.shape
    chunk = min(chunk, max(n, 1))
    pad = (-n) % chunk
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)])
    return x.reshape(-1, chunk, d), n, pad


def _assign_jnp(x: jax.Array, centroids: jax.Array) -> jax.Array:
    """codes[i] = argmin_l ‖x_i − c_l‖².  x: (n, D), centroids: (L, D)."""
    # ‖x‖² is constant across l — only the cross term and ‖c‖² matter.
    scores = (2.0 * (x @ centroids.T)
              - jnp.sum(centroids * centroids, axis=-1)[None, :])
    return jnp.argmax(scores, axis=-1).astype(jnp.int32)


def _encode_jnp(x: jax.Array, centroids: jax.Array, chunk: int):
    """Assignment + gather + residual, chunked so scores stay (chunk, L)."""
    d = x.shape[1]
    xc, n, _ = _pad_chunks(x, chunk)

    def body(_, xb):
        codes = _assign_jnp(xb, centroids)
        zt = centroids[codes]
        return None, (zt, xb - zt, codes)

    _, (zt, resid, codes) = jax.lax.scan(body, None, xc)
    return (zt.reshape(-1, d)[:n], resid.reshape(-1, d)[:n],
            codes.reshape(-1)[:n])


def _assign_dist_jnp(x: jax.Array, centroids: jax.Array, chunk: int):
    """codes + per-point squared distances, without materializing z̃."""
    xc, n, _ = _pad_chunks(x, chunk)

    def body(_, xb):
        codes = _assign_jnp(xb, centroids)
        err = jnp.sum(jnp.square(xb - centroids[codes]), axis=-1)
        return None, (codes, err)

    _, (codes, err) = jax.lax.scan(body, None, xc)
    return codes.reshape(-1)[:n], err.reshape(-1)[:n]


def _assign_pallas(x: jax.Array, centroids: jax.Array) -> jax.Array:
    from repro.kernels import ops  # deferred: kernels must stay optional here
    codes, _ = ops.kmeans_assign(x, centroids)
    return codes


def _encode_pallas(x: jax.Array, centroids: jax.Array, chunk: int):
    from repro.kernels import ops
    block_n = min(512, max(chunk, 8))
    zt, resid, codes = ops.pq_quantize(x, centroids, block_n=block_n)
    return zt.astype(jnp.float32), resid, codes


def _assign_dist_pallas(x: jax.Array, centroids: jax.Array, chunk: int):
    # the assign kernel already emits distances — no z̃ HBM write
    from repro.kernels import ops
    return ops.kmeans_assign(x, centroids, block_n=min(512, max(chunk, 8)))


def _update_scan(assign, x, weights, centroids, chunk):
    """Fallback Lloyd-update: scan over chunks on top of ``assign``.

    This is the pre-kernel structure: per scan step XLA materializes a
    (chunk, L) one-hot and re-reads the centroids for the deviation gather.
    Bitwise-identical to the historical in-``lloyd`` accumulation."""
    L, d = centroids.shape
    xc = x.reshape(-1, min(chunk, max(x.shape[0], 1)), d)  # x pre-padded
    wc = weights.reshape(xc.shape[0], -1)

    def acc(carry, inp):
        dsums, counts = carry
        xb, wb = inp
        codes = assign(xb, centroids)
        onehot = jax.nn.one_hot(codes, L, dtype=jnp.float32) * wb[:, None]
        # deviation accumulation: exact-cover clusters contribute 0
        delta = xb - centroids[codes]
        return (dsums + onehot.T @ delta,
                counts + onehot.sum(axis=0)), None

    (dsums, counts), _ = jax.lax.scan(
        acc, (jnp.zeros((L, d), jnp.float32), jnp.zeros((L,), jnp.float32)),
        (xc, wc))
    return dsums, counts


def _update_pallas(x: jax.Array, weights: jax.Array, centroids: jax.Array,
                   chunk: int):
    from repro.kernels import ops
    return ops.lloyd_update(x, centroids, weights,
                            block_n=min(512, max(chunk, 8)))


_REGISTRY: Dict[str, Backend] = {
    "jnp": Backend("jnp", _assign_jnp, _encode_jnp, _assign_dist_jnp),
    "pallas": Backend("pallas", _assign_pallas, _encode_pallas,
                      _assign_dist_pallas, _update_pallas),
}


def register_backend(backend: Backend) -> None:
    """Register (or replace) a named backend."""
    _REGISTRY[backend.name] = backend


def available_backends() -> Tuple[str, ...]:
    return tuple(_REGISTRY) + ("auto",)


def resolve_backend(name: str = "auto") -> str:
    """Resolve "auto" to a concrete registered backend name."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return name


def get_backend(name: str = "auto") -> Backend:
    resolved = resolve_backend(name)
    try:
        return _REGISTRY[resolved]
    except KeyError:
        raise ValueError(
            f"unknown quantizer backend {name!r} (resolved {resolved!r}); "
            f"registered: {sorted(_REGISTRY)}") from None


# ---------------------------------------------------------------------------
# Lloyd iterations
# ---------------------------------------------------------------------------

def _init_centroids(x: jax.Array, num_clusters: int,
                    key: Optional[jax.Array]) -> jax.Array:
    """Farthest-point / k-means++ seeding on a strided subsample.

    Plain strided or uniform-random seeding regularly drops a true cluster and
    Lloyd cannot recover (empty-cluster local minimum). FPS guarantees spread
    seeds at O(L·M·D) cost on an M = O(L) subsample — negligible next to one
    Lloyd iteration over the full batch. With a PRNG key the selection becomes
    kmeans++ (D² sampling); without, it is deterministic farthest-point.
    """
    n, d = x.shape
    L = num_clusters
    m = min(n, max(4 * L, 256))
    xs = x[:: max(n // m, 1)][:m]
    m = xs.shape[0]

    cents0 = jnp.zeros((L, d), x.dtype).at[0].set(xs[0])
    mind0 = jnp.sum(jnp.square(xs - xs[0][None, :]), axis=-1)

    if key is None:
        def body(l, state):
            cents, mind = state
            idx = jnp.argmax(mind)
            c = xs[idx]
            cents = cents.at[l].set(c)
            mind = jnp.minimum(mind,
                               jnp.sum(jnp.square(xs - c[None, :]), axis=-1))
            return cents, mind
        cents, _ = jax.lax.fori_loop(1, L, body, (cents0, mind0))
    else:
        keys = jax.random.split(key, L)

        def body(l, state):
            cents, mind = state
            logits = jnp.log(jnp.maximum(mind, 1e-30))
            idx = jax.random.categorical(keys[l], logits)
            c = xs[idx]
            cents = cents.at[l].set(c)
            mind = jnp.minimum(mind,
                               jnp.sum(jnp.square(xs - c[None, :]), axis=-1))
            return cents, mind
        cents, _ = jax.lax.fori_loop(1, L, body, (cents0, mind0))
    return cents


def lloyd(x: jax.Array, num_clusters: int, num_iters: int = 8, *,
          key: Optional[jax.Array] = None, chunk: int = 4096,
          backend: str = "jnp",
          init_centroids: Optional[jax.Array] = None) -> jax.Array:
    """Lloyd iterations only: returns fp32 centroids (L, D), no final assign.

    ``init_centroids`` (L, D) warm-starts the iterations from a previous
    round's codebook instead of FPS/kmeans++ seeding — the cross-round
    reuse path (``num_iters`` is then typically ``PQConfig.warm_iters``;
    ``num_iters=0`` returns the initializer unchanged).

    Each iteration's statistics come from the backend's fused ``update``
    (one HBM sweep under Pallas) or the ``assign``-based scan fallback. The
    centroid update is accumulated as deviations from the current centroids
    (see module docstring) so clusters that exactly cover their points are
    fixed points of the update in fp32, not just in exact arithmetic.
    """
    x = x.astype(jnp.float32)
    n, d = x.shape
    L = num_clusters
    b = get_backend(backend)

    # records eager calls only (a no-op while jit-tracing; shapes are
    # static either way, so the args never capture tracers)
    with obs.span("kmeans.lloyd", cat="kmeans", n=int(n), d=int(d),
                  clusters=int(L), iters=int(num_iters), backend=b.name,
                  warm=init_centroids is not None):
        # pad N up to a multiple of chunk; padded rows carry zero weight
        xc, n, n_pad = _pad_chunks(x, chunk)
        weights = jnp.concatenate(
            [jnp.ones((n,), jnp.float32), jnp.zeros((n_pad,), jnp.float32)])
        x_flat = xc.reshape(-1, d)
        chunk_eff = xc.shape[1]

        if init_centroids is not None:
            cents0 = init_centroids.astype(jnp.float32)
            if cents0.shape != (L, d):
                raise ValueError(
                    f"init_centroids {cents0.shape} != ({L}, {d})")
        else:
            cents0 = _init_centroids(x, L, key)

        def lloyd_iter(_, cents):
            if b.update is not None:
                dsums, counts = b.update(x_flat, weights, cents, chunk_eff)
            else:
                dsums, counts = _update_scan(b.assign, x_flat, weights,
                                             cents, chunk_eff)
            # empty clusters keep their previous centroid
            return cents + jnp.where(counts[:, None] > 0,
                                     dsums / jnp.maximum(counts[:, None],
                                                         1.0),
                                     0.0)

        return jax.lax.fori_loop(0, num_iters, lloyd_iter, cents0)


def kmeans(x: jax.Array, num_clusters: int, num_iters: int = 8, *,
           key: Optional[jax.Array] = None, chunk: int = 4096,
           backend: str = "jnp",
           init_centroids: Optional[jax.Array] = None) -> KMeansResult:
    """Lloyd's algorithm with a fixed iteration count.

    Args:
      x: (N, D) points. Computation runs in fp32 regardless of input dtype.
      num_clusters: L.
      num_iters: Lloyd iterations (static).
      key: optional PRNG key for random init; None = deterministic strided.
      chunk: points per scan step for the assign/accumulate pass.
      backend: "jnp" | "pallas" | "auto" (see module docstring).
      init_centroids: optional (L, D) warm-start codebook (skips seeding).
    Returns:
      KMeansResult(centroids (L, D) in x.dtype, codes (N,) int32, distortion).
    """
    in_dtype = x.dtype
    xf = x.astype(jnp.float32)
    n = xf.shape[0]
    with obs.span("kmeans.kmeans", cat="kmeans", n=int(n),
                  clusters=int(num_clusters), iters=int(num_iters)):
        cents = lloyd(xf, num_clusters, num_iters, key=key, chunk=chunk,
                      backend=backend, init_centroids=init_centroids)
        b = get_backend(backend)
        if b.assign_dist is not None:
            codes, sqdist = b.assign_dist(xf, cents, chunk)
        else:  # backend without a distance pass: derive from encode
            _, resid, codes = b.encode(xf, cents, chunk)
            sqdist = jnp.sum(resid * resid, axis=-1)
        distortion = jnp.sum(sqdist) / jnp.maximum(n, 1)
        return KMeansResult(cents.astype(in_dtype), codes, distortion)


@functools.partial(jax.jit, static_argnums=(1, 2))
def kmeans_jit(x, num_clusters, num_iters):
    return kmeans(x, num_clusters, num_iters)


def _vmap_groups(per_group_fn, x, key, init=None, **kw):
    fn = functools.partial(per_group_fn, **kw)
    keys = None if key is None else jax.random.split(key, x.shape[0])
    if init is None and keys is None:
        return jax.vmap(lambda g: fn(g))(x)
    if init is None:
        return jax.vmap(lambda g, k: fn(g, key=k))(x, keys)
    if keys is None:
        return jax.vmap(lambda g, c: fn(g, init_centroids=c))(x, init)
    return jax.vmap(
        lambda g, k, c: fn(g, key=k, init_centroids=c))(x, keys, init)


def batched_lloyd(x: jax.Array, num_clusters: int, num_iters: int = 8, *,
                  key: Optional[jax.Array] = None, chunk: int = 4096,
                  backend: str = "jnp",
                  init_centroids: Optional[jax.Array] = None) -> jax.Array:
    """vmapped ``lloyd`` over a leading group axis. x: (G, N, D) -> (G, L, D).
    ``init_centroids``: optional (G, L, D) per-group warm-start codebooks."""
    return _vmap_groups(lloyd, x, key, init_centroids,
                        num_clusters=num_clusters, num_iters=num_iters,
                        chunk=chunk, backend=backend)


def batched_kmeans(x: jax.Array, num_clusters: int, num_iters: int = 8, *,
                   key: Optional[jax.Array] = None, chunk: int = 4096,
                   backend: str = "jnp",
                   init_centroids: Optional[jax.Array] = None):
    """vmapped kmeans over a leading group axis.  x: (G, N, D)."""
    return _vmap_groups(kmeans, x, key, init_centroids,
                        num_clusters=num_clusters, num_iters=num_iters,
                        chunk=chunk, backend=backend)
