"""FedLite / SplitFed / FedAvg training steps and communication accounting.

One jitted ``train_step`` realizes a full FedLite iteration (paper Fig. 1):

  client forward  ->  grouped PQ with gradient-corrected VJP  ->  server
  forward/backward  ->  client backward (receives the corrected activation
  cotangent)  ->  simultaneous client+server optimizer updates.

SplitFed is the ``quantize=False`` special case — by §3 of the paper it is
*exactly* mini-batch SGD, which ``tests/test_fedlite.py`` asserts bitwise.

The simulation maps each data-parallel mesh shard to a client cohort; the
bits that would cross the real client->server WAN link are accounted
analytically by ``comm_report`` (the paper's §3/§5 cost model), because the
whole point of the method is what it *saves on the uplink*, not what moves
across ICI inside the simulation.
"""

from __future__ import annotations

import dataclasses
import operator
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import kmeans as _km
from repro.core.quantizer import PQConfig
from repro.core.split import dtype_bits, tree_bits
from repro.models.transformer import TransformerLM
from repro.optim import Optimizer


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array

    @classmethod
    def create(cls, params, optimizer: Optimizer) -> "TrainState":
        return cls(params=params, opt_state=optimizer.init(params),
                   step=jnp.zeros((), jnp.int32))


def make_train_step(model: TransformerLM, optimizer: Optimizer, *,
                    quantize: bool = True,
                    microbatches: int = 1,
                    lam_schedule: Optional[Callable] = None,
                    donate: bool = True,
                    step_key: Optional[jax.Array] = None) -> Callable:
    """Build the jitted FedLite (quantize=True) / SplitFed (False) step.

    ``microbatches > 1`` runs gradient accumulation inside the step: the
    global batch is split along its leading axis into m sequential
    microbatches (a lax.scan), dividing peak activation memory by ~m at the
    same global batch size and numerics (grads averaged before the single
    optimizer update). Used by the memory-bound giant archs (see configs).

    ``lam_schedule(step) -> λ`` (beyond-paper): schedules the gradient-
    correction strength per step without recompilation — e.g. a warm-up that
    keeps λ≈0 until the server head carries signal, avoiding the
    activation-collapse failure mode of a strong constant λ at extreme
    compression (see EXPERIMENTS.md §Perf).

    ``step_key`` (beyond-paper): a base PRNG key; each step folds in
    ``state.step`` and hands the derived key to the model's cut-layer
    codecs — today that enables stochastic rounding on the ``scalarq``
    downlink. ``None`` keeps the deterministic, bitwise-historical path.

    The returned step accepts an optional third argument ``cut_state``
    (`core/compressors.CutState`): when passed, the model threads codebook
    warm-start / error-feedback state through the round and returns the
    updated state under ``metrics["cut_state"]`` (callers pop it before
    treating metrics as scalars). Incompatible with ``microbatches > 1``.
    """

    def loss_fn(params, batch, step, cut_state):
        lam = None if lam_schedule is None else lam_schedule(step)
        kw = {}
        if step_key is not None:
            kw["key"] = jax.random.fold_in(step_key, step)
        if cut_state is not None:
            kw["cut_state"] = cut_state
        return model.loss(params, batch, quantize=quantize, lam_override=lam,
                          **kw)

    def grads_of(params, batch, step, cut_state=None):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch, step,
                                                         cut_state)

    def train_step(state: TrainState, batch,
                   cut_state=None) -> Tuple[TrainState, Dict]:
        if microbatches == 1:
            (loss, metrics), grads = grads_of(state.params, batch, state.step,
                                              cut_state)
        else:
            if cut_state is not None:
                raise ValueError(
                    "cut_state is not supported with microbatches > 1")
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])

            mb = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, mbatch):
                g_acc, loss_acc = carry
                (loss, metrics), g = grads_of(state.params, mbatch, state.step)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            (g_sum, loss_sum), metrics = jax.lax.scan(
                acc, (zero_g, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype),
                g_sum, state.params)
            loss = loss_sum / microbatches
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = jax.tree.map(operator.add, state.params, updates)
        metrics = dict(metrics, loss=loss)
        return TrainState(params, opt_state, state.step + 1), metrics

    return jax.jit(train_step, donate_argnums=(0,) if donate else ())


def make_weighted_step(model, optimizer: Optimizer, *,
                       quantize: bool = True, donate: bool = True,
                       step_key: Optional[jax.Array] = None) -> Callable:
    """Per-contribution staleness-weighted server update (FedBuff, exact).

    ``step(state, batches, weights)`` takes client-major batches (every leaf
    (C, B, ...)) and a (C,) weight vector; each client's gradient split is
    computed separately (vmap over the client axis) and discounted by ITS
    OWN staleness weight before aggregation:

        ĝ = (1/C) Σ_c w_c · g_c          (Nguyen et al. 2022, eq. 4)

    — where the cohort-level approximation the scheduler previously used
    scaled the fused cohort gradient by mean(w). The two agree exactly only
    when all buffered contributions share one staleness. Weights are traced
    (no recompile per staleness multiset); one optimizer update per flush.

    ``donate=True`` donates the train state to the jit — like
    ``make_train_step`` — so the optimizer update reuses the parameter
    buffers instead of copying the full params per async flush (pass False
    when the caller keeps using the pre-step state). ``step_key`` and the
    optional ``cut_state`` argument (leaves with a leading client axis)
    mirror ``make_train_step``'s cut-layer threading, per client.
    """

    def loss_fn(params, batch, key, cut_state):
        kw = {}
        if key is not None:
            kw["key"] = key
        if cut_state is not None:
            kw["cut_state"] = cut_state
        return model.loss(params, batch, quantize=quantize, **kw)

    def weighted_step(state: TrainState, batches, weights,
                      cut_state=None) -> Tuple[TrainState, Dict]:
        num_clients = weights.shape[0]
        base = None if step_key is None \
            else jax.random.fold_in(step_key, state.step)
        keys = None if base is None else jax.random.split(base, num_clients)

        def per_client(params, b, key, cs):
            (loss, metrics), g = jax.value_and_grad(
                loss_fn, has_aux=True)(params, b, key, cs)
            return g, loss, metrics

        grads, losses, metrics = jax.vmap(
            per_client,
            in_axes=(None, 0, None if keys is None else 0,
                     None if cut_state is None else 0))(
            state.params, batches, keys, cut_state)
        w = weights.astype(jnp.float32) / weights.shape[0]
        ghat = jax.tree.map(
            lambda g: jnp.tensordot(w, g.astype(jnp.float32), axes=1)
            .astype(g.dtype), grads)
        updates, opt_state = optimizer.update(ghat, state.opt_state,
                                              state.params)
        params = jax.tree.map(operator.add, state.params, updates)
        # the cut state is carry, not a scalar metric: keep its client axis
        new_cut = metrics.pop("cut_state", None)
        metrics = jax.tree.map(lambda m: jnp.mean(m, axis=0), metrics)
        metrics = dict(metrics, loss=jnp.mean(losses),
                       mean_staleness_weight=jnp.mean(weights))
        if new_cut is not None:
            metrics["cut_state"] = new_cut
        return TrainState(params, opt_state, state.step + 1), metrics

    return jax.jit(weighted_step, donate_argnums=(0,) if donate else ())


def make_mesh_step(model, optimizer: Optimizer, mesh, *,
                   quantize: bool = True, donate: bool = True,
                   step_key: Optional[jax.Array] = None,
                   correction_scope: str = "cohort") -> Callable:
    """Cohort-parallel server update: shard_map over the ``clients`` axis.

    The mesh analogue of the stacked steps: client-major inputs (every
    batch leaf ``(C, B, ...)``, weights/mask ``(C,)``, optional per-client
    ``cut_state``) are sharded over ``mesh``'s ``clients`` axis; each shard
    computes its local clients' gradients (vmap, the per-client math of
    ``make_weighted_step`` — including the shard-local cut-state carry) and
    the weighted gradient sum crosses shards exactly once, as an explicit
    psum over ``clients``.

    ``mask`` (0/1 per client slot) exists because a cohort rarely divides
    the shard count: callers pad the client axis to a multiple of the mesh
    size and zero-mask the padding, which contributes nothing to the
    gradient or the masked metric means (padded slots' gradients are
    multiplied by the mask AFTER the cut hooks run, so the λ-correction of
    a duplicated padding row cannot leak either).

    ``correction_scope`` pins which stacked semantic the per-client
    gradients reproduce — the two differ ONLY in how FedLite's eq.-5
    λ-correction meets the loss scaling, because the correction is added to
    the raw activation cotangent inside the VJP hook rather than scaling
    with it:

      * ``"cohort"`` — the fused synchronous step (``make_train_step`` on
        the concatenated cohort batch): each client's loss is pre-scaled by
        ``w_c / Σm`` INSIDE differentiation, so the data cotangent reaching
        the cut hook carries the global 1/(C·B) scale while the correction
        fires at full λ — gradients match the stacked step bit-for-bit up
        to float reassociation. Used by the synchronous policies.
      * ``"client"`` — ``make_weighted_step`` (FedBuff): raw per-client
        gradients (correction at λ against the client-local 1/B cotangent)
        are discounted AFTER differentiation by ``w_c / Σm``. Used under
        `AsyncBuffer`, where the staleness weights must discount the whole
        contribution, correction included.

    Per-client metrics come back masked-mean-reduced; the cut state (when
    passed) returns under ``metrics["cut_state"]`` in client-major layout,
    sharding preserved, padding slots still attached (callers absorb only
    the unmasked entries). One optimizer update per call, on the replicated
    combined gradient — parameters never shard over ``clients``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.sharding.ctx import CLIENTS_AXIS

    if CLIENTS_AXIS not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no "
                         f"{CLIENTS_AXIS!r} axis")
    if correction_scope not in ("cohort", "client"):
        raise ValueError(f"correction_scope={correction_scope!r} must be "
                         "'cohort' or 'client'")
    pre_scale = correction_scope == "cohort"

    def loss_fn(params, batch, key, cut_state):
        kw = {}
        if key is not None:
            kw["key"] = key
        if cut_state is not None:
            kw["cut_state"] = cut_state
        return model.loss(params, batch, quantize=quantize, **kw)

    def mesh_step(state: TrainState, batches, weights, mask,
                  cut_state=None) -> Tuple[TrainState, Dict]:
        num_slots = weights.shape[0]
        base = None if step_key is None \
            else jax.random.fold_in(step_key, state.step)
        keys = None if base is None else jax.random.split(base, num_slots)
        cnt = jnp.maximum(jnp.sum(mask.astype(jnp.float32)), 1.0)

        def shard_local(params, b, w, m, keys_l, cs):
            def per_client(b_i, s_i, key_i, cs_i):
                def scaled(p):
                    loss, metrics = loss_fn(p, b_i, key_i, cs_i)
                    return loss * (s_i if pre_scale else 1.0), (loss, metrics)

                (_, (loss, metrics)), g = jax.value_and_grad(
                    scaled, has_aux=True)(params)
                return g, loss, metrics

            scale = (w / cnt).astype(jnp.float32)
            grads, losses, metrics = jax.vmap(
                per_client,
                in_axes=(0, 0, None if keys_l is None else 0,
                         None if cs is None else 0))(b, scale, keys_l, cs)
            # padding slots are zeroed AFTER differentiation either way (the
            # λ-correction inside the hook does not scale with the loss);
            # "client" scope additionally applies the weight here
            post = (m if pre_scale else m * scale).astype(jnp.float32)
            gsum = jax.tree.map(
                lambda g: jax.lax.psum(
                    jnp.tensordot(post, g.astype(jnp.float32), axes=1),
                    CLIENTS_AXIS), grads)
            return gsum, losses, metrics

        # prefix specs: every client-major pytree (batches, keys, cut state,
        # per-client losses/metrics) shards its LEADING axis over `clients`;
        # params and the psum'd gradient stay replicated
        gsum, losses, metrics = shard_map(
            shard_local, mesh=mesh,
            in_specs=(P(), P(CLIENTS_AXIS), P(CLIENTS_AXIS), P(CLIENTS_AXIS),
                      P(CLIENTS_AXIS), P(CLIENTS_AXIS)),
            out_specs=(P(), P(CLIENTS_AXIS), P(CLIENTS_AXIS)),
            check_rep=False)(state.params, batches, weights, mask, keys,
                             cut_state)
        ghat = jax.tree.map(
            lambda g, p: g.astype(p.dtype), gsum, state.params)
        updates, opt_state = optimizer.update(ghat, state.opt_state,
                                              state.params)
        params = jax.tree.map(operator.add, state.params, updates)
        new_cut = metrics.pop("cut_state", None)
        mf = mask.astype(jnp.float32)
        metrics = jax.tree.map(lambda x: jnp.sum(x * mf) / cnt, metrics)
        metrics = dict(
            metrics, loss=jnp.sum(losses * mf) / cnt,
            mean_staleness_weight=jnp.sum(weights * mf) / cnt)
        if new_cut is not None:
            metrics["cut_state"] = new_cut
        return TrainState(params, opt_state, state.step + 1), metrics

    return jax.jit(mesh_step, donate_argnums=(0,) if donate else ())


def make_eval_step(model: TransformerLM) -> Callable:
    def eval_step(params, batch):
        acts, _, _ = model.client_forward(params["client"], batch, mode="train")
        x, _, _ = model.server_forward(params["server"], acts, batch,
                                       mode="train")
        lg = model.logits(params, x)
        ce = model.token_ce(lg, batch["labels"])
        pred = jnp.argmax(lg, axis=-1)
        labels = batch["labels"]
        if model.cfg.num_codebooks > 1:
            labels = jnp.moveaxis(labels, 1, 2)
        mask = labels >= 0
        acc = jnp.sum((pred == labels) * mask) / jnp.maximum(mask.sum(), 1)
        return {"ce": ce, "accuracy": acc}

    return jax.jit(eval_step)


# ---------------------------------------------------------------------------
# communication accounting (paper Table 1 + §5 worked example)
# ---------------------------------------------------------------------------

def comm_report(model: TransformerLM, params, tokens_per_client: int,
                pq: Optional[PQConfig] = None,
                phi_bits: Optional[int] = None) -> Dict[str, float]:
    """Per-client, per-iteration wire bits for FedAvg / SplitFed / FedLite.

    ``tokens_per_client`` is B (examples per client) × activation vectors per
    example (seq length for LMs; 1 for the paper's CNN whose cut activation
    is a single flattened vector).

    ``phi_bits=None`` (default) derives the accounting width from the actual
    dtypes: parameters count per-leaf dtype bits, activations (and the PQ
    codebooks) count the model's compute dtype. Pass φ=64 explicitly to
    reproduce the paper's fixed-width §5 numbers.

    Downlink: the cut-layer gradient message is the same B·d floats unless
    the model carries a ``downlink_compressor``, in which case its analytic
    bits are reported alongside the dense baseline.
    """
    d = model.cfg.d_model
    pq = pq if pq is not None else model.pq
    act_phi = phi_bits if phi_bits is not None else \
        dtype_bits(getattr(model.cfg, "dtype", "float32"))
    client_bits = tree_bits(params["client"], phi_bits)
    total_bits = client_bits + tree_bits(params["server"], phi_bits)
    act_bits = act_phi * d * tokens_per_client

    report = {
        "activation_dim": d,
        "tokens_per_client": tokens_per_client,
        "phi_bits": float(act_phi),
        "pq_backend": None if pq is None else _km.resolve_backend(pq.backend),
        "fedavg_uplink_bits": float(total_bits),
        "splitfed_uplink_bits": float(client_bits + act_bits),
        "splitfed_activation_bits": float(act_bits),
        "downlink_dense_bits": float(act_bits),
    }
    if pq is not None:
        msg = pq.message_bits(tokens_per_client, d, phi_bits=act_phi)
        report.update({
            "fedlite_uplink_bits": float(client_bits + msg),
            "fedlite_activation_bits": float(msg),
            "activation_compression_ratio": act_bits / max(msg, 1),
            "uplink_reduction_vs_splitfed":
                (client_bits + act_bits) / max(client_bits + msg, 1),
            "uplink_reduction_vs_fedavg":
                total_bits / max(client_bits + msg, 1),
        })
    dl = getattr(model, "downlink_compressor", None)
    if dl is not None and dl.name != "none":
        dl_bits = dl.analytic_bits(tokens_per_client, d, phi_bits=act_phi)
        report.update({
            "downlink_compressor": getattr(dl, "spec", dl.name),
            "downlink_bits": float(dl_bits),
            "downlink_compression_ratio": act_bits / max(dl_bits, 1),
        })
    return report
