"""CLI: ``python -m repro.lint [paths] [--json] [--select pass,...]``.

Exit status: 0 on a clean tree, 1 on any finding, 2 on usage errors.
"""

from __future__ import annotations

import argparse
import sys

from repro.lint import (available_passes, findings_to_json, rule_catalogue,
                        run_lint)
from repro.lint import wire_checks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.lint",
        description="fedlint: jit/Pallas/shard_map/custom-VJP/wire static "
                    "analysis (see repro.lint docstring for the rule "
                    "catalogue)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories to lint (default: src)")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable findings on stdout")
    ap.add_argument("--select", default=None, metavar="PASS[,PASS...]",
                    help=f"run only these passes (available: "
                         f"{', '.join(available_passes())})")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the pass/rule catalogue and exit")
    ap.add_argument("--update-wire-manifest", action="store_true",
                    help="re-pin encode-body hashes in wire_manifest.json "
                         "for the given paths, then exit")
    args = ap.parse_args(argv)
    paths = args.paths or ["src"]

    if args.list_rules:
        for pass_name, rules in rule_catalogue().items():
            print(pass_name)
            for rule, desc in sorted(rules.items()):
                print(f"  {rule}: {desc}")
        return 0

    if args.update_wire_manifest:
        manifest = wire_checks.update_manifest(paths)
        print(f"pinned {len(manifest)} encoder(s) in "
              f"{wire_checks.MANIFEST_PATH}")
        return 0

    select = [s.strip() for s in args.select.split(",") if s.strip()] \
        if args.select else None
    try:
        findings = run_lint(paths, select)
    except (FileNotFoundError, ValueError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    if args.json:
        print(findings_to_json(findings))
    else:
        for f in findings:
            print(f.format())
        print(f"{len(findings)} finding(s) from "
              f"{len(select or available_passes())} pass(es)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
