"""jaxpr-level checks: properties the AST passes cannot decide statically.

These helpers trace a function (abstractly — no FLOPs run) and inspect the
resulting jaxpr, complementing the AST passes:

  * ``collective_axis_names`` — every named axis appearing in collective
    equations (``psum``/``all_gather``/``shard_map``...), recursing into
    closed subjaxprs. Cross-checked against a mesh's declared axes by
    ``undeclared_collective_axes``.
  * ``host_callback_primitives`` — callback/debug primitives reachable
    from traced code (``pure_callback``, ``io_callback``,
    ``debug_callback``): each is a host round-trip per step.
  * ``integer_cotangent_violations`` — runs the real VJP and verifies the
    float0/None cotangent contract for integer/bool primals (the bug class
    the custom-VJP AST pass can only check arity for).
"""

from __future__ import annotations

from typing import Iterable, List, Sequence, Set

import jax
import jax.numpy as jnp

_CALLBACK_PRIMITIVES = {"pure_callback", "io_callback", "debug_callback",
                        "outside_call"}


def iter_eqns(jaxpr) -> Iterable:
    """All equations of ``jaxpr``, recursing into closed subjaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            sub = getattr(v, "jaxpr", None)
            if sub is not None:
                yield from iter_eqns(sub)
            elif isinstance(v, (list, tuple)):
                for item in v:
                    sub = getattr(item, "jaxpr", None)
                    if sub is not None:
                        yield from iter_eqns(sub)


def _axis_strings(value) -> Set[str]:
    if isinstance(value, str):
        return {value}
    if isinstance(value, (list, tuple, set, frozenset)):
        out: Set[str] = set()
        for v in value:
            out |= _axis_strings(v)
        return out
    return set()


def collective_axis_names(fn, *args, **kwargs) -> Set[str]:
    """Named axes referenced by collectives in ``fn``'s jaxpr."""
    jaxpr = jax.make_jaxpr(fn, **kwargs)(*args).jaxpr
    axes: Set[str] = set()
    for eqn in iter_eqns(jaxpr):
        for key in ("axes", "axis_name", "axis_names"):
            if key in eqn.params:
                axes |= _axis_strings(eqn.params[key])
        mesh = eqn.params.get("mesh")
        if mesh is not None and hasattr(mesh, "axis_names"):
            # shard_map in/out specs reference these; the mesh itself
            # declares them, so they are not "uses" — skip.
            pass
    return axes


def undeclared_collective_axes(fn, declared: Sequence[str],
                               *args) -> Set[str]:
    """Collective axes in ``fn``'s jaxpr that ``declared`` does not cover."""
    return collective_axis_names(fn, *args) - set(declared)


def host_callback_primitives(fn, *args) -> List[str]:
    """Names of host-callback primitives reachable from ``fn``'s jaxpr."""
    jaxpr = jax.make_jaxpr(fn)(*args).jaxpr
    return [eqn.primitive.name for eqn in iter_eqns(jaxpr)
            if eqn.primitive.name in _CALLBACK_PRIMITIVES]


def integer_cotangent_violations(fn, *primals) -> List[int]:
    """Argument indices whose cotangent violates the float0 contract.

    Runs ``jax.vjp(fn, *primals)`` with a ones-like output cotangent. For
    every integer/bool primal, the returned cotangent must have dtype
    ``float0`` (the "no gradient" dtype) — anything else means the custom
    VJP invents gradients for non-differentiable inputs. Raises whatever
    the VJP itself raises (a wrong-arity bwd fails here too)."""
    out, vjp_fn = jax.vjp(fn, *primals)
    cts = vjp_fn(jax.tree.map(jnp.ones_like, out))
    bad: List[int] = []
    for i, (p, ct) in enumerate(zip(primals, cts)):
        leaves = jax.tree.leaves(p)
        ct_leaves = jax.tree.leaves(ct)
        if not leaves or not ct_leaves:
            continue
        if all(jnp.issubdtype(jnp.asarray(l).dtype, jnp.integer)
               or jnp.asarray(l).dtype == jnp.bool_ for l in leaves):
            if any(c.dtype != jax.dtypes.float0 for c in ct_leaves):
                bad.append(i)
    return bad
