"""host-sync / retrace hygiene pass.

Flags device→host synchronization and recompilation hazards:

  * host-sync calls (``float()``, ``.item()``, ``np.asarray``,
    ``jax.device_get``, ``print``, ``.block_until_ready()``) inside
    jit-traced code — these either fail at trace time or silently insert a
    blocking transfer per step;
  * the same calls inside host-side hot loops and per-arrival callbacks
    (the scheduler's ``execute=`` path) when they touch values produced by
    a jitted step — a per-round device sync defeating async dispatch;
  * jit closures rebuilt per call: a ``@jax.jit`` function defined *and
    called* inside another function gets a fresh cache on every invocation,
    i.e. a full retrace per round;
  * ``static_argnames`` naming parameters the wrapped function does not
    have, and ``static_argnums``/``donate_argnums`` out of range — silent
    cache-miss churn on newer JAX, errors on older;
  * hand-rolled timing (``time.perf_counter`` & friends) and ``print``
    in the ``repro/federated`` / ``repro/core`` hot paths — telemetry
    there goes through ``repro.obs`` spans/events so host and virtual
    time lanes stay aligned in one exportable log (benchmarks, tests and
    the obs package itself are exempt; ``# fedlint: disable=`` works as
    everywhere).
"""

from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             call_name, dotted_name, keyword_arg)

_JIT_NAMES = {"jit", "jax.jit"}
_TRACE_WRAPPERS = {"shard_map", "jax.experimental.shard_map.shard_map",
                   "pmap", "jax.pmap", "vmap", "jax.vmap"}
_NP_HOST = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
            "onp.asarray", "onp.array"}
_SYNC_ATTRS = {"item", "block_until_ready"}

# the hot paths where ad-hoc timing/printing is banned in favor of
# repro.obs spans/events (repro/obs itself is deliberately outside)
_HOT_PATH_RE = re.compile(r"(^|[/\\])repro[/\\](federated|core)[/\\]")
_TEST_PATH_RE = re.compile(r"(^|[/\\])(tests?[/\\]|test_)")
_RAW_TIMERS = {"time.perf_counter", "time.monotonic", "time.process_time",
               "time.perf_counter_ns", "time.monotonic_ns",
               "perf_counter", "monotonic", "process_time",
               "perf_counter_ns", "monotonic_ns"}


def _is_jit_expr(node: ast.expr) -> bool:
    """True for ``jax.jit``, ``jax.jit(...)`` and
    ``functools.partial(jax.jit, ...)`` decorator/value expressions."""
    name = dotted_name(node)
    if name in _JIT_NAMES:
        return True
    if isinstance(node, ast.Call):
        fname = call_name(node)
        if fname in _JIT_NAMES:
            return True
        if fname in ("functools.partial", "partial") and node.args \
                and dotted_name(node.args[0]) in _JIT_NAMES:
            return True
    return False


def _jit_call_params(node: ast.expr) -> Optional[ast.Call]:
    """The Call carrying jit kwargs (static_argnames etc.), if any."""
    if isinstance(node, ast.Call):
        fname = call_name(node)
        if fname in _JIT_NAMES:
            return node
        if fname in ("functools.partial", "partial") and node.args \
                and dotted_name(node.args[0]) in _JIT_NAMES:
            return node
    return None


def _own_nodes(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk ``fn`` without descending into nested function/class defs."""
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda, ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(node))


def _params(fn) -> List[str]:
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs])


def _contains_shape_access(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in ("shape", "size",
                                                       "ndim", "itemsize"):
            return True
        if isinstance(n, ast.Call) and call_name(n) in ("len", "ord"):
            return True
    return False


def _banned(call: ast.Call, *, in_jit: bool,
            dynamic_params: Optional[Set[str]] = None) -> Optional[str]:
    """A human description if ``call`` is a host sync in this context.

    In jit context ``float()``/``int()`` is only flagged when the argument
    references a *traced* (non-static) parameter — ``float(levels)`` of a
    Python scalar derived from static args is legitimate and common."""
    name = call_name(call)
    if isinstance(call.func, ast.Attribute) and call.func.attr in _SYNC_ATTRS \
            and not call.args:
        return f".{call.func.attr}() blocks on a device value"
    if name and (name in ("device_get", "jax.device_get")
                 or name.endswith(".device_get")):
        return "jax.device_get blocks on device values"
    if name in _NP_HOST and in_jit:
        return f"{name} materializes the traced value on the host"
    if name == "print" and in_jit:
        return "print() inside traced code runs at trace time only " \
               "(use jax.debug.print)"
    if name in ("float", "int") and len(call.args) == 1:
        arg = call.args[0]
        if isinstance(arg, ast.Constant) or _contains_shape_access(arg):
            return None
        if in_jit:
            refs = {n.id for n in ast.walk(arg) if isinstance(n, ast.Name)}
            if not refs & (dynamic_params or set()):
                return None
        return f"{name}() forces a blocking device→host transfer"
    return None


class HostSyncPass(LintPass):
    name = "host-sync"
    rules = {
        "host-sync-in-jit":
            "host sync (float/.item/np.asarray/device_get/print) reachable "
            "from jit-traced code",
        "host-sync-in-loop":
            "per-iteration device sync on a jitted step's output inside a "
            "host loop",
        "host-sync-in-callback":
            "device sync inside a per-arrival callback (scheduler "
            "execute=/sample_cohort= path)",
        "jit-closure-rebuild":
            "@jax.jit closure defined and called in the same function: a "
            "fresh jit cache (full retrace) per call",
        "jit-static-args":
            "static_argnames/static_argnums/donate_argnums inconsistent "
            "with the wrapped function's signature",
        "raw-timing-in-hot-path":
            "hand-rolled time.perf_counter()/print() instrumentation in a "
            "repro/federated or repro/core hot path; record through "
            "repro.obs spans/events instead",
    }

    # ---- module facts ------------------------------------------------------

    def _module_facts(self, module: Module):
        tree = module.tree
        defs: Dict[str, List[ast.FunctionDef]] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(node)

        jit_roots: Set[ast.AST] = set()
        jitted_names: Set[str] = set()
        for fns in defs.values():
            for fn in fns:
                if any(_is_jit_expr(d) for d in fn.decorator_list):
                    jit_roots.add(fn)
                    jitted_names.add(fn.name)
        # functions passed to jax.jit(f, ...)/shard_map(f, ...) by name
        for call in ast.walk(tree):
            if not isinstance(call, ast.Call):
                continue
            cname = call_name(call)
            is_wrap = cname in _JIT_NAMES \
                or (cname and cname.split(".")[-1] in
                    {n.split(".")[-1] for n in _TRACE_WRAPPERS})
            if is_wrap and call.args and isinstance(call.args[0], ast.Name):
                target = call.args[0].id
                jitted_names.add(target)
                jit_roots.update(defs.get(target, []))
        # g = jax.jit(...) style assignments
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) and _is_jit_expr(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        jitted_names.add(t.id)
        # factories whose return value is a jitted function: calling them
        # yields a jitted callable, so assignments from those calls taint
        for fns in defs.values():
            for fn in fns:
                for node in _own_nodes(fn):
                    if not isinstance(node, ast.Return) or node.value is None:
                        continue
                    if _is_jit_expr(node.value):
                        jitted_names.add(fn.name)
                    elif isinstance(node.value, ast.Name) \
                            and node.value.id in jitted_names:
                        jitted_names.add(fn.name)

        imports_jax = any(
            (isinstance(n, ast.Import)
             and any(a.name.split(".")[0] == "jax" for a in n.names))
            or (isinstance(n, ast.ImportFrom) and n.module
                and n.module.split(".")[0] == "jax")
            for n in ast.walk(tree))
        return defs, jit_roots, jitted_names, imports_jax

    # ---- checks ------------------------------------------------------------

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        defs, jit_roots, jitted_names, imports_jax = \
            self._module_facts(module)
        findings: List[Finding] = []

        # 1. host syncs inside traced code (roots + everything nested)
        for root in jit_roots:
            static = self._static_argnames(root)
            dynamic: Set[str] = set()
            for node in ast.walk(root):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    dynamic.update(p for p in _params(node)
                                   if p not in static and p != "self")
            for node in ast.walk(root):
                if isinstance(node, ast.Call):
                    why = _banned(node, in_jit=True, dynamic_params=dynamic)
                    if why:
                        findings.append(self.finding(
                            module, node, "host-sync-in-jit",
                            f"{why} — this code is traced by jax.jit "
                            f"(via {getattr(root, 'name', '<fn>')!r})"))

        all_fns = [fn for fns in defs.values() for fn in fns]
        for fn in all_fns:
            if fn in jit_roots:
                continue
            findings.extend(self._check_loops(module, fn, jitted_names,
                                              imports_jax))
            findings.extend(self._check_closure_rebuild(module, fn))
            findings.extend(self._check_callbacks(module, fn))
        findings.extend(self._check_static_args(module, defs))
        findings.extend(self._check_raw_timing(module))
        return findings

    def _check_raw_timing(self, module: Module) -> Iterable[Finding]:
        """Ban ad-hoc wall-clock timing and print() in the hot paths.

        `repro.obs.span` records the same interval into the run's event
        log (host lane, aligned with the scheduler's virtual lane) at
        near-zero cost when telemetry is off — a bare ``perf_counter``
        pair or a ``print`` is measurement that vanishes when the run
        ends. Scoped to ``repro/federated`` and ``repro/core`` (not
        benchmarks, tests, or ``repro/obs`` itself, which legitimately
        owns the clock)."""
        if not _HOT_PATH_RE.search(module.path) \
                or _TEST_PATH_RE.search(module.path):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            if name in _RAW_TIMERS:
                yield self.finding(
                    module, node, "raw-timing-in-hot-path",
                    f"{name}() hand-rolls wall-clock timing in a hot "
                    "path; wrap the region in repro.obs.span(...) so the "
                    "measurement lands in the run's event log alongside "
                    "the scheduler's virtual clock")
            elif name == "print":
                yield self.finding(
                    module, node, "raw-timing-in-hot-path",
                    "print() in a hot path is unstructured and serializes "
                    "stdout; emit repro.obs.event(...) (or logging) so "
                    "the record survives in the run's event log")

    @staticmethod
    def _static_argnames(root) -> Set[str]:
        static: Set[str] = set()
        for dec in getattr(root, "decorator_list", []):
            c = _jit_call_params(dec)
            if c is not None:
                kw = keyword_arg(c, "static_argnames")
                if kw is not None:
                    static.update(s for s, _ in _iter_str_elems(kw))
        return static

    def _check_loops(self, module: Module, fn, jitted_names: Set[str],
                     imports_jax: bool) -> Iterable[Finding]:
        if not imports_jax:
            return
        for loop in _own_nodes(fn):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            tainted: Set[str] = set()
            for node in ast.walk(loop):
                if isinstance(node, ast.Assign) \
                        and isinstance(node.value, ast.Call) \
                        and isinstance(node.value.func, ast.Name) \
                        and node.value.func.id in jitted_names:
                    for t in node.targets:
                        names = t.elts if isinstance(t, ast.Tuple) else [t]
                        tainted.update(e.id for e in names
                                       if isinstance(e, ast.Name))
            for node in ast.walk(loop):
                if not isinstance(node, ast.Call):
                    continue
                name = call_name(node)
                if name and (name.endswith(".device_get")
                             or name == "device_get"):
                    yield self.finding(
                        module, node, "host-sync-in-loop",
                        "jax.device_get inside a loop syncs every "
                        "iteration; batch values and transfer once after "
                        "the loop")
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr in _SYNC_ATTRS and not node.args \
                        and isinstance(node.func.value, ast.Name) \
                        and node.func.value.id in tainted:
                    yield self.finding(
                        module, node, "host-sync-in-loop",
                        f"per-iteration .{node.func.attr}() on "
                        f"{node.func.value.id!r} (output of a jitted step) "
                        "blocks the dispatch pipeline")
                elif call_name(node) in ("float", "int") and node.args \
                        and isinstance(node.args[0], ast.Name) \
                        and node.args[0].id in tainted:
                    yield self.finding(
                        module, node, "host-sync-in-loop",
                        f"{call_name(node)}({node.args[0].id}) syncs a "
                        "jitted step's output every iteration; accumulate "
                        "device values and jax.device_get once after the "
                        "loop")

    def _check_closure_rebuild(self, module: Module, fn) -> Iterable[Finding]:
        nested_jits = [c for c in _own_nodes(fn)
                       if isinstance(c, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))
                       and any(_is_jit_expr(d) for d in c.decorator_list)]
        if not nested_jits:
            return
        called = {call_name(n) for n in _own_nodes(fn)
                  if isinstance(n, ast.Call)}
        for c in nested_jits:
            if c.name in called:
                yield self.finding(
                    module, c, "jit-closure-rebuild",
                    f"@jax.jit {c.name!r} is defined inside "
                    f"{fn.name!r} and called there: every call of "
                    f"{fn.name!r} builds a fresh jit cache and retraces — "
                    "hoist the jitted function (or build it once in a "
                    "factory and reuse it)")

    def _check_callbacks(self, module: Module, fn) -> Iterable[Finding]:
        nested = {c.name: c for c in _own_nodes(fn)
                  if isinstance(c, (ast.FunctionDef, ast.AsyncFunctionDef))
                  and not any(_is_jit_expr(d) for d in c.decorator_list)}
        if not nested:
            return
        passed: Set[str] = set()
        for node in _own_nodes(fn):
            if not isinstance(node, ast.Call):
                continue
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name) and arg.id in nested:
                    passed.add(arg.id)
        for name in passed:
            for node in ast.walk(nested[name]):
                if isinstance(node, ast.Call):
                    why = _banned(node, in_jit=False)
                    if why:
                        yield self.finding(
                            module, node, "host-sync-in-callback",
                            f"{why} — {name!r} is a per-arrival callback; "
                            "syncing here serializes every round "
                            "(keep device values, transfer after the run)",
                            severity="warning")

    def _check_static_args(self, module: Module, defs) -> Iterable[Finding]:
        for node in ast.walk(module.tree):
            target_fn = None
            jit_call = None
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    c = _jit_call_params(dec)
                    if c is not None:
                        target_fn, jit_call = node, c
                        break
            elif isinstance(node, ast.Call):
                c = _jit_call_params(node)
                if c is not None and c.args \
                        and isinstance(c.args[0], ast.Name):
                    cands = defs.get(c.args[0].id, [])
                    if len(cands) == 1:
                        target_fn, jit_call = cands[0], c
            if target_fn is None:
                continue
            params = _params(target_fn)
            has_var = target_fn.args.vararg or target_fn.args.kwarg
            names_kw = keyword_arg(jit_call, "static_argnames")
            if names_kw is not None and not has_var:
                literals = [v for v, _ in _iter_str_elems(names_kw)]
                for bad in [s for s in literals if s not in params]:
                    yield self.finding(
                        module, jit_call, "jit-static-args",
                        f"static_argnames names {bad!r} but "
                        f"{target_fn.name!r} has no such parameter "
                        f"(params: {params})")
            for kw in ("static_argnums", "donate_argnums"):
                nums_kw = keyword_arg(jit_call, kw)
                if nums_kw is None or has_var:
                    continue
                for idx in _iter_int_elems(nums_kw):
                    if idx >= len(params) or idx < -len(params):
                        yield self.finding(
                            module, jit_call, "jit-static-args",
                            f"{kw} index {idx} is out of range for "
                            f"{target_fn.name!r} ({len(params)} parameters)")


def _iter_str_elems(node: ast.expr):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        yield node.value, node.lineno
    elif isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, str):
                yield e.value, e.lineno


def _iter_int_elems(node: ast.expr):
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        yield node.value
    elif isinstance(node, (ast.Tuple, ast.List)):
        for e in node.elts:
            if isinstance(e, ast.Constant) and isinstance(e.value, int):
                yield e.value
