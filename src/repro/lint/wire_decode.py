"""wire-decode pass: decode calls in hot paths must catch `WireError`.

`federated/wire.py` promises that ANY malformed payload — truncation,
bit-flips, duplication, version skew, codebook-lineage mismatch — raises
from the typed `WireError` hierarchy and nothing else (the decode fuzzer
in tests/test_wire.py pins it). That promise is only worth something if
the call sites honor it: an unguarded ``decode_*`` in the federated
runtime turns a corrupt payload into a crashed server instead of a
quarantined contribution (``runtime._screen_cohort``).

This pass flags every call to ``decode_bytes`` / ``decode_payload`` /
``decode_pq_delta`` inside ``repro/federated/`` (tests excluded) that is
not lexically inside a ``try`` whose handlers catch the hierarchy —
``WireError``, one of its subclasses, ``ValueError`` (the hierarchy
root's base), or a broader catch. ``wire.py`` itself is exempt: the
codec module *produces* the hierarchy, and its internal decode calls
(e.g. `DeltaCodebookLink.decode` surfacing `WireResyncError` to drive a
resync handshake) are the contract, not a violation of it. Trusted
loopback decodes of bytes the same function just encoded carry inline
``# fedlint: disable=unchecked-wire-decode`` suppressions.
"""

from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Iterable

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             dotted_name, is_test_path)

_DECODE_NAMES = {"decode_bytes", "decode_payload", "decode_pq_delta"}
_HOT_PATH_RE = re.compile(r"(^|[/\\])repro[/\\]federated[/\\]")
# anything that catches WireError: itself, a subclass, or a superclass
_CATCHERS = {"WireError", "WireTruncationError", "WireCorruptionError",
             "WireVersionError", "WireResyncError", "ValueError",
             "Exception", "BaseException"}


def _handler_catches(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:            # bare except
        return True
    types = handler.type.elts if isinstance(handler.type, ast.Tuple) \
        else [handler.type]
    for t in types:
        name = dotted_name(t)
        if name is not None and name.split(".")[-1] in _CATCHERS:
            return True
    return False


class WireDecodePass(LintPass):
    name = "wire-decode"
    rules = {
        "unchecked-wire-decode":
            "wire decode call in a federated hot path outside a try that "
            "catches the WireError hierarchy; a malformed payload crashes "
            "the server instead of being quarantined",
    }

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        if not _HOT_PATH_RE.search(module.path) or is_test_path(module.path):
            return
        if Path(module.path).name == "wire.py":
            return   # the codec module produces the hierarchy
        yield from self._visit(module.tree, False, module)

    def _visit(self, node: ast.AST, guarded: bool,
               module: Module) -> Iterable[Finding]:
        if isinstance(node, ast.Try):
            caught = any(_handler_catches(h) for h in node.handlers)
            for child in node.body:
                yield from self._visit(child, guarded or caught, module)
            # handler/else/finally bodies are OUTSIDE the try's protection
            for h in node.handlers:
                for child in h.body:
                    yield from self._visit(child, guarded, module)
            for child in node.orelse + node.finalbody:
                yield from self._visit(child, guarded, module)
            return
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            short = name.split(".")[-1] if name else ""
            if short in _DECODE_NAMES and not guarded:
                yield self.finding(
                    module, node, "unchecked-wire-decode",
                    f"{short}() outside a try/except catching WireError: "
                    "corrupt or truncated payloads raise the typed wire "
                    "hierarchy — catch it and quarantine the contribution "
                    "(or suppress for trusted loopback bytes)")
        for child in ast.iter_child_nodes(node):
            yield from self._visit(child, guarded, module)
