"""Pallas kernel checks.

For every ``pl.pallas_call`` site (and every kernel body, identified by
``*_ref`` parameters):

  * index-map arity — each BlockSpec's index map must take one argument
    per grid dimension (a 3-D grid with a 2-arg lambda only fails at
    lowering time, on a TPU);
  * index-map rank — the returned block-index tuple must have one entry
    per block-shape dimension;
  * block divisibility — when the out_shape and the out BlockSpec are both
    integer literals, block dims must divide the operand dims (partial
    blocks need explicit padding, as ``kernels/ops.py`` does);
  * VMEM footprint — when every block/scratch shape is statically
    resolvable, the summed per-step footprint (4 B/elem) is checked
    against the per-core VMEM budget (16 MiB, v4/v5e class);
  * fp32 accumulator discipline — ``dot_general``/``dot``/``matmul``/``@``
    inside a kernel body must pin ``preferred_element_type=jnp.float32``
    or the MXU accumulates at the input dtype;
  * no hardcoded ``interpret=True`` outside tests — neither as a call
    keyword nor as a parameter default; the backend-aware resolution in
    ``kernels/ops.py`` is the one place that decision belongs.
"""

from __future__ import annotations

import ast
import math
from typing import Dict, Iterable, List, Optional, Tuple

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             call_name, is_test_path, keyword_arg)

VMEM_BUDGET_BYTES = 16 * 1024 * 1024   # per-core VMEM, v4/v5e class
_MATMULS = {"dot_general", "dot", "matmul"}


def _literal_int_tuple(node: ast.expr) -> Optional[List[Optional[int]]]:
    """Tuple elements as ints where literal, None where not; None if the
    node is not a tuple/list at all."""
    if not isinstance(node, (ast.Tuple, ast.List)):
        return None
    out: List[Optional[int]] = []
    for e in node.elts:
        out.append(e.value if isinstance(e, ast.Constant)
                   and isinstance(e.value, int) else None)
    return out


def _fn_arity(fn) -> Optional[int]:
    if fn is None:
        return None
    args = fn.args
    if args.vararg or args.kwarg:
        return None
    return len(args.posonlyargs) + len(args.args) + len(args.kwonlyargs)


def _fn_return_tuple_len(fn) -> Optional[int]:
    if isinstance(fn, ast.Lambda):
        return len(fn.body.elts) if isinstance(fn.body, ast.Tuple) else None
    if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
        lens = {len(n.value.elts) for n in ast.walk(fn)
                if isinstance(n, ast.Return)
                and isinstance(n.value, ast.Tuple)}
        return lens.pop() if len(lens) == 1 else None
    return None


class PallasPass(LintPass):
    name = "pallas"
    rules = {
        "pallas-index-map-arity":
            "BlockSpec index map arity does not match the grid rank",
        "pallas-index-map-rank":
            "BlockSpec index map returns a block index whose rank does not "
            "match the block shape",
        "pallas-block-divide":
            "block shape does not divide the operand shape (needs explicit "
            "padding)",
        "pallas-vmem-budget":
            "statically-resolvable per-step block footprint exceeds the "
            "per-core VMEM budget",
        "pallas-accum-dtype":
            "matmul in a kernel body without "
            "preferred_element_type=jnp.float32 (MXU accumulates at input "
            "dtype)",
        "pallas-interpret-hardcoded":
            "interpret=True hardcoded outside tests (belongs in the "
            "backend-aware default of kernels/ops.py)",
    }

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        tree = module.tree
        in_tests = is_test_path(module.path)

        local_fns: Dict[str, ast.AST] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                local_fns.setdefault(node.name, node)
            elif isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Lambda):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        local_fns.setdefault(t.id, node.value)

        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield from self._check_def(module, node, in_tests)
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            last = name.split(".")[-1] if name else ""
            if not in_tests:
                kw = keyword_arg(node, "interpret")
                if isinstance(kw, ast.Constant) and kw.value is True:
                    yield self.finding(
                        module, kw, "pallas-interpret-hardcoded",
                        "interpret=True hardcoded at a call site — on a "
                        "TPU this silently runs the kernel in python; let "
                        "the ops-layer default resolve it per backend")
            if last == "pallas_call":
                yield from self._check_pallas_call(module, node, local_fns)

    # ---- kernel bodies -----------------------------------------------------

    def _check_def(self, module: Module, fn,
                   in_tests: bool) -> Iterable[Finding]:
        args = fn.args
        pos = args.posonlyargs + args.args
        defaults = args.defaults
        if not in_tests:
            pairs = list(zip(pos[len(pos) - len(defaults):], defaults)) \
                + list(zip(args.kwonlyargs, args.kw_defaults))
            for param, default in pairs:
                if param.arg == "interpret" \
                        and isinstance(default, ast.Constant) \
                        and default.value is True:
                    yield self.finding(
                        module, param, "pallas-interpret-hardcoded",
                        f"{fn.name!r} defaults interpret=True — a caller "
                        "that omits the kwarg runs python-interpreted on "
                        "TPU; default to False (or None + backend-aware "
                        "resolution)")
        if not any(p.arg.endswith("_ref") for p in pos):
            return
        # this is a kernel body: fp32 accumulator discipline
        for node in ast.walk(fn):
            if isinstance(node, ast.BinOp) and isinstance(node.op,
                                                          ast.MatMult):
                yield self.finding(
                    module, node, "pallas-accum-dtype",
                    f"'@' matmul in kernel {fn.name!r} cannot pin the "
                    "accumulator dtype — use lax.dot_general(..., "
                    "preferred_element_type=jnp.float32)")
            if isinstance(node, ast.Call):
                cname = call_name(node)
                if cname and cname.split(".")[-1] in _MATMULS \
                        and keyword_arg(node,
                                        "preferred_element_type") is None:
                    yield self.finding(
                        module, node, "pallas-accum-dtype",
                        f"{cname} in kernel {fn.name!r} without "
                        "preferred_element_type=jnp.float32: the MXU "
                        "accumulates at the input dtype (bf16 inputs lose "
                        "the fp32 accumulation the reference math assumes)")

    # ---- pallas_call sites -------------------------------------------------

    def _check_pallas_call(self, module: Module, call: ast.Call,
                           local_fns: Dict[str, ast.AST]
                           ) -> Iterable[Finding]:
        grid = keyword_arg(call, "grid")
        grid_rank: Optional[int] = None
        if isinstance(grid, (ast.Tuple, ast.List)):
            grid_rank = len(grid.elts)
        elif grid is not None:
            grid_rank = 1

        specs: List[Tuple[ast.Call, bool]] = []   # (BlockSpec call, is_out)
        in_specs = keyword_arg(call, "in_specs")
        if isinstance(in_specs, (ast.Tuple, ast.List)):
            specs += [(e, False) for e in in_specs.elts
                      if isinstance(e, ast.Call)]
        out_specs = keyword_arg(call, "out_specs")
        if isinstance(out_specs, (ast.Tuple, ast.List)):
            specs += [(e, True) for e in out_specs.elts
                      if isinstance(e, ast.Call)]
        elif isinstance(out_specs, ast.Call):
            specs.append((out_specs, True))

        out_shape = None
        os = keyword_arg(call, "out_shape")
        if isinstance(os, ast.Call) and (call_name(os) or "") \
                .endswith("ShapeDtypeStruct") and os.args:
            out_shape = _literal_int_tuple(os.args[0])

        footprint = 0
        resolvable = bool(specs)
        for spec, is_out in specs:
            cname = call_name(spec) or ""
            if not cname.split(".")[-1] == "BlockSpec":
                resolvable = False
                continue
            block = _literal_int_tuple(spec.args[0]) if spec.args else None
            index_map = spec.args[1] if len(spec.args) > 1 \
                else keyword_arg(spec, "index_map")
            if isinstance(index_map, ast.Name):
                index_map = local_fns.get(index_map.id)
            if index_map is not None and grid_rank is not None:
                arity = _fn_arity(index_map)
                if arity is not None and arity != grid_rank:
                    yield self.finding(
                        module, spec, "pallas-index-map-arity",
                        f"index map takes {arity} argument(s) but the grid "
                        f"has {grid_rank} dimension(s) — the map cannot "
                        "cover the grid")
            if index_map is not None and block is not None:
                rank = _fn_return_tuple_len(index_map)
                if rank is not None and rank != len(block):
                    yield self.finding(
                        module, spec, "pallas-index-map-rank",
                        f"index map returns a rank-{rank} block index for "
                        f"a rank-{len(block)} block shape")
            if block is None or any(b is None for b in block):
                resolvable = False
            else:
                footprint += 4 * math.prod(block)
            if is_out and block is not None and out_shape is not None \
                    and len(block) == len(out_shape):
                for dim, (b, s) in enumerate(zip(block, out_shape)):
                    if b and s and s % b:
                        yield self.finding(
                            module, spec, "pallas-block-divide",
                            f"out block dim {dim} is {b} but the operand "
                            f"dim is {s} ({s} % {b} != 0) — pad the "
                            "operand or pick a dividing block")

        scratch = keyword_arg(call, "scratch_shapes")
        if isinstance(scratch, (ast.Tuple, ast.List)):
            for e in scratch.elts:
                shape = _literal_int_tuple(e.args[0]) \
                    if isinstance(e, ast.Call) and e.args else None
                if shape is None or any(s is None for s in shape):
                    resolvable = False
                else:
                    footprint += 4 * math.prod(shape)
        if resolvable and footprint > VMEM_BUDGET_BYTES:
            yield self.finding(
                module, call, "pallas-vmem-budget",
                f"per-step block footprint ≈ {footprint / 2 ** 20:.1f} MiB "
                f"exceeds the {VMEM_BUDGET_BYTES // 2 ** 20} MiB per-core "
                "VMEM budget — shrink the block shapes")
