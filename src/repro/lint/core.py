"""fedlint framework: findings, suppressions, the pass registry, the runner.

The framework mirrors the repo's compressor/executor registry idiom
(``core/compressors.py`` / ``federated/executor.py``): passes register
factories by name, selection is by name (``--select``), and unknown names
fail loudly with the registered set in the message.

A `LintPass` sees every file twice:

  1. ``collect(module, ctx)`` — gather cross-file facts (e.g. every mesh
     axis declared anywhere) into ``ctx``;
  2. ``check(module, ctx)``  — emit `Finding`s against one module, with the
     whole-tree facts available.

Suppressions are source comments, checked per finding line:

  * ``# fedlint: disable=<rule>[,<rule>...]`` — suppress on that line;
  * ``# fedlint: disable=all``                — suppress every rule there;
  * ``# fedlint: disable-file=<rule>[,...]``  — suppress for the whole file.

Suppressing a rule is a reviewed decision: the comment lands in the diff,
whereas an un-suppressed finding fails CI (the ``static-analysis`` job and
``benchmarks/run.py --preflight`` both run ``python -m repro.lint`` and
refuse on any finding).
"""

from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

SEVERITIES = ("error", "warning")

# JSON schema version for --json output (tests pin the layout)
JSON_SCHEMA_VERSION = 1

_SUPPRESS_RE = re.compile(
    r"#\s*fedlint:\s*(?P<scope>disable|disable-file)\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_,\s-]+)")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One lint result: where, which rule, and why it matters."""
    path: str          # file, relative to the lint invocation
    line: int          # 1-indexed source line
    rule: str          # stable rule id (kebab-case; suppression key)
    message: str       # human explanation with the concrete evidence
    severity: str = "error"
    pass_name: str = ""

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.severity} "
                f"[{self.rule}] {self.message}")

    def to_json(self) -> Dict[str, object]:
        return {"path": self.path, "line": self.line, "rule": self.rule,
                "severity": self.severity, "pass": self.pass_name,
                "message": self.message}


class Module:
    """One parsed source file plus its suppression table."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.line_suppressions: Dict[int, set] = {}
        self.file_suppressions: set = set()
        for lineno, line in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(line)
            if not m:
                continue
            rules = {r.strip() for r in m.group("rules").split(",")
                     if r.strip()}
            if m.group("scope") == "disable-file":
                self.file_suppressions |= rules
            else:
                self.line_suppressions.setdefault(lineno, set()).update(rules)

    def suppressed(self, rule: str, line: int) -> bool:
        if self.file_suppressions & {rule, "all"}:
            return True
        at = self.line_suppressions.get(line, set())
        return bool(at & {rule, "all"})


class LintContext:
    """Cross-file facts accumulated by the collect phase.

    Passes namespace their facts by attribute (``ctx.mesh_axes`` etc.) —
    a plain attribute bag keeps pass modules decoupled from each other.
    """

    def __init__(self, modules: Sequence[Module]):
        self.modules = list(modules)


class LintPass:
    """Base pass: subclass, set ``name``/``rules``, implement ``check``."""
    name: str = "base"
    # rule id -> one-line description (the --list-rules catalogue)
    rules: Dict[str, str] = {}

    def collect(self, module: Module, ctx: LintContext) -> None:
        """Phase 1: gather cross-file facts (default: nothing)."""

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        raise NotImplementedError

    def finding(self, module: Module, node, rule: str, message: str,
                severity: str = "error") -> Finding:
        if rule not in self.rules:
            raise ValueError(f"pass {self.name!r} emitted unregistered rule "
                             f"{rule!r}; known: {sorted(self.rules)}")
        line = getattr(node, "lineno", node if isinstance(node, int) else 1)
        return Finding(path=module.path, line=int(line), rule=rule,
                       message=message, severity=severity,
                       pass_name=self.name)


# ---------------------------------------------------------------------------
# registry (the compressor/executor idiom)
# ---------------------------------------------------------------------------

_PASSES: Dict[str, Callable[[], LintPass]] = {}


def register_pass(name: str, factory: Callable[[], LintPass]) -> None:
    """Register (or replace) a named lint pass factory."""
    _PASSES[name] = factory


def available_passes() -> Tuple[str, ...]:
    return tuple(sorted(_PASSES))


def make_passes(select: Optional[Sequence[str]] = None) -> List[LintPass]:
    names = list(select) if select else list(available_passes())
    unknown = [n for n in names if n not in _PASSES]
    if unknown:
        raise ValueError(f"unknown lint pass(es) {unknown}; registered: "
                         f"{available_passes()}")
    return [_PASSES[n]() for n in names]


def rule_catalogue() -> Dict[str, Dict[str, str]]:
    """pass name -> {rule id -> description} for every registered pass."""
    return {name: dict(_PASSES[name]().rules) for name in available_passes()}


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

def iter_python_files(paths: Sequence[str]) -> List[Path]:
    files: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            files.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            files.append(path)
        elif not path.exists():
            raise FileNotFoundError(f"lint target {p!r} does not exist")
    # stable order, no duplicates
    seen, unique = set(), []
    for f in files:
        if f not in seen:
            seen.add(f)
            unique.append(f)
    return unique


def load_modules(paths: Sequence[str]) -> List[Module]:
    modules = []
    for f in iter_python_files(paths):
        source = f.read_text(encoding="utf-8")
        modules.append(Module(str(f), source))
    return modules


def run_passes(modules: Sequence[Module],
               passes: Sequence[LintPass]) -> List[Finding]:
    ctx = LintContext(modules)
    for p in passes:
        for m in modules:
            p.collect(m, ctx)
    findings: set = set()
    for p in passes:
        for m in modules:
            for f in p.check(m, ctx):
                if not m.suppressed(f.rule, f.line):
                    findings.add(f)
    return sorted(findings)


def run_lint(paths: Sequence[str],
             select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint ``paths`` (files or directories) with the selected passes."""
    return run_passes(load_modules(paths), make_passes(select))


def findings_to_json(findings: Sequence[Finding]) -> str:
    counts: Dict[str, int] = {}
    for f in findings:
        counts[f.rule] = counts.get(f.rule, 0) + 1
    doc = {
        "schema_version": JSON_SCHEMA_VERSION,
        "findings": [f.to_json() for f in findings],
        "counts": dict(sorted(counts.items())),
        "total": len(findings),
    }
    return json.dumps(doc, indent=2, sort_keys=False)


# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains; None for anything else."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(call: ast.Call) -> Optional[str]:
    return dotted_name(call.func)


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def str_constants(node: ast.AST) -> List[Tuple[str, int]]:
    """Every string literal under ``node`` with its line number."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.append((n.value, n.lineno))
    return out


def iter_calls(tree: ast.AST) -> Iterable[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


def is_test_path(path: str) -> bool:
    parts = Path(path).parts
    return any(p in ("tests", "test") for p in parts) \
        or Path(path).name.startswith("test_")
