"""obs-events pass: every emitted obs event name must be registered.

The obs event log is a contract between emitters (``repro/federated/``)
and tooling (the inspector's ``--flight``/``--health`` views, the SLO
monitors, the Perfetto export, downstream dashboards). An event name
that exists only at its emission site is invisible to all of them — a
typo'd ``obs.event("fault.round_vioded", ...)`` silently drops data the
chaos tests think they are recording.

This pass walks ``repro/federated/`` (tests excluded) for
``obs.event(...)`` / ``event(...)`` calls and checks the literal event
name against `repro.obs.schema.EVENT_SCHEMAS`:

  * ``orphan-obs-event`` — a literal event name that is not in the
    registry: add it to ``schema.py`` (with its category and args) so
    tooling can see it, or fix the typo.
  * ``dynamic-obs-event`` — a non-literal first argument: the registry
    cannot vouch for a computed name, so hoist the name into a literal
    (or suppress with a reviewed ``# fedlint: disable=``).
"""

from __future__ import annotations

import ast
from typing import Iterable

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             dotted_name, is_test_path)
from repro.lint.fleet_loops import _HOT_PATH_RE
from repro.obs.schema import EVENT_SCHEMAS

# call shapes that append a named event to the obs log
_EVENT_FNS = frozenset({"obs.event", "event", "spans.event", "_obs_event"})


class ObsEventPass(LintPass):
    name = "obs-events"
    rules = {
        "orphan-obs-event":
            "obs.event() emits a name missing from the "
            "repro.obs.schema.EVENT_SCHEMAS registry; the inspector, SLO "
            "monitors and exporters will never see it — register it or "
            "fix the typo",
        "dynamic-obs-event":
            "obs.event() called with a computed (non-literal) event name; "
            "the schema registry cannot check it — use a literal name",
    }

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        if not _HOT_PATH_RE.search(module.path) or is_test_path(module.path):
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            fn = dotted_name(node.func)
            if fn not in _EVENT_FNS or not node.args:
                continue
            name_arg = node.args[0]
            if not isinstance(name_arg, ast.Constant) \
                    or not isinstance(name_arg.value, str):
                yield self.finding(
                    module, node, "dynamic-obs-event",
                    f"{fn}() with a computed event name: the "
                    "EVENT_SCHEMAS registry cannot vouch for it; emit a "
                    "literal name (suppress if dynamism is reviewed)")
                continue
            ev_name = name_arg.value
            if ev_name not in EVENT_SCHEMAS:
                yield self.finding(
                    module, node, "orphan-obs-event",
                    f"event {ev_name!r} is not registered in "
                    "repro.obs.schema.EVENT_SCHEMAS — tooling (inspector, "
                    "SLO monitors, Perfetto flows) will never surface it; "
                    "add it to the registry or fix the name")
