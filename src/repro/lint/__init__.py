"""fedlint — domain-aware static analysis for this repo's jax/Pallas code.

Run it as ``python -m repro.lint [paths...] [--json] [--select pass,...]``;
CI's ``static-analysis`` job and ``benchmarks/run.py --preflight`` run it
over ``src benchmarks examples`` and fail on ANY finding, so the committed
tree always carries an empty baseline. Passes live in a registry mirroring
the compressor/executor registries (``repro.lint.core.register_pass``);
``--select`` picks a subset by name. AST passes are complemented by
jaxpr-level helpers (``repro.lint.jaxprs``) for properties that need a
trace (collective axis sets, callback primitives, float0 cotangents).

Rule catalogue
==============

host-sync (``host_sync.py``)
  * ``host-sync-in-jit`` — ``float()``/``.item()``/``np.asarray``/
    ``jax.device_get``/``print`` reachable from jit-traced code.
  * ``host-sync-in-loop`` — per-iteration sync on a jitted step's output
    inside a host loop (``float(loss)`` per local step, ``device_get`` in
    a round loop).
  * ``host-sync-in-callback`` — syncs inside per-arrival callbacks (the
    scheduler's ``execute=`` path); serializes every round.
  * ``jit-closure-rebuild`` — a ``@jax.jit`` function defined *and called*
    inside another function: fresh jit cache (full retrace) per call.
  * ``jit-static-args`` — ``static_argnames`` naming absent parameters;
    ``static_argnums``/``donate_argnums`` out of range.

custom-vjp (``vjp.py``)
  * ``vjp-missing-defvjp`` — primal without ``defvjp(fwd, bwd)``.
  * ``vjp-fwd-arity`` / ``vjp-fwd-pair`` — fwd signature must match the
    primal; fwd must return ``(output, residuals)``.
  * ``vjp-bwd-arity`` — bwd takes ``len(nondiff_argnums) + 2`` params.
  * ``vjp-bwd-return-arity`` — one cotangent per differentiable primal arg.
  * ``vjp-nondiff-range`` — ``nondiff_argnums`` index out of range.

mesh-axes (``mesh_axes.py``)
  * ``mesh-axis-undeclared`` — axis names used in ``PartitionSpec``/
    collectives/``shard()`` are cross-checked against every mesh axis
    declared anywhere in the linted tree (two-phase collect/check); a
    typo'd ``"client"`` is a lint error, not a trace-time crash.

pallas (``pallas_checks.py``)
  * ``pallas-index-map-arity`` / ``pallas-index-map-rank`` — BlockSpec
    index maps must match the grid rank and the block-shape rank.
  * ``pallas-block-divide`` — literal block shapes must divide literal
    operand shapes (pad explicitly otherwise).
  * ``pallas-vmem-budget`` — statically-resolvable per-step footprint vs
    the 16 MiB per-core VMEM budget.
  * ``pallas-accum-dtype`` — matmuls in kernel bodies must pin
    ``preferred_element_type=jnp.float32``.
  * ``pallas-interpret-hardcoded`` — no ``interpret=True`` call kwargs or
    parameter defaults outside ``tests/``.

fleet-scale (``fleet_loops.py``)
  * ``python-loop-over-fleet`` — a ``for``/comprehension over a
    fleet- or arrival-sized sequence (``fleet``/``arrivals``/
    ``profiles``, incl. ``enumerate``/``zip``/``sorted`` wrappers) in
    ``repro/federated/`` hot paths: O(population) interpreter work per
    round — use the vectorized `ClientFleet`/sorted-arrival core; the
    heapq reference backend carries reviewed suppressions.

obs-events (``obs_events.py``)
  * ``orphan-obs-event`` — an ``obs.event(...)`` in ``repro/federated/``
    emitting a literal name missing from the
    ``repro.obs.schema.EVENT_SCHEMAS`` registry: invisible to the
    inspector, SLO monitors and exporters — register it or fix the typo.
  * ``dynamic-obs-event`` — a computed (non-literal) event name the
    registry cannot check; hoist it into a literal.

wire-decode (``wire_decode.py``)
  * ``unchecked-wire-decode`` — a ``decode_bytes``/``decode_payload``/
    ``decode_pq_delta`` call in ``repro/federated/`` hot paths outside a
    ``try`` catching the `WireError` hierarchy: a malformed payload
    crashes the server instead of being quarantined (``wire.py`` itself
    and reviewed loopback decodes are exempt/suppressed).

wire-format (``wire_checks.py``)
  * ``wire-kind-no-encoder`` / ``wire-kind-no-decoder`` — every
    ``KIND_*`` tag needs a ``.pack`` site and an explicit decode
    comparison (unlabeled fallthroughs mis-decode the next kind added).
  * ``wire-unknown-kind-guard`` — an explicit ``kind not in ...`` raise.
  * ``wire-version-stale`` — the AST hash of each ``encode_*`` body is
    pinned with its version literal in ``wire_manifest.json``; body edits
    require a version bump + ``--update-wire-manifest``.

Suppressions
============

  * same line:  ``x = float(loss)  # fedlint: disable=host-sync-in-loop``
  * every rule: ``# fedlint: disable=all``
  * whole file: ``# fedlint: disable-file=<rule>[,<rule>...]``

A suppression is a reviewed decision that lands in the diff; an
unsuppressed finding fails CI.
"""

from repro.lint.core import (Finding, LintPass, available_passes,
                             findings_to_json, register_pass, rule_catalogue,
                             run_lint)

# importing the pass modules registers them
from repro.lint import fleet_loops as _fleet_loops
from repro.lint import host_sync as _host_sync
from repro.lint import mesh_axes as _mesh_axes
from repro.lint import obs_events as _obs_events
from repro.lint import pallas_checks as _pallas_checks
from repro.lint import vjp as _vjp
from repro.lint import wire_checks as _wire_checks
from repro.lint import wire_decode as _wire_decode

register_pass("fleet-scale", _fleet_loops.FleetLoopPass)
register_pass("host-sync", _host_sync.HostSyncPass)
register_pass("custom-vjp", _vjp.CustomVjpPass)
register_pass("mesh-axes", _mesh_axes.MeshAxesPass)
register_pass("obs-events", _obs_events.ObsEventPass)
register_pass("pallas", _pallas_checks.PallasPass)
register_pass("wire-format", _wire_checks.WirePass)
register_pass("wire-decode", _wire_decode.WireDecodePass)

__all__ = ["Finding", "LintPass", "available_passes", "findings_to_json",
           "register_pass", "rule_catalogue", "run_lint"]
