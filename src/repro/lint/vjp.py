"""custom-VJP contract checker.

For every ``jax.custom_vjp`` primal in a module:

  * a ``primal.defvjp(fwd, bwd)`` registration must exist;
  * ``fwd`` must accept the primal's full signature and return a
    ``(output, residuals)`` 2-tuple;
  * ``bwd`` must accept ``len(nondiff_argnums) + 2`` parameters (the
    threaded nondiff args, the residuals, the cotangent) and return a tuple
    with one cotangent per *differentiable* primal argument;
  * ``nondiff_argnums`` indices must be valid positions of the primal.

Arity is checked only when it is statically decidable (no ``*args``, tuple
returns visible in the source). The float0/None cotangent discipline for
integer/state primals is a runtime property — ``repro.lint.jaxprs``
provides ``integer_cotangent_violations`` for that (used in tests).
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Tuple

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             call_name, dotted_name, keyword_arg)

_CUSTOM_VJP = {"jax.custom_vjp", "custom_vjp"}


def _custom_vjp_decorator(dec: ast.expr) -> Optional[Tuple[bool, list]]:
    """(is_custom_vjp, nondiff_argnums literal list or None)."""
    if dotted_name(dec) in _CUSTOM_VJP:
        return True, []
    if isinstance(dec, ast.Call):
        name = call_name(dec)
        if name in _CUSTOM_VJP:
            return True, _nondiff_from(dec)
        if name in ("functools.partial", "partial") and dec.args \
                and dotted_name(dec.args[0]) in _CUSTOM_VJP:
            return True, _nondiff_from(dec)
    return None


def _nondiff_from(call: ast.Call) -> Optional[list]:
    kw = keyword_arg(call, "nondiff_argnums")
    if kw is None:
        return []
    if isinstance(kw, (ast.Tuple, ast.List)) and all(
            isinstance(e, ast.Constant) and isinstance(e.value, int)
            for e in kw.elts):
        return [e.value for e in kw.elts]
    if isinstance(kw, ast.Constant) and isinstance(kw.value, int):
        return [kw.value]
    return None   # not statically known


def _n_params(fn) -> Optional[int]:
    if fn.args.vararg or fn.args.kwarg:
        return None
    return len(fn.args.posonlyargs) + len(fn.args.args) \
        + len(fn.args.kwonlyargs)


def _tuple_returns(fn) -> List[Tuple[ast.Return, int]]:
    """(return node, tuple length) for every visible tuple return in ``fn``
    (not descending into nested defs)."""
    out = []
    stack = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Return) and isinstance(node.value, ast.Tuple):
            out.append((node, len(node.value.elts)))
        stack.extend(ast.iter_child_nodes(node))
    return out


class CustomVjpPass(LintPass):
    name = "custom-vjp"
    rules = {
        "vjp-missing-defvjp":
            "jax.custom_vjp primal without a defvjp(fwd, bwd) registration",
        "vjp-fwd-arity":
            "custom_vjp fwd signature does not match the primal's",
        "vjp-fwd-pair":
            "custom_vjp fwd must return an (output, residuals) 2-tuple",
        "vjp-bwd-arity":
            "custom_vjp bwd parameter count != len(nondiff_argnums) + 2 "
            "(nondiff args are threaded before residuals and cotangent)",
        "vjp-bwd-return-arity":
            "custom_vjp bwd must return one cotangent per differentiable "
            "primal argument",
        "vjp-nondiff-range":
            "nondiff_argnums index out of the primal's parameter range",
    }

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        tree = module.tree
        defs: Dict[str, ast.FunctionDef] = {}
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name not in defs:
                defs[node.name] = node

        primals: Dict[str, Tuple[ast.FunctionDef, Optional[list]]] = {}
        for fn in defs.values():
            for dec in fn.decorator_list:
                info = _custom_vjp_decorator(dec)
                if info:
                    primals[fn.name] = (fn, info[1])
        # primal = jax.custom_vjp(f, nondiff_argnums=...) form
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call) \
                    and call_name(node.value) in _CUSTOM_VJP \
                    and node.value.args \
                    and isinstance(node.value.args[0], ast.Name):
                inner = defs.get(node.value.args[0].id)
                for t in node.targets:
                    if isinstance(t, ast.Name) and inner is not None:
                        primals[t.id] = (inner, _nondiff_from(node.value))

        registrations: Dict[str, Tuple[ast.Call, Optional[str],
                                       Optional[str]]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "defvjp" \
                    and isinstance(node.func.value, ast.Name):
                fwd = node.args[0].id if node.args \
                    and isinstance(node.args[0], ast.Name) else None
                bwd = node.args[1].id if len(node.args) > 1 \
                    and isinstance(node.args[1], ast.Name) else None
                registrations[node.func.value.id] = (node, fwd, bwd)

        for pname, (primal, nondiff) in primals.items():
            reg = registrations.get(pname)
            if reg is None:
                yield self.finding(
                    module, primal, "vjp-missing-defvjp",
                    f"custom_vjp primal {pname!r} has no "
                    f"{pname}.defvjp(fwd, bwd) in this module — "
                    "differentiating it raises at trace time")
                continue
            n_primal = _n_params(primal)
            if nondiff is not None and n_primal is not None:
                for idx in nondiff:
                    if not (0 <= idx < n_primal):
                        yield self.finding(
                            module, primal, "vjp-nondiff-range",
                            f"nondiff_argnums index {idx} is out of range "
                            f"for {pname!r} ({n_primal} parameters)")
            reg_call, fwd_name, bwd_name = reg
            fwd = defs.get(fwd_name) if fwd_name else None
            bwd = defs.get(bwd_name) if bwd_name else None
            if fwd is not None and n_primal is not None:
                n_fwd = _n_params(fwd)
                if n_fwd is not None and n_fwd != n_primal:
                    yield self.finding(
                        module, fwd, "vjp-fwd-arity",
                        f"{fwd_name!r} takes {n_fwd} parameters but the "
                        f"primal {pname!r} takes {n_primal} — custom_vjp "
                        "calls fwd with the primal's full argument list")
                for ret, n in _tuple_returns(fwd):
                    if n != 2:
                        yield self.finding(
                            module, ret, "vjp-fwd-pair",
                            f"{fwd_name!r} returns a {n}-tuple; custom_vjp "
                            "fwd must return (output, residuals)")
            if bwd is not None and nondiff is not None:
                expected = len(nondiff) + 2
                n_bwd = _n_params(bwd)
                if n_bwd is not None and n_bwd != expected:
                    yield self.finding(
                        module, bwd, "vjp-bwd-arity",
                        f"{bwd_name!r} takes {n_bwd} parameters; with "
                        f"nondiff_argnums={tuple(nondiff)} it must take "
                        f"{expected} (nondiff args, residuals, cotangent)")
                if n_primal is not None:
                    want = n_primal - len(nondiff)
                    for ret, n in _tuple_returns(bwd):
                        if n != want:
                            yield self.finding(
                                module, ret, "vjp-bwd-return-arity",
                                f"{bwd_name!r} returns {n} cotangents but "
                                f"the primal {pname!r} has {want} "
                                f"differentiable arguments "
                                f"({n_primal} params minus "
                                f"{len(nondiff)} nondiff)")
