"""wire-format exhaustiveness + version-manifest pass.

Applies to modules declaring wire kind tags (``KIND_* = <int>`` constants,
as ``federated/wire.py`` does). Checks:

  * every kind has an **encoder arm** — the constant appears in a
    ``.pack(...)`` header call;
  * every kind has a **decoder arm** — the constant appears in an explicit
    comparison (``kind == KIND_X`` / ``!=``); an unlabeled fallthrough
    (``# KIND_X`` comment at the end of a dispatch chain) does not count,
    because the next kind added silently decodes as the fallthrough;
  * an **unknown-kind rejection** exists (a ``kind not in ...`` guard that
    raises);
  * **version discipline** — the AST hash of every ``encode_*`` body is
    pinned in the checked-in ``wire_manifest.json`` next to the version
    literal it packs; editing an encode body without bumping the version
    *and* refreshing the manifest (``python -m repro.lint
    --update-wire-manifest``) is an error. Docstring-only edits do not
    change the hash.
"""

from __future__ import annotations

import ast
import copy
import hashlib
import json
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             call_name, iter_python_files)

MANIFEST_PATH = Path(__file__).with_name("wire_manifest.json")


def _kind_constants(tree: ast.Module) -> Dict[str, Tuple[int, int]]:
    """KIND_* name -> (value, lineno) for top-level int constants."""
    kinds = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id.startswith("KIND_") \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            kinds[node.targets[0].id] = (node.value.value, node.lineno)
    return kinds


def _version_constants(tree: ast.Module) -> Dict[str, int]:
    out = {}
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and node.targets[0].id.startswith("_VERSION") \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            out[node.targets[0].id] = node.value.value
    return out


def _strip_docstring(fn: ast.FunctionDef) -> ast.FunctionDef:
    fn = copy.deepcopy(fn)
    if fn.body and isinstance(fn.body[0], ast.Expr) \
            and isinstance(fn.body[0].value, ast.Constant) \
            and isinstance(fn.body[0].value.value, str):
        fn.body = fn.body[1:]
    return fn


def _encoder_hash(fn: ast.FunctionDef) -> str:
    dump = ast.dump(_strip_docstring(fn), annotate_fields=False)
    return hashlib.sha256(dump.encode()).hexdigest()[:16]


def _packed_version(fn: ast.FunctionDef,
                    versions: Dict[str, int]) -> Optional[int]:
    """The version literal this encoder packs into its header, if visible."""
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "pack" and len(node.args) >= 2:
            v = node.args[1]
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return v.value
            if isinstance(v, ast.Name) and v.id in versions:
                return versions[v.id]
    return None


def _encoders(tree: ast.Module) -> List[ast.FunctionDef]:
    return [n for n in tree.body if isinstance(n, ast.FunctionDef)
            and n.name.startswith("encode_")]


def _manifest_key(module: Module, fn_name: str) -> str:
    return f"{Path(module.path).name}:{fn_name}"


def load_manifest() -> dict:
    if MANIFEST_PATH.exists():
        return json.loads(MANIFEST_PATH.read_text())
    return {}


def update_manifest(paths) -> dict:
    """Regenerate manifest entries for every wire module under ``paths``."""
    from repro.lint.core import Module as _M
    manifest = load_manifest()
    for f in iter_python_files(paths):
        module = _M(str(f), f.read_text(encoding="utf-8"))
        if not _kind_constants(module.tree):
            continue
        versions = _version_constants(module.tree)
        for fn in _encoders(module.tree):
            manifest[_manifest_key(module, fn.name)] = {
                "hash": _encoder_hash(fn),
                "version": _packed_version(fn, versions),
            }
    MANIFEST_PATH.write_text(json.dumps(manifest, indent=2, sort_keys=True)
                             + "\n")
    return manifest


class WirePass(LintPass):
    name = "wire-format"
    rules = {
        "wire-kind-no-encoder":
            "wire kind tag never packed into a header (no encoder arm)",
        "wire-kind-no-decoder":
            "wire kind tag never compared in a decode path (no explicit "
            "decoder arm; fallthroughs mis-decode the next kind added)",
        "wire-unknown-kind-guard":
            "wire module lacks an explicit unknown-kind rejection "
            "(`kind not in ...` raise)",
        "wire-version-stale":
            "encode body changed without a version bump + manifest refresh "
            "(run `python -m repro.lint --update-wire-manifest` after "
            "bumping)",
    }

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        kinds = _kind_constants(module.tree)
        if not kinds:
            return
        packed: set = set()
        compared: set = set()
        has_guard = False
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "pack":
                for arg in node.args:
                    if isinstance(arg, ast.Name) and arg.id in kinds:
                        packed.add(arg.id)
            if isinstance(node, ast.Compare):
                names = [n.id for n in [node.left] + node.comparators
                         if isinstance(n, ast.Name)]
                for n in names:
                    if n in kinds and any(isinstance(op, (ast.Eq, ast.NotEq))
                                          for op in node.ops):
                        compared.add(n)
                if any(isinstance(op, ast.NotIn) for op in node.ops):
                    has_guard = True

        for kname, (_, line) in kinds.items():
            if kname not in packed:
                yield self.finding(
                    module, line, "wire-kind-no-encoder",
                    f"{kname} is never packed into a wire header — the "
                    "kind is declared but unproducible")
            if kname not in compared:
                yield self.finding(
                    module, line, "wire-kind-no-decoder",
                    f"{kname} is never compared in a decode dispatch — an "
                    "unlabeled fallthrough decodes it today and silently "
                    "mis-decodes the next kind added; give it an explicit "
                    f"`kind == {kname}` arm")
        if not has_guard:
            yield self.finding(
                module, 1, "wire-unknown-kind-guard",
                "no `kind not in ...` rejection found — unknown payload "
                "kinds must fail loudly, not decode as garbage")

        yield from self._check_manifest(module)

    def _check_manifest(self, module: Module) -> Iterable[Finding]:
        manifest = load_manifest()
        versions = _version_constants(module.tree)
        for fn in _encoders(module.tree):
            key = _manifest_key(module, fn.name)
            entry = manifest.get(key)
            cur_hash = _encoder_hash(fn)
            cur_version = _packed_version(fn, versions)
            if entry is None:
                yield self.finding(
                    module, fn, "wire-version-stale",
                    f"encoder {fn.name!r} is not pinned in "
                    f"{MANIFEST_PATH.name} — run `python -m repro.lint "
                    "--update-wire-manifest <paths>`")
                continue
            if entry.get("hash") != cur_hash:
                if entry.get("version") == cur_version:
                    yield self.finding(
                        module, fn, "wire-version-stale",
                        f"encode body of {fn.name!r} changed but it still "
                        f"packs version {cur_version} — old decoders would "
                        "accept payloads they cannot parse; bump the "
                        "version literal and refresh the manifest")
                else:
                    yield self.finding(
                        module, fn, "wire-version-stale",
                        f"encode body of {fn.name!r} changed (version "
                        f"{entry.get('version')} → {cur_version}); refresh "
                        "the manifest to pin the new body")
