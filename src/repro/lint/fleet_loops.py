"""fleet-scale pass: no per-client Python loops in federated hot paths.

The vectorized scheduler core exists because a Python ``for`` over a
fleet- or arrival-sized sequence is O(cohort) interpreter work per round
— the exact wall the heapq backend hit at 10^5 clients. This pass keeps
the hot paths honest: inside ``repro/federated/`` (tests excluded), any
``for`` statement or comprehension whose iterable is (or wraps, via
``enumerate``/``zip``/``sorted``/``reversed``/``list``/``tuple``) a name
like ``fleet`` / ``arrivals`` / ``profiles`` is flagged as
``python-loop-over-fleet`` — those sequences scale with the population,
so the loop should be an array op over `ClientFleet` columns or the
sorted arrival vector instead.

Round-boundary loops over cohort-sized survivors/buffers are fine (they
are bounded by the cohort, not the fleet) and are not matched. The heapq
reference backend's intentional per-arrival code carries inline
``# fedlint: disable=python-loop-over-fleet`` suppressions — the point
is that NEW per-client loops must justify themselves in review the same
way.
"""

from __future__ import annotations

import ast
import re
from typing import Iterable, List, Optional, Tuple

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             dotted_name, is_test_path)

# sequences whose length scales with the fleet/arrival population
_FLEET_NAME_RE = re.compile(r"^(fleet|fleets|arrival|arrivals|profiles)$")
# the hot paths the vectorized core owns
_HOT_PATH_RE = re.compile(r"(^|[/\\])repro[/\\]federated[/\\]")
# transparent wrappers: iterating enumerate(fleet) is iterating fleet
_WRAPPERS = ("enumerate", "zip", "sorted", "reversed", "list", "tuple")


def _fleet_operand(node: ast.expr) -> Optional[str]:
    """The fleet-like name this iterable expression walks, if any."""
    if isinstance(node, ast.Call):
        fn = dotted_name(node.func)
        if fn in _WRAPPERS:
            for arg in node.args:
                hit = _fleet_operand(arg)
                if hit:
                    return hit
        return None
    name = dotted_name(node)
    if name is None:
        return None
    last = name.split(".")[-1]
    return name if _FLEET_NAME_RE.match(last) else None


class FleetLoopPass(LintPass):
    name = "fleet-scale"
    rules = {
        "python-loop-over-fleet":
            "per-client Python for/comprehension over a fleet/arrival "
            "sequence in a federated hot path; use the vectorized "
            "ClientFleet / sorted-arrival array core (or suppress where "
            "the heapq reference backend is intentional)",
    }

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        if not _HOT_PATH_RE.search(module.path) or is_test_path(module.path):
            return
        for node in ast.walk(module.tree):
            loops: List[Tuple[ast.AST, ast.expr]] = []
            if isinstance(node, (ast.For, ast.AsyncFor)):
                loops.append((node, node.iter))
            elif isinstance(node, (ast.ListComp, ast.SetComp, ast.DictComp,
                                   ast.GeneratorExp)):
                loops.extend((gen.iter, gen.iter) for gen in node.generators)
            for anchor, it in loops:
                name = _fleet_operand(it)
                if name is None:
                    continue
                yield self.finding(
                    module, anchor, "python-loop-over-fleet",
                    f"Python loop over fleet-scaled sequence {name!r}: this "
                    "is O(population) interpreter work per round — use the "
                    "vectorized ClientFleet/array path, or suppress if this "
                    "is the heapq reference backend")
