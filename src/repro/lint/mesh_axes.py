"""mesh-axis consistency pass (two-phase, cross-file).

Collect phase — gather every *declared* mesh axis name across the whole
linted tree:

  * tuple-of-string arguments (positional or ``axis_names=``) of any call
    whose name contains ``mesh`` (``jax.make_mesh``, ``Mesh``,
    ``make_clients_mesh``, ...);
  * tuple-of-string assignments to variables named ``axes``/``axis_names``
    (including the paired-tuple form ``shape, axes = (...), (...)``);
  * ALL-CAPS string constants ending in ``_AXIS`` (e.g.
    ``CLIENTS_AXIS = "clients"``), which also resolve ``Name`` references
    at use sites.

Check phase — every axis-name *use* must be a declared axis:

  * string entries of ``PartitionSpec(...)`` / ``P(...)`` (nested tuples
    included — hence ``NamedSharding(mesh, P(...))`` too);
  * the axis argument of collectives (``psum``, ``pmean``, ``all_gather``,
    ...) and any ``axis_name=`` keyword (including ``shard_map``);
  * entries of the repo's ``shard(x, *entries)`` constraint helper.

A typo'd ``"client"`` is a lint error here instead of a trace-time crash on
a real mesh. If the linted tree declares no axes at all, the pass stays
silent (nothing to cross-check against).
"""

from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set, Tuple

from repro.lint.core import (Finding, LintContext, LintPass, Module,
                             call_name, keyword_arg)

_SPEC_CALLS = {"P", "PartitionSpec"}
_COLLECTIVES = {"psum", "pmean", "pmax", "pmin", "all_gather",
                "psum_scatter", "all_to_all", "ppermute", "axis_index",
                "pbroadcast"}


def _str_elems(node: ast.AST) -> List[Tuple[str, int]]:
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Constant) and isinstance(n.value, str):
            out.append((n.value, n.lineno))
    return out


def _tuple_of_strings(node: ast.expr) -> Optional[List[str]]:
    if isinstance(node, (ast.Tuple, ast.List)) and node.elts and all(
            isinstance(e, ast.Constant) and isinstance(e.value, str)
            for e in node.elts):
        return [e.value for e in node.elts]
    return None


class MeshAxesPass(LintPass):
    name = "mesh-axes"
    rules = {
        "mesh-axis-undeclared":
            "axis name used in PartitionSpec/collective/shard() that no "
            "mesh declaration defines",
    }

    def __init__(self):
        self._declared: Set[str] = set()
        self._constants: dict = {}     # NAME -> axis string
        self._pending: Set[str] = set()  # Name refs seen in declarations
        self._finalized = False

    # ---- collect -----------------------------------------------------------

    def collect(self, module: Module, ctx: LintContext) -> None:
        for node in ast.walk(module.tree):
            if isinstance(node, ast.Assign):
                self._collect_assign(node)
            elif isinstance(node, ast.Call):
                name = call_name(node)
                if name and "mesh" in name.split(".")[-1].lower():
                    self._collect_mesh_call(node)

    def _collect_assign(self, node: ast.Assign) -> None:
        pairs: List[Tuple[ast.expr, ast.expr]] = []
        for target in node.targets:
            if isinstance(target, ast.Tuple) \
                    and isinstance(node.value, ast.Tuple) \
                    and len(target.elts) == len(node.value.elts):
                pairs.extend(zip(target.elts, node.value.elts))
            else:
                pairs.append((target, node.value))
        for target, value in pairs:
            if not isinstance(target, ast.Name):
                continue
            if target.id.isupper() and target.id.endswith("_AXIS") \
                    and isinstance(value, ast.Constant) \
                    and isinstance(value.value, str):
                self._declared.add(value.value)
                self._constants[target.id] = value.value
            elif target.id.lower() in ("axes", "axis_names", "mesh_axes"):
                strs = _tuple_of_strings(value)
                if strs:
                    self._declared.update(strs)

    def _collect_mesh_call(self, call: ast.Call) -> None:
        candidates = list(call.args)
        kw = keyword_arg(call, "axis_names")
        if kw is not None:
            candidates.append(kw)
        for arg in candidates:
            if isinstance(arg, (ast.Tuple, ast.List)):
                for e in arg.elts:
                    if isinstance(e, ast.Constant) \
                            and isinstance(e.value, str):
                        self._declared.add(e.value)
                    elif isinstance(e, ast.Name):
                        self._pending.add(e.id)
            elif isinstance(arg, ast.Name):
                self._pending.add(arg.id)

    def _finalize(self) -> None:
        if self._finalized:
            return
        for name in self._pending:
            if name in self._constants:
                self._declared.add(self._constants[name])
        self._finalized = True

    # ---- check -------------------------------------------------------------

    def _resolve(self, node: ast.expr) -> List[Tuple[str, int]]:
        """Axis-name strings (with lines) an axis argument refers to."""
        if isinstance(node, ast.Name) and node.id in self._constants:
            return [(self._constants[node.id], node.lineno)]
        return _str_elems(node)

    def check(self, module: Module, ctx: LintContext) -> Iterable[Finding]:
        self._finalize()
        if not self._declared:
            return
        for node in ast.walk(module.tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node)
            last = name.split(".")[-1] if name else ""
            uses: List[Tuple[str, int, str]] = []
            if last in _SPEC_CALLS:
                uses += [(s, ln, "PartitionSpec entry")
                         for s, ln in _str_elems(node)]
            elif last in _COLLECTIVES:
                axis = keyword_arg(node, "axis_name")
                if axis is None and len(node.args) > 1:
                    axis = node.args[1]
                if axis is not None:
                    uses += [(s, ln, f"{last} axis")
                             for s, ln in self._resolve(axis)]
            elif last in ("shard", "shard_residual"):
                for arg in node.args[1:]:
                    uses += [(s, ln, "shard() entry")
                             for s, ln in self._resolve(arg)]
            axis_kw = keyword_arg(node, "axis_name")
            if axis_kw is not None and last not in _COLLECTIVES:
                uses += [(s, ln, "axis_name=")
                         for s, ln in self._resolve(axis_kw)]
            for axis, line, where in uses:
                if axis not in self._declared:
                    yield self.finding(
                        module, line, "mesh-axis-undeclared",
                        f"{where} {axis!r} matches no declared mesh axis "
                        f"(declared: {sorted(self._declared)}) — typo'd "
                        "axis names only explode at trace time on a real "
                        "mesh")
