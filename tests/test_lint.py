"""fedlint framework + pass tests.

Fixture files live in ``tests/lint_fixtures/`` (non-``test_`` names so
pytest never collects them; they are parsed, never imported). Each bad
fixture marks the expected findings with ``# SEED: <rule>`` comments on
the exact line the finding must anchor to; clean counterparts must lint
to zero findings. Fixtures are loaded under a ``fixtures/`` pseudo-path
so test-path-sensitive rules (``pallas-interpret-hardcoded``) behave as
they do for ``src/``.
"""

import json
import os
import re
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.lint import (available_passes, findings_to_json, jaxprs,
                        rule_catalogue, run_lint, wire_checks)
from repro.lint.core import (Finding, LintPass, Module, is_test_path,
                             make_passes, run_passes)

REPO_ROOT = Path(__file__).resolve().parent.parent
FIXTURES = Path(__file__).resolve().parent / "lint_fixtures"

BAD_FIXTURES = ["host_sync_bad.py", "vjp_bad.py", "mesh_bad.py",
                "pallas_bad.py", "wire_bad.py"]
CLEAN_FIXTURES = ["host_sync_clean.py", "vjp_clean.py", "mesh_clean.py",
                  "pallas_clean.py", "wire_clean.py"]

_SEED_RE = re.compile(r"#\s*SEED:\s*(?P<rules>[a-z0-9,\- ]+)$")


def _load(name: str) -> Module:
    # a fixtures/ pseudo-path so is_test_path() is False, as for src/
    return Module(f"fixtures/{name}", (FIXTURES / name).read_text())


def _seeds(source: str):
    out = []
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _SEED_RE.search(line)
        if m:
            out.extend((r.strip(), lineno)
                       for r in m.group("rules").split(","))
    return sorted(out)


@pytest.fixture
def tmp_manifest(tmp_path, monkeypatch):
    """Point the wire manifest at a scratch file (empty until pinned)."""
    path = tmp_path / "wire_manifest.json"
    monkeypatch.setattr(wire_checks, "MANIFEST_PATH", path)
    return path


# ---------------------------------------------------------------------------
# seeded violations / clean baselines
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", BAD_FIXTURES)
def test_seeded_violations_found_at_marked_lines(name, tmp_manifest):
    mod = _load(name)
    expected = _seeds(mod.source)
    assert expected, f"{name} has no SEED markers"
    got = sorted({(f.rule, f.line)
                  for f in run_passes([mod], make_passes())})
    assert got == expected


@pytest.mark.parametrize("name", CLEAN_FIXTURES)
def test_clean_fixtures_have_zero_findings(name, tmp_manifest):
    if name == "wire_clean.py":
        wire_checks.update_manifest([str(FIXTURES / name)])
    findings = run_passes([_load(name)], make_passes())
    assert findings == []


def test_every_pass_is_exercised_by_a_fixture(tmp_manifest):
    hit = set()
    for name in BAD_FIXTURES:
        for f in run_passes([_load(name)], make_passes()):
            hit.add(f.pass_name)
    for name in ("fleet_loops_bad.py", "wire_decode_bad.py",
                 "obs_events_bad.py"):
        for f in run_passes([_load_federated(name)], make_passes()):
            hit.add(f.pass_name)
    assert hit == set(available_passes())


# ---------------------------------------------------------------------------
# fleet-scale pass: path-gated to repro/federated/ hot paths
# ---------------------------------------------------------------------------

def _load_federated(name: str) -> Module:
    """The fleet-scale pass only fires inside ``repro/federated/`` non-test
    paths, so its fixtures load under a federated pseudo-path instead of
    the standard ``fixtures/`` one."""
    return Module(f"src/repro/federated/{name}",
                  (FIXTURES / name).read_text())


def test_fleet_loop_seeded_violations(tmp_manifest):
    mod = _load_federated("fleet_loops_bad.py")
    expected = _seeds(mod.source)
    assert expected, "fleet_loops_bad.py has no SEED markers"
    got = sorted({(f.rule, f.line)
                  for f in run_passes([mod], make_passes())})
    assert got == expected


def test_fleet_loop_clean_fixture(tmp_manifest):
    """Vectorized idiom, cohort-sized loops and a reviewed suppression all
    lint clean under the hot-path pseudo-path."""
    findings = run_passes([_load_federated("fleet_loops_clean.py")],
                          make_passes())
    assert findings == []


def test_fleet_loop_pass_is_path_gated(tmp_manifest):
    src = (FIXTURES / "fleet_loops_bad.py").read_text()
    # outside repro/federated/: not a hot path, nothing fires
    assert run_passes([Module("fixtures/fleet_loops_bad.py", src)],
                      make_passes(["fleet-scale"])) == []
    # federated test files are exempt too
    assert run_passes([Module("src/repro/federated/test_x.py", src)],
                      make_passes(["fleet-scale"])) == []


# ---------------------------------------------------------------------------
# obs-events pass: emitted names vs the schema registry
# ---------------------------------------------------------------------------

def test_obs_event_seeded_violations(tmp_manifest):
    """An unregistered literal name and a computed name both fire at the
    marked lines."""
    mod = _load_federated("obs_events_bad.py")
    expected = _seeds(mod.source)
    assert expected, "obs_events_bad.py has no SEED markers"
    got = sorted({(f.rule, f.line)
                  for f in run_passes([mod], make_passes())})
    assert got == expected


def test_obs_event_clean_fixture(tmp_manifest):
    """Registered names, a reviewed dynamic-name suppression, and a
    non-obs call with an event-looking string all lint clean."""
    findings = run_passes([_load_federated("obs_events_clean.py")],
                          make_passes())
    assert findings == []


def test_obs_event_pass_is_path_gated(tmp_manifest):
    src = (FIXTURES / "obs_events_bad.py").read_text()
    # outside repro/federated/: emitters there are the obs layer's own
    assert run_passes([Module("fixtures/obs_events_bad.py", src)],
                      make_passes(["obs-events"])) == []
    assert run_passes([Module("src/repro/federated/test_x.py", src)],
                      make_passes(["obs-events"])) == []


def test_every_registered_federated_emission_is_in_schema():
    """The live check the CI gate runs: every obs.event in the shipped
    federated layer names a registered event."""
    mods = []
    fed = REPO_ROOT / "src" / "repro" / "federated"
    for path in sorted(fed.glob("*.py")):
        mods.append(Module(str(path), path.read_text()))
    assert run_passes(mods, make_passes(["obs-events"])) == []


# ---------------------------------------------------------------------------
# wire-decode pass: unguarded decodes in hot paths
# ---------------------------------------------------------------------------

def test_wire_decode_seeded_violations(tmp_manifest):
    """Bare decode, wrong-hierarchy except, and a decode inside a handler
    body (outside its own try) all fire at the marked lines."""
    mod = _load_federated("wire_decode_bad.py")
    expected = _seeds(mod.source)
    assert expected, "wire_decode_bad.py has no SEED markers"
    got = sorted({(f.rule, f.line)
                  for f in run_passes([mod], make_passes())})
    assert got == expected


def test_wire_decode_clean_fixture(tmp_manifest):
    """Typed-hierarchy catches (incl. tuple form and the ValueError base)
    and a reviewed loopback suppression all lint clean."""
    findings = run_passes([_load_federated("wire_decode_clean.py")],
                          make_passes())
    assert findings == []


def test_wire_decode_pass_is_path_gated(tmp_manifest):
    src = (FIXTURES / "wire_decode_bad.py").read_text()
    # outside repro/federated/: not a hot path, nothing fires
    assert run_passes([Module("fixtures/wire_decode_bad.py", src)],
                      make_passes(["wire-decode"])) == []
    # federated test files are exempt
    assert run_passes([Module("src/repro/federated/test_x.py", src)],
                      make_passes(["wire-decode"])) == []
    # the codec module itself is exempt: it *produces* the hierarchy
    assert run_passes([Module("src/repro/federated/wire.py", src)],
                      make_passes(["wire-decode"])) == []


def test_wire_decode_repo_tree_is_clean():
    """Every decode call in the real federated package is guarded (or
    carries a reviewed loopback suppression)."""
    findings = run_lint([str(REPO_ROOT / "src" / "repro" / "federated")],
                        ["wire-decode"])
    assert findings == [], [f.format() for f in findings]


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

def test_line_suppression_silences_the_rule(tmp_manifest):
    findings = run_passes([_load("host_sync_suppressed.py")], make_passes())
    assert findings == []


def test_without_suppression_the_same_code_is_flagged(tmp_manifest):
    src = (FIXTURES / "host_sync_suppressed.py").read_text()
    stripped = src.replace("  # fedlint: disable=host-sync-in-jit", "")
    assert stripped != src
    findings = run_passes([Module("fixtures/host_sync_suppressed.py",
                                  stripped)], make_passes())
    assert [f.rule for f in findings] == ["host-sync-in-jit"]


def test_file_suppression_and_disable_all(tmp_manifest):
    src = (FIXTURES / "host_sync_suppressed.py").read_text()
    for comment in ("# fedlint: disable-file=host-sync-in-jit",
                    "# fedlint: disable-file=all"):
        body = src.replace("# fedlint: disable=host-sync-in-jit", "") \
            + f"\n{comment}\n"
        findings = run_passes([Module("fixtures/x.py", body)], make_passes())
        assert findings == [], comment


# ---------------------------------------------------------------------------
# framework: registry, findings, JSON schema
# ---------------------------------------------------------------------------

def test_registry_lists_the_eight_passes():
    assert available_passes() == ("custom-vjp", "fleet-scale", "host-sync",
                                  "mesh-axes", "obs-events", "pallas",
                                  "wire-decode", "wire-format")


def test_unknown_pass_selection_fails_loudly():
    with pytest.raises(ValueError, match="registered"):
        make_passes(["no-such-pass"])


def test_rule_catalogue_covers_every_pass():
    cat = rule_catalogue()
    assert set(cat) == set(available_passes())
    assert all(rules for rules in cat.values())


def test_unregistered_rule_emission_is_an_error():
    class P(LintPass):
        name = "p"
        rules = {"known": "desc"}
    mod = Module("x.py", "pass\n")
    with pytest.raises(ValueError, match="unregistered"):
        P().finding(mod, 1, "unknown", "msg")


def test_finding_severity_is_validated():
    with pytest.raises(ValueError):
        Finding(path="x.py", line=1, rule="r", message="m", severity="fatal")


def test_is_test_path():
    assert is_test_path("tests/test_foo.py")
    assert is_test_path("pkg/test_bar.py")
    assert not is_test_path("src/repro/kernels/ops.py")


def test_json_schema_is_stable(tmp_manifest):
    findings = run_passes([_load("vjp_bad.py")], make_passes())
    doc = json.loads(findings_to_json(findings))
    assert doc["schema_version"] == 1
    assert set(doc) == {"schema_version", "findings", "counts", "total"}
    assert doc["total"] == len(findings) == len(doc["findings"])
    for entry in doc["findings"]:
        assert set(entry) == {"path", "line", "rule", "severity", "pass",
                              "message"}
    assert sum(doc["counts"].values()) == doc["total"]


def test_select_runs_only_that_pass(tmp_manifest):
    findings = run_passes([_load("vjp_bad.py")], make_passes(["host-sync"]))
    assert findings == []
    findings = run_passes([_load("vjp_bad.py")], make_passes(["custom-vjp"]))
    assert findings and all(f.pass_name == "custom-vjp" for f in findings)


# ---------------------------------------------------------------------------
# wire manifest: version-stale detection
# ---------------------------------------------------------------------------

def test_wire_body_edit_without_version_bump_is_stale(tmp_manifest):
    src = (FIXTURES / "wire_clean.py").read_text()
    wire_checks.update_manifest([str(FIXTURES / "wire_clean.py")])
    edited = src.replace("len(payload)) + payload",
                         "len(payload) + 1) + payload")
    assert edited != src
    findings = run_passes([Module("fixtures/wire_clean.py", edited)],
                          make_passes(["wire-format"]))
    stale = [f for f in findings if f.rule == "wire-version-stale"]
    assert len(stale) == 2
    assert all("bump the version" in f.message for f in stale)


def test_wire_docstring_edit_does_not_change_the_hash(tmp_manifest):
    src = (FIXTURES / "wire_clean.py").read_text()
    wire_checks.update_manifest([str(FIXTURES / "wire_clean.py")])
    edited = src.replace(
        "def encode_dense(payload):\n",
        'def encode_dense(payload):\n    """v1 wire header."""\n')
    assert edited != src
    findings = run_passes([Module("fixtures/wire_clean.py", edited)],
                          make_passes(["wire-format"]))
    assert findings == []


def test_repo_wire_manifest_is_current():
    """The checked-in manifest must match the checked-in encoders — a
    drifted manifest means someone edited wire.py without refreshing."""
    findings = run_lint([str(REPO_ROOT / "src" / "repro" / "federated"
                             / "wire.py")], ["wire-format"])
    assert findings == [], [f.format() for f in findings]


# ---------------------------------------------------------------------------
# jaxpr-level helpers
# ---------------------------------------------------------------------------

def test_collective_axis_names_recurses_into_subjaxprs():
    def f(x):
        return jax.jit(lambda y: jax.lax.psum(y, "data"))(x)
    axes = jaxprs.collective_axis_names(f, jnp.ones(4),
                                        axis_env=[("data", 2)])
    assert axes == {"data"}


def test_undeclared_collective_axes_clean():
    def f(x):
        return x * 2.0
    assert jaxprs.undeclared_collective_axes(f, ["data"], jnp.ones(3)) \
        == set()


def test_host_callback_primitives_detected():
    def g(x):
        jax.debug.print("x = {x}", x=x)
        return x
    assert "debug_callback" in jaxprs.host_callback_primitives(g, jnp.ones(3))
    def h(x):
        return x + 1.0
    assert jaxprs.host_callback_primitives(h, jnp.ones(3)) == []


def test_integer_cotangents_follow_float0_contract():
    def good(x, i):
        return x * 2.0
    assert jaxprs.integer_cotangent_violations(
        good, jnp.ones(3), jnp.arange(3)) == []


def test_integer_cotangent_check_propagates_bwd_structure_errors():
    @jax.custom_vjp
    def broken(x, i):
        return x

    def broken_fwd(x, i):
        return broken(x, i), None

    def broken_bwd(res, ct):
        return (ct,)   # missing the integer primal's cotangent slot

    broken.defvjp(broken_fwd, broken_bwd)
    with pytest.raises(TypeError):
        jaxprs.integer_cotangent_violations(broken, jnp.ones(3),
                                            jnp.arange(3))


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=str(REPO_ROOT / "src"))
    return subprocess.run([sys.executable, "-m", "repro.lint", *args],
                          capture_output=True, text=True, env=env,
                          cwd=REPO_ROOT)


def test_cli_exit_codes_and_output():
    bad = str(FIXTURES / "vjp_bad.py")
    r = _run_cli(bad, "--select", "custom-vjp")
    assert r.returncode == 1
    assert "[vjp-missing-defvjp]" in r.stdout

    r = _run_cli(str(FIXTURES / "vjp_clean.py"), "--select", "custom-vjp")
    assert r.returncode == 0

    r = _run_cli(bad, "--select", "custom-vjp", "--json")
    assert r.returncode == 1
    doc = json.loads(r.stdout)
    assert doc["schema_version"] == 1 and doc["total"] > 0


def test_cli_usage_errors():
    assert _run_cli("no/such/path.py").returncode == 2
    assert _run_cli("--select", "bogus", ".").returncode == 2


def test_cli_list_rules():
    r = _run_cli("--list-rules")
    assert r.returncode == 0
    for pass_name in available_passes():
        assert pass_name in r.stdout


# ---------------------------------------------------------------------------
# raw-timing-in-hot-path: ad-hoc timers/print in federated/core hot paths
# ---------------------------------------------------------------------------

_HOT_TIMING_SRC = """\
import time

def round_loop():
    t0 = time.perf_counter()
    print("round took", time.perf_counter() - t0)
"""


def _timing_findings(path, src=_HOT_TIMING_SRC, tmp=None):
    return [f for f in run_passes([Module(path, src)], make_passes())
            if f.rule == "raw-timing-in-hot-path"]


def test_raw_timing_flagged_in_hot_paths(tmp_manifest):
    findings = _timing_findings("src/repro/federated/runtime.py")
    # two perf_counter calls + one print
    assert sorted(f.line for f in findings) == [4, 5, 5]
    assert any("repro.obs.span" in f.message for f in findings)
    assert any("repro.obs.event" in f.message for f in findings)
    assert _timing_findings("src/repro/core/kmeans.py")


def test_raw_timing_exempt_paths(tmp_manifest):
    for path in ("src/repro/obs/spans.py",          # obs implements timing
                 "benchmarks/common.py",            # benchmarks time freely
                 "tests/test_something.py",         # test code
                 "src/repro/federated/test_util.py",
                 "src/repro/models/paper_models.py"):
        assert _timing_findings(path) == [], path


def test_raw_timing_line_suppression(tmp_manifest):
    src = _HOT_TIMING_SRC.replace(
        "t0 = time.perf_counter()",
        "t0 = time.perf_counter()"
        "  # fedlint: disable=raw-timing-in-hot-path")
    findings = _timing_findings("src/repro/federated/runtime.py", src)
    assert sorted(f.line for f in findings) == [5, 5]  # only the bare line
