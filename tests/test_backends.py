"""Quantizer backend registry tests (tentpole of the dispatch-layer PR).

jnp vs pallas(-interpret) parity on codes / z̃ / residual, VJP parity under
the gradient correction, "auto" resolution, and the single-K-means-run
invariant of ``quantize_with_correction``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kmeans as km
from repro.core.correction import quantize_with_correction
from repro.core.quantizer import PQConfig, quantize


def _cfg(backend, **kw):
    base = dict(num_subvectors=4, num_clusters=8, kmeans_iters=6)
    base.update(kw)
    return PQConfig(backend=backend, **base)


# N=60 -> group rows M = 4*60/R: not a multiple of the pallas block (padded);
# N=128 -> M power of two (unpadded for block_n<=512 divisors)
@pytest.mark.parametrize("n", [60, 128])
@pytest.mark.parametrize("r", [1, 2])
def test_jnp_pallas_parity_codes_zt_residual(n, r):
    z = jax.random.normal(jax.random.PRNGKey(n + r), (n, 32))
    qj = quantize(z, _cfg("jnp", num_groups=r))
    qp = quantize(z, _cfg("pallas", num_groups=r))
    np.testing.assert_array_equal(np.asarray(qj.codes), np.asarray(qp.codes))
    np.testing.assert_allclose(qj.dequantized, qp.dequantized,
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(qj.residual, qp.residual, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(qj.distortion), float(qp.distortion),
                               rtol=1e-6)


def test_vjp_parity_between_backends():
    """quantize_with_correction's VJP under pallas == jnp to fp32 tolerance."""
    z = jax.random.normal(jax.random.PRNGKey(3), (48, 16))
    g_in = jax.random.normal(jax.random.PRNGKey(4), (48, 16))
    lam = 0.37
    outs = {}
    for backend in ("jnp", "pallas"):
        zt, vjp = jax.vjp(
            lambda x: quantize_with_correction(x, lam, _cfg(backend)), z)
        (g_out,) = vjp(g_in)
        outs[backend] = (zt, g_out)
        # eq. (5) must hold within each backend too
        np.testing.assert_allclose(g_out, g_in + lam * (z - zt),
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(outs["jnp"][0], outs["pallas"][0],
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(outs["jnp"][1], outs["pallas"][1],
                               rtol=1e-5, atol=1e-6)


def test_auto_resolution_and_registry():
    resolved = km.resolve_backend("auto")
    expected = "pallas" if jax.default_backend() == "tpu" else "jnp"
    assert resolved == expected
    assert km.resolve_backend("jnp") == "jnp"
    assert set(km.available_backends()) >= {"jnp", "pallas", "auto"}
    with pytest.raises(ValueError):
        km.get_backend("nope")
    # auto-backend quantize runs end to end
    z = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    qb = quantize(z, _cfg("auto", num_subvectors=2, num_clusters=4))
    assert qb.dequantized.shape == z.shape


def test_register_custom_backend():
    probe = {"assign": 0}
    jnp_backend = km.get_backend("jnp")

    def counting_assign(x, c):
        probe["assign"] += 1
        return jnp_backend.assign(x, c)

    km.register_backend(km.Backend("probe", counting_assign,
                                   jnp_backend.encode))
    try:
        x = jax.random.normal(jax.random.PRNGKey(1), (64, 8))
        r = km.kmeans(x, 4, 3, backend="probe")
        # fori_loop/scan trace the body once regardless of iteration count
        assert probe["assign"] >= 1
        r_jnp = km.kmeans(x, 4, 3, backend="jnp")
        np.testing.assert_allclose(r.centroids, r_jnp.centroids,
                                   rtol=1e-6, atol=1e-6)
    finally:
        km._REGISTRY.pop("probe", None)


def test_correction_runs_kmeans_exactly_once(monkeypatch):
    """Forward+backward of quantize_with_correction traces K-means ONCE:
    the residual is emitted by the fused encode and reused by the VJP."""
    calls = {"lloyd": 0, "encode": 0}
    real_lloyd = km.lloyd
    real_get = km.get_backend

    def counting_lloyd(*a, **kw):
        calls["lloyd"] += 1
        return real_lloyd(*a, **kw)

    def counting_get(name="auto"):
        b = real_get(name)

        def encode(x, c, chunk):
            calls["encode"] += 1
            return b.encode(x, c, chunk)

        return km.Backend(b.name, b.assign, encode)

    monkeypatch.setattr(km, "lloyd", counting_lloyd)
    monkeypatch.setattr(km, "get_backend", counting_get)

    z = jax.random.normal(jax.random.PRNGKey(7), (32, 16))
    cfg = _cfg("jnp")
    out, grad = jax.value_and_grad(
        lambda x: jnp.sum(quantize_with_correction(x, 0.5, cfg) ** 2))(z)
    assert np.isfinite(float(out)) and np.isfinite(np.asarray(grad)).all()
    # one vmapped Lloyd + one vmapped encode across fwd AND bwd (R=1 group)
    assert calls["lloyd"] == 1
    assert calls["encode"] == 1


def test_exact_reconstruction_zero_residual_both_backends():
    """Identical rows must produce a bitwise-zero residual on every backend
    (the FedLite->SplitFed equivalence of tests/test_fedlite.py)."""
    row = jax.random.normal(jax.random.PRNGKey(9), (1, 64))
    z = jnp.tile(row, (8, 1))
    for backend in ("jnp", "pallas"):
        qb = quantize(z, _cfg(backend, num_subvectors=1, num_clusters=2))
        assert float(jnp.abs(qb.residual).max()) == 0.0
        np.testing.assert_array_equal(np.asarray(qb.dequantized),
                                      np.asarray(z))


def test_pq_backend_threaded_from_arch_config():
    from repro.configs.base import get_arch
    from repro.launch.specs import default_pq
    cfg = get_arch("llama3_8b", smoke=True)
    pq = default_pq(cfg)
    assert pq.backend == cfg.pq_backend == "auto"
    pq2 = default_pq(dataclasses.replace(cfg, pq_backend="jnp"))
    assert pq2.backend == "jnp"
