"""Shared test fixtures. NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the single real CPU device; only
launch/dryrun.py fakes 512 devices (per its module docstring)."""

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
