"""Shared test fixtures. NOTE: no XLA_FLAGS device-count override here —
smoke tests and benches must see the single real CPU device; only
launch/dryrun.py fakes 512 devices (per its module docstring).

Rank promotion is an error under test (set REPRO_RANK_PROMOTION=warn or
allow to relax locally): silent broadcast of mismatched ranks is how
per-client weight vectors end up averaged against full matrices."""

import os

import jax
import pytest

jax.config.update("jax_numpy_rank_promotion",
                  os.environ.get("REPRO_RANK_PROMOTION", "raise"))


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
