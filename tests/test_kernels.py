"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

Kernels run in interpret mode on this CPU container (TPU is the target).
``hypothesis`` is a dev-only dependency (requirements-dev.txt); without it
the property tests skip instead of aborting collection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import ops, ref


def _mk(n, d, l, dtype, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n, d)).astype(dtype)
    c = jax.random.normal(k2, (l, d)).astype(dtype)
    return x, c


SHAPES = [(8, 8, 2), (64, 8, 16), (100, 16, 7), (512, 8, 32), (513, 4, 3),
          (256, 64, 960)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("n,d,l", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_kmeans_assign_matches_ref(n, d, l, dtype):
    x, c = _mk(n, d, l, dtype)
    lmask = jnp.ones(l, jnp.float32)
    codes_k, dist_k = ops.kmeans_assign(x, c, interpret=True)
    codes_r, dist_r = ref.kmeans_assign_ref(x, c, lmask)
    # argmin ties can differ legitimately: compare achieved distances
    np.testing.assert_allclose(dist_k, dist_r, rtol=2e-2, atol=1e-3)
    agree = np.mean(np.array(codes_k) == np.array(codes_r))
    assert agree > 0.99


@pytest.mark.parametrize("n,d,l", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_pq_quantize_matches_ref(n, d, l, dtype):
    x, c = _mk(n, d, l, dtype, seed=3)
    lmask = jnp.ones(l, jnp.float32)
    zt_k, resid_k, codes_k = ops.pq_quantize(x, c, interpret=True)
    zt_r, resid_r, codes_r = ref.pq_quantize_ref(x, c, lmask)
    assert zt_k.dtype == x.dtype
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(zt_k, np.float32),
                               np.asarray(zt_r, np.float32),
                               rtol=tol, atol=tol)
    np.testing.assert_allclose(resid_k, resid_r, rtol=tol, atol=tol)


def test_fused_residual_identity():
    """z̃ + residual == x (up to fp32 rounding of the subtract/re-add)."""
    x, c = _mk(128, 8, 4, jnp.float32, seed=9)
    zt, resid, _ = ops.pq_quantize(x, c, interpret=True)
    np.testing.assert_allclose(zt + resid, x, rtol=1e-5, atol=1e-6)


if HAVE_HYPOTHESIS:
    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 130), d=st.sampled_from([2, 4, 8, 16]),
           l=st.integers(1, 40), seed=st.integers(0, 100))
    def test_property_assign_is_true_argmin(n, d, l, seed):
        """Property: the kernel's assignment achieves the minimal distance."""
        x, c = _mk(n, d, l, jnp.float32, seed=seed)
        codes, dist = ops.kmeans_assign(x, c, interpret=True)
        xf, cf = np.asarray(x), np.asarray(c)
        d2 = ((xf[:, None] - cf[None]) ** 2).sum(-1)
        np.testing.assert_allclose(dist, d2.min(-1), rtol=1e-4, atol=1e-4)
        picked = d2[np.arange(n), np.asarray(codes)]
        np.testing.assert_allclose(picked, d2.min(-1), rtol=1e-4, atol=1e-4)
else:
    @pytest.mark.skip(reason="hypothesis not installed (requirements-dev.txt)")
    def test_property_assign_is_true_argmin():
        pass


def test_kernel_as_kmeans_backend():
    """Full K-means with backend="pallas" == the jnp backend."""
    from repro.core import kmeans as km
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 16))
    r_jnp = km.kmeans(x, 8, 6, backend="jnp")
    r_kern = km.kmeans(x, 8, 6, backend="pallas")
    np.testing.assert_allclose(r_jnp.centroids, r_kern.centroids,
                               rtol=1e-4, atol=1e-5)
    assert float(jnp.mean((r_jnp.codes == r_kern.codes) * 1.0)) > 0.99
