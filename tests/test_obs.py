"""Observability tests: span recording + trace-safety, exporter
round-trips, Trace windowed-reduction edge cases, the run inspector, and
the transfer-counting guarantee (instrumentation adds zero device→host
syncs to a training run)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated import (DEFAULT_CHAOS, DropSlowestK, FederatedTrainer,
                             lognormal_fleet)
from repro.federated.trace import RoundRecord, Trace
from repro.models.paper_models import FemnistCNN
from repro.obs.inspect import format_report, main, percentile, summarize
from repro.optim import sgd


@pytest.fixture(autouse=True)
def _no_leaked_recorder():
    """Every test starts and ends without a module-level recorder."""
    obs.shutdown()
    yield
    obs.shutdown()


def _record(round, t0, t1, loss=None, up=100, down=200, dropped=(),
            ledger=None):
    return RoundRecord(
        round=round, t_start=t0, t_end=t1, participants=(0, 1),
        dropped=tuple(dropped), uplink_bytes=up, downlink_bytes=down,
        metrics={} if loss is None else {"loss": loss},
        ledger=ledger or {})


# ---------------------------------------------------------------------------
# Trace windowed reductions: empty / single-round / extreme-q edge cases
# ---------------------------------------------------------------------------

def test_empty_trace_reductions_are_defined():
    t = Trace()
    assert t.duration_percentile(50.0) == 0.0
    assert t.duration_percentile(0.0) == 0.0
    assert t.tail_ratio() == 1.0
    assert t.loss_slope() == 0.0
    assert t.drop_rate() == 0.0
    assert t.bytes_per_round() == 0.0
    assert t.ledger_totals() == {}
    s = t.summary()
    assert s["rounds"] == 0
    assert s["simulated_seconds"] == 0.0
    assert s["mean_staleness"] == 0.0


def test_single_round_trace_reductions():
    t = Trace(records=[_record(0, 0.0, 2.5, loss=1.0)])
    # every percentile of one sample is that sample, including q in {0, 1}
    for q in (0.0, 1.0, 50.0, 100.0):
        assert t.duration_percentile(q) == pytest.approx(2.5)
    assert t.tail_ratio() == pytest.approx(1.0)
    assert t.loss_slope() == 0.0          # needs >= 2 loss points
    assert t.summary()["rounds"] == 1


def test_duration_percentile_extreme_q():
    durations = [1.0, 2.0, 4.0, 8.0]
    t = Trace(records=[_record(i, 0.0, d) for i, d in enumerate(durations)])
    assert t.duration_percentile(0.0) == pytest.approx(1.0)    # the min
    assert t.duration_percentile(100.0) == pytest.approx(8.0)  # the max
    # q is clamped, not wrapped, outside [0, 100]
    assert t.duration_percentile(-5.0) == pytest.approx(1.0)
    assert t.duration_percentile(250.0) == pytest.approx(8.0)
    # q=1 (of 100) interpolates just above the minimum
    assert 1.0 <= t.duration_percentile(1.0) < 2.0


def test_loss_slope_and_targets():
    t = Trace(records=[_record(i, float(i), float(i + 1), loss=4.0 - i)
                       for i in range(4)])
    assert t.loss_slope() == pytest.approx(-1.0)
    assert t.time_to_target(2.0) == pytest.approx(3.0)
    assert t.bytes_to_target(2.0) == 300          # 3 rounds of uplink
    assert t.time_to_target(-10.0) is None


def test_ledger_totals_accumulate_across_rounds():
    t = Trace(records=[
        _record(0, 0.0, 1.0, ledger={"uplink/pq": 10, "downlink/dense": 50}),
        _record(1, 1.0, 2.0, ledger={"uplink/pq": 15}),
        _record(2, 2.0, 3.0),                     # legacy: empty ledger
    ])
    assert t.ledger_totals() == {"uplink/pq": 25, "downlink/dense": 50}


# ---------------------------------------------------------------------------
# spans: recording, trace-safety, the instrument wrapper
# ---------------------------------------------------------------------------

def test_span_is_noop_without_recorder():
    with obs.span("nothing", cat="test") as sp:
        sp.set(key="value")                       # must not raise
    assert obs.current() is None
    assert not obs.enabled()


def test_span_records_host_lane():
    rec = obs.configure(run="t", meta={"k": "v"})
    with obs.span("work", cat="test", n=3) as sp:
        sp.set(extra=1)
    obs.virtual_span("simwork", 1.0, 3.5, cat="test", round=0)
    obs.event("mark", cat="test", lane="virtual", t=2.0, why="x")
    spans = [e for e in rec.events if e["type"] == "span"]
    assert {(s["lane"], s["name"]) for s in spans} == \
        {("host", "work"), ("virtual", "simwork")}
    host = next(s for s in spans if s["lane"] == "host")
    assert host["t1"] >= host["t0"] >= 0.0
    assert host["args"] == {"n": 3, "extra": 1}
    virt = next(s for s in spans if s["lane"] == "virtual")
    assert (virt["t0"], virt["t1"]) == (1.0, 3.5)
    ev = next(e for e in rec.events if e["type"] == "event")
    assert (ev["name"], ev["t"], ev["lane"]) == ("mark", 2.0, "virtual")
    # the run_start meta event carries the configured meta
    assert rec.events[0]["args"] == {"k": "v", "run": "t"}


def test_span_suppressed_inside_jit_tracing():
    rec = obs.configure(run="t")

    @jax.jit
    def f(x):
        with obs.span("should-not-record", cat="test"):
            pass
        obs.event("should-not-record-either", cat="test")
        return x * 2

    f(jnp.ones(3)).block_until_ready()
    names = {e["name"] for e in rec.events}
    assert "should-not-record" not in names
    assert "should-not-record-either" not in names


def test_instrument_wrapper_records_per_call():
    @obs.instrument("my.fn", cat="test")
    def fn(a, b=1):
        return a + b

    assert fn(2, b=3) == 5                        # no recorder: plain call
    rec = obs.configure(run="t")
    assert fn(2) == 3
    spans = [e for e in rec.events if e["type"] == "span"]
    assert [s["name"] for s in spans] == ["my.fn"]
    assert fn.__name__ == "fn"                    # functools.wraps preserved


# ---------------------------------------------------------------------------
# exporters: JSONL append-only round-trip + Perfetto structure
# ---------------------------------------------------------------------------

def test_jsonl_round_trip_and_incremental_append(tmp_path):
    rec = obs.configure(run="t")
    with obs.span("a", cat="test"):
        pass
    path = tmp_path / "run.jsonl"
    n1 = rec.write_jsonl(path)
    assert n1 == 2                                # run_start meta + 1 span
    assert rec.write_jsonl(path) == 0             # nothing new: no rewrite
    with obs.span("b", cat="test"):
        pass
    assert rec.write_jsonl(path) == 1             # only the new event
    events = obs.read_jsonl(path)
    assert [e.get("name") for e in events] == ["run_start", "a", "b"]
    assert events == json.loads(
        "[" + ",".join(p for p in path.read_text().splitlines()) + "]")


def test_jsonable_handles_arrays_and_fallbacks():
    assert obs.jsonable(jnp.arange(3)) == [0, 1, 2]
    assert obs.jsonable(np.float32(1.5)) == 1.5
    assert obs.jsonable({"k": (1, 2)}) == {"k": [1, 2]}
    assert obs.jsonable(object()).startswith("<object")


def test_perfetto_two_lanes_and_phases(tmp_path):
    rec = obs.configure(run="t")
    with obs.span("hostwork", cat="exec"):
        pass
    obs.virtual_span("round 0", 0.0, 1.0, cat="rounds")
    obs.event("cut", cat="sched", lane="virtual", t=0.5)
    path = tmp_path / "trace.perfetto.json"
    rec.write_perfetto(path)
    doc = json.loads(path.read_text())
    evs = doc["traceEvents"]
    lanes = {e["args"]["name"] for e in evs if e.get("name") == "process_name"}
    assert lanes == {"host wall-clock", "scheduler virtual-clock"}
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"hostwork", "round 0"}
    assert xs["hostwork"]["pid"] != xs["round 0"]["pid"]   # distinct lanes
    assert xs["round 0"]["dur"] == pytest.approx(1e6)      # µs
    assert all(e["dur"] >= 0.0 for e in evs if e["ph"] == "X")
    inst = [e for e in evs if e["ph"] == "i"]
    assert {e["name"] for e in inst} >= {"cut"}
    assert all(e["s"] == "t" for e in inst)


# ---------------------------------------------------------------------------
# in-jit metrics + the single-flush buffer
# ---------------------------------------------------------------------------

def test_metric_helpers_inside_jit():
    @jax.jit
    def step(x):
        return {"n": obs.counter(jnp.ones_like(x)),
                "mean": obs.gauge(x.mean()),
                "hist": obs.histogram(x, bins=4, lo=0.0, hi=1.0)}

    buf = obs.MetricsBuffer()
    buf.record(step(jnp.array([0.1, 0.3, 0.6, 0.9])))
    buf.record(step(jnp.array([-1.0, 2.0])))      # out-of-range clamps
    assert len(buf) == 2
    out = buf.flush()
    assert len(buf) == 0
    assert out[0]["n"] == 4.0 and isinstance(out[0]["n"], float)
    assert out[0]["hist"] == [1.0, 1.0, 1.0, 1.0]
    assert out[1]["hist"] == [1.0, 0.0, 0.0, 1.0]  # edge buckets
    assert buf.flush() == []                       # idempotent when drained


def _small_trainer():
    data = make_federated_image_data(num_clients=8, seed=0)
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4)
    return FederatedTrainer(model, sgd(0.03), data, cohort=4, client_batch=8,
                            fleet=lognormal_fleet(8, seed=0),
                            policy=DropSlowestK(1))


def _count_transfers(monkeypatch, configured):
    calls = {"n": 0}
    real = jax.device_get

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(jax, "device_get", counting)
    try:
        if configured:
            obs.configure(run="count")
        tr = _small_trainer()
        tr.run(2, jax.random.PRNGKey(0))
    finally:
        monkeypatch.setattr(jax, "device_get", real)
        rec = obs.shutdown()
    if configured:
        assert any(e["type"] == "round" for e in rec.events)
    return calls["n"]


def test_instrumentation_adds_no_device_transfers(monkeypatch):
    """The sync-free contract: a fully instrumented run performs no more
    blocking device→host transfers than an uninstrumented one, and the
    whole run's metrics arrive through a single flush."""
    plain = _count_transfers(monkeypatch, configured=False)
    instrumented = _count_transfers(monkeypatch, configured=True)
    assert instrumented <= plain
    assert plain >= 1                              # the run's single flush


# ---------------------------------------------------------------------------
# log_trace + the run inspector
# ---------------------------------------------------------------------------

def _synthetic_run_events():
    rec = obs.configure(run="synthetic", meta={"suite": "unit"})
    trace = Trace(records=[
        _record(0, 0.0, 1.0, loss=4.0, up=1000, down=4000,
                ledger={"uplink/pq": 1000, "downlink/dense": 4000}),
        _record(1, 1.0, 3.0, loss=2.0, up=1000, down=4000, dropped=(7,),
                ledger={"uplink/pq": 1000, "downlink/dense": 4000}),
    ], meta={"uplink_compressor": "pq"})
    obs.log_trace(trace)
    obs.shutdown()
    return rec.events


def test_log_trace_emits_round_and_run_events():
    events = _synthetic_run_events()
    rounds = [e for e in events if e["type"] == "round"]
    assert [r["args"]["round"] for r in rounds] == [0, 1]
    assert all(r["lane"] == "virtual" for r in rounds)
    assert rounds[1]["args"]["dropped"] == 1
    runs = [e for e in events if e["type"] == "run"]
    assert len(runs) == 1
    assert runs[0]["args"]["meta"]["uplink_compressor"] == "pq"


def test_log_trace_is_noop_without_recorder():
    obs.log_trace(Trace(records=[_record(0, 0.0, 1.0)]))  # must not raise


def test_summarize_rounds_ledger_and_target():
    events = _synthetic_run_events()
    s = summarize(events, target=2.5)
    assert len(s["rounds"]) == 2
    assert s["ledger"] == {"uplink/pq": 2000, "downlink/dense": 8000}
    assert s["uplink_bytes"] == 2000
    assert s["simulated_seconds"] == pytest.approx(3.0)
    assert s["round_duration_p50_s"] == pytest.approx(1.5)
    assert s["target"]["reached_round"] == 1
    assert s["target"]["time_to_target_s"] == pytest.approx(3.0)
    assert s["target"]["bytes_to_target"] == 10000    # both directions
    missed = summarize(events, target=0.1)
    assert missed["target"]["reached_round"] is None
    report = format_report(s)
    assert "byte ledger" in report and "uplink/pq" in report
    assert "reached at round 1" in report


def test_summarize_empty_and_percentile_edges():
    s = summarize([])
    assert s["events"] == 0 and s["rounds"] == []
    assert s["tail_ratio"] == 1.0
    assert percentile([], 50) == 0.0
    assert percentile([3.0], 0) == 3.0
    assert percentile([3.0], 100) == 3.0
    assert percentile([1.0, 3.0], 200) == 3.0         # clamped
    format_report(s)                                  # renders without rounds


def test_inspector_cli(tmp_path, capsys):
    rec = obs.configure(run="cli")
    obs.log_trace(Trace(records=[_record(0, 0.0, 1.0, loss=1.0)]))
    obs.shutdown()
    path = tmp_path / "run.jsonl"
    rec.write_jsonl(path)
    assert main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "run: cli" in out and "round" in out
    assert main([str(path), "--json", "--target", "2.0"]) == 0
    doc = json.loads(capsys.readouterr().out)
    assert doc["target"]["reached_round"] == 0
    assert main([str(tmp_path / "missing.jsonl")]) == 2


# ---------------------------------------------------------------------------
# contribution flight recorder: frames, exemplars, flow links, inspector
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_run(tmp_path_factory):
    """One chaos training run recorded end-to-end, shared by the flight /
    SLO / inspector tests below (the run itself is the expensive part).
    DEFAULT_CHAOS on this seed yields >= 1 quarantine and >= 1 crash
    retry, so the exemplar stream exercises every lifecycle edge."""
    obs.shutdown()
    rec = obs.configure(run="chaos", meta={"suite": "unit"})
    data = make_federated_image_data(num_clients=8, seed=0)
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4)
    tr = FederatedTrainer(
        model, sgd(0.03), data, cohort=4, client_batch=8, quantize=True,
        seed=0, fleet=lognormal_fleet(8, seed=0), fault_plan=DEFAULT_CHAOS,
        slo_monitor=obs.HealthMonitor(rules=(
            obs.SloRule("impossible", "rounds", ">=", 1000),)))
    tr.run(6, jax.random.PRNGKey(0))
    obs.shutdown()
    path = tmp_path_factory.mktemp("chaos") / "run.jsonl"
    rec.write_jsonl(path)
    ppath = path.parent / "run.perfetto.json"
    rec.write_perfetto(ppath)
    return {"events": rec.events, "trace": tr.last_trace,
            "path": path, "ppath": ppath}


def _by_name(events, name):
    return [e for e in events if e.get("name") == name]


def test_flight_frame_json_round_trip(chaos_run):
    frames = chaos_run["trace"].flights
    assert len(frames) == 6
    for frame in frames:
        doc = frame.to_json()
        json.dumps(doc)                       # plain-JSON serializable
        clone = obs.FlightFrame.from_json(doc)
        assert clone == frame                 # NaN-aware column equality
        assert clone is not frame and len(clone) == len(frame)


def test_chaos_run_emits_rollups_and_exemplars(chaos_run):
    events = chaos_run["events"]
    rollups = _by_name(events, "flight.rollup")
    assert [r["args"]["round"] for r in rollups] == list(range(6))
    for r in rollups:
        # O(cohort) rollup: state histogram covers the whole cohort
        assert sum(r["args"]["states"].values()) == r["args"]["flights"] == 4
    # reservoir exemplars: every lifecycle stage event carries a flight_id
    for name in ("flight.sampled", "flight.placed", "flight.uplink",
                 "flight.outcome", "flight.server"):
        stage = _by_name(events, name)
        assert len(stage) == 24               # 4-exemplar cohorts x 6 rounds
        assert all(e["args"]["flight_id"].startswith("r") for e in stage)
    # the chaos plan actually bit on this seed, and the recorder saw it
    assert _by_name(events, "flight.quarantined")
    assert _by_name(events, "flight.retry")


def test_flight_exemplar_lifecycle_is_causally_ordered(chaos_run):
    events = chaos_run["events"]
    quarantined = _by_name(events, "flight.quarantined")[0]
    fid = quarantined["args"]["flight_id"]
    stages = [e["name"] for e in events
              if e.get("args", {}).get("flight_id") == fid]
    assert stages[0] == "flight.sampled"
    assert stages.index("flight.placed") < stages.index("flight.uplink")
    assert stages.index("flight.quarantined") < stages.index("flight.outcome")
    assert stages[-1] == "flight.server"      # server-side screening span


def test_perfetto_flow_events_link_flight_spans(chaos_run):
    doc = json.loads(chaos_run["ppath"].read_text())
    flows = [e for e in doc["traceEvents"] if e.get("cat") == "flights"
             and e["ph"] in ("s", "t", "f")]
    assert flows
    by_id = {}
    for e in flows:
        by_id.setdefault(e["id"], []).append(e)
    for fid, chain in by_id.items():
        chain.sort(key=lambda e: e["ts"])
        phases = [e["ph"] for e in chain]
        # each flight is one s -> t* -> f arrow chain across the lanes
        assert phases[0] == "s" and phases[-1] == "f"
        assert set(phases[1:-1]) <= {"t"}
        assert chain[-1].get("bp") == "e"     # bind the arrow to span end


def test_inspector_reconstructs_a_flight(chaos_run, capsys):
    events = chaos_run["events"]
    fid = _by_name(events, "flight.quarantined")[0]["args"]["flight_id"]
    assert main([str(chaos_run["path"]), "--flight", fid]) == 0
    out = capsys.readouterr().out
    assert fid in out and "quarantined" in out
    # a miss lists known exemplars instead, and exits nonzero
    assert main([str(chaos_run["path"]), "--flight", "r9-c9-s9"]) == 1
    assert "r9-c9-s9" in capsys.readouterr().out


def test_inspector_health_and_slo_flags(chaos_run, capsys):
    path = str(chaos_run["path"])
    assert main([path, "--health"]) == 0
    out = capsys.readouterr().out
    assert "PASS" in out and "corruption-detected" in out
    # extra rule that must fail: still a report (exit 0), graded FAIL
    assert main([path, "--slo", "rounds>=100"]) == 0
    assert "FAIL" in capsys.readouterr().out
    assert main([path, "--slo", "not a rule"]) == 2


def test_slo_monitor_emits_violation_events(chaos_run):
    violations = _by_name(chaos_run["events"], "slo_violation")
    assert len(violations) == 1               # the impossible rounds>=1000
    args = violations[0]["args"]
    assert args["rule"] == "impossible" and args["signal"] == "rounds"
    assert args["value"] == 6.0 and args["op"] == ">="


# ---------------------------------------------------------------------------
# SLO rules + health monitor unit surface
# ---------------------------------------------------------------------------

def test_parse_rule_round_trips_the_cli_syntax():
    r = obs.parse_rule("drop_rate<=0.3")
    assert (r.signal, r.op, r.threshold, r.window) == \
        ("drop_rate", "<=", 0.3, None)
    r = obs.parse_rule("rounds >= 5 @ 20")
    assert (r.signal, r.op, r.threshold, r.window) == ("rounds", ">=", 5.0, 20)
    with pytest.raises(ValueError):
        obs.parse_rule("drop_rate == 0.3")


def test_health_monitor_grades_a_trace():
    trace = Trace(records=[_record(0, 0.0, 1.0), _record(1, 1.0, 2.0)])
    results = obs.HealthMonitor().evaluate(trace)
    assert [r.rule.name for r in results] == \
        [r.name for r in obs.DEFAULT_SLOS]
    assert all(r.ok for r in results)         # clean run passes defaults
    tight = obs.HealthMonitor(rules=(
        obs.SloRule("floor", "rounds", ">=", 3),))
    bad = tight.evaluate(trace)[0]
    assert not bad.ok and bad.value == 2.0
    assert bad.describe().startswith("FAIL")
    # an unknown signal is "not measurable": no violation, but rendered
    # as value=n/a so the gap is visible in the report
    missing = obs.HealthMonitor(rules=(
        obs.SloRule("ghost", "no_such_signal", "<=", 1.0),))
    res = missing.evaluate(trace)[0]
    assert res.value is None and res.ok
    assert "n/a" in res.describe()


def test_health_monitor_check_without_recorder_is_quiet():
    trace = Trace(records=[_record(0, 0.0, 1.0)])
    results = obs.HealthMonitor(rules=(
        obs.SloRule("floor", "rounds", ">=", 3),)).check(trace)
    assert results and not results[0].ok      # graded, nothing emitted


# ---------------------------------------------------------------------------
# tolerant JSONL reads (mid-write-killed logs)
# ---------------------------------------------------------------------------

def test_tolerant_reader_recovers_a_truncated_tail(tmp_path):
    rec = obs.configure(run="t")
    with obs.span("a", cat="test"):
        pass
    obs.shutdown()
    path = tmp_path / "run.jsonl"
    rec.write_jsonl(path)
    with open(path, "a") as fh:               # process killed mid-write
        fh.write('{"type": "event", "name": "half')
    with pytest.raises(json.JSONDecodeError):
        obs.read_jsonl(path)                  # strict reader refuses
    events, skipped = obs.read_jsonl_tolerant(path)
    assert skipped == 1
    assert [e.get("name") for e in events] == ["run_start", "a"]


def test_tolerant_reader_skips_non_object_lines(tmp_path):
    path = tmp_path / "weird.jsonl"
    path.write_text('{"type": "event", "name": "ok"}\n'
                    '[1, 2, 3]\n'
                    '\n'
                    'not json at all\n')
    events, skipped = obs.read_jsonl_tolerant(path)
    assert [e["name"] for e in events] == ["ok"]
    assert skipped == 2                       # array + garbage; blank is free


def test_inspector_warns_but_renders_truncated_logs(tmp_path, capsys):
    rec = obs.configure(run="cut")
    obs.log_trace(Trace(records=[_record(0, 0.0, 1.0, loss=1.0)]))
    obs.shutdown()
    path = tmp_path / "run.jsonl"
    rec.write_jsonl(path)
    with open(path, "a") as fh:
        fh.write('{"truncat')
    assert main([str(path)]) == 0
    captured = capsys.readouterr()
    assert "run: cut" in captured.out
    assert "skipped 1 unparseable line" in captured.err
