"""Scheduler/network/runtime tests: determinism, bitwise ideal-profile
reproduction, participation policies, and weighted client sampling."""

import jax
import numpy as np
import pytest

from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated import (AsyncBuffer, ClientProfile, Deadline,
                             DropSlowestK, FederatedTrainer, FullSync,
                             Scheduler, lognormal_fleet, mobile_fleet,
                             sample_clients, uniform_fleet, weighted_average)
from repro.models.paper_models import FemnistCNN
from repro.optim import sgd


def _trainer(policy=None, fleet=None, seed=0, quantize=True):
    data = make_federated_image_data(num_clients=8, seed=0)
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2) \
        if quantize else None
    model = FemnistCNN(pq=pq, lam=1e-4)
    return FederatedTrainer(model, sgd(0.03), data, cohort=4, client_batch=8,
                            quantize=quantize, seed=seed,
                            fleet=fleet, policy=policy)


# ---------------------------------------------------------------------------
# bitwise preservation of the pre-subsystem behavior
# ---------------------------------------------------------------------------

def test_ideal_profile_reproduces_manual_loop_bitwise():
    """run() under the default (ideal, full-sync) scheduler == the plain
    round()-by-round() synchronous loop, bit for bit."""
    key = jax.random.PRNGKey(0)
    tr = _trainer()
    state, hist = tr.run(5, key)

    tr2 = _trainer()
    st = tr2.init_state(key)
    losses = []
    for t in range(5):
        st, m = tr2.round(st, jax.random.fold_in(key, t + 1))
        losses.append(float(m["loss"]))

    assert [h["loss"] for h in hist] == losses
    for a, b in zip(jax.tree.leaves(state.params), jax.tree.leaves(st.params)):
        np.testing.assert_array_equal(a, b)


def test_ideal_trace_is_free_of_network_cost():
    tr = _trainer()
    _, hist = tr.run(3, jax.random.PRNGKey(0))
    trace = tr.last_trace
    # ideal clients: each round costs exactly the reference compute time
    assert trace.simulated_seconds == pytest.approx(3 * tr.client_step_seconds)
    assert trace.total_dropped == 0
    assert all(len(r.participants) == 4 for r in trace)
    assert trace.total_uplink_bytes > 0  # measured, not analytic


# ---------------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------------

def _stub_run(fleet, policy, seed=0, rounds=6, cohort=4, cohort_ids=None):
    """Drive the scheduler with a stub executor (no model math).

    ``cohort_ids=None`` draws a fixed random cohort stream (deterministic
    across calls); an explicit list pins every round's cohort."""
    rng = np.random.default_rng(123)
    cohorts = [rng.choice(len(fleet), cohort, replace=False)
               for _ in range(rounds + 64)]
    sample = (lambda rd: cohort_ids) if cohort_ids is not None \
        else (lambda rd: cohorts[rd])
    sched = Scheduler(fleet=fleet, policy=policy, seed=seed)
    return sched.run(rounds, sample_cohort=sample,
                     uplink_bytes=1000, downlink_bytes=4000,
                     execute=lambda i, parts, w: {"loss": float(len(parts))})


@pytest.mark.parametrize("policy", [
    FullSync(), DropSlowestK(1), Deadline(8.0), AsyncBuffer(3)])
def test_same_seed_same_profiles_identical_trace(policy):
    fleet = mobile_fleet(8, flaky_fraction=0.5, seed=7)
    t1 = _stub_run(fleet, policy)
    t2 = _stub_run(fleet, policy)
    assert len(t1) == len(t2)
    for a, b in zip(t1, t2):
        assert a == b  # RoundRecord dataclass equality: every field


def test_different_seed_changes_dropout_draws():
    fleet = uniform_fleet(8, ClientProfile(dropout_prob=0.5))
    t1 = _stub_run(fleet, FullSync(), seed=0)
    t2 = _stub_run(fleet, FullSync(), seed=1)
    assert [r.dropped for r in t1] != [r.dropped for r in t2]


# ---------------------------------------------------------------------------
# policy semantics
# ---------------------------------------------------------------------------

def _two_speed_fleet(n=8, slow_every=2):
    """Even clients fast, odd clients 10x slower."""
    return [ClientProfile(compute_multiplier=10.0 if i % slow_every else 1.0)
            for i in range(n)]


def test_full_sync_waits_for_slowest():
    trace = _stub_run(_two_speed_fleet(), FullSync(), rounds=3,
                      cohort_ids=[0, 1, 2, 3])
    for r in trace:
        assert r.duration == pytest.approx(10.0)  # gated by slow clients
        assert len(r.participants) == 4


def test_drop_slowest_k_cuts_stragglers():
    trace = _stub_run(_two_speed_fleet(), DropSlowestK(2), rounds=3,
                      cohort_ids=[0, 1, 2, 3])
    for r in trace:
        assert len(r.participants) == 2
        assert len(r.dropped) == 2
        # slow clients (odd ids) never survive a 2-fast/2-slow cohort
        assert all(c % 2 == 0 for c in r.participants)
        assert r.duration == pytest.approx(1.0)
        # cut uploads still crossed the wire: all 4 count against the link
        assert r.uplink_bytes == 4 * 1000


def test_deadline_drops_late_uploads():
    trace = _stub_run(_two_speed_fleet(), Deadline(5.0), rounds=3,
                      cohort_ids=[0, 1, 2, 3])
    for r in trace:
        assert r.duration == pytest.approx(5.0)  # closed at the budget
        assert all(c % 2 == 0 for c in r.participants)


def test_async_buffer_flushes_and_tracks_staleness():
    fleet = _two_speed_fleet()
    trace = _stub_run(fleet, AsyncBuffer(2), rounds=6,
                      cohort_ids=[0, 1, 2, 3])
    assert len(trace) == 6
    for r in trace:
        assert len(r.participants) == 2
        assert len(r.staleness) == 2
    # fast clients lap the slow ones -> some contribution must be stale
    assert trace.mean_staleness > 0


def test_async_all_dropout_terminates():
    """A fleet that always drops out must not spin the event loop forever:
    the guard stops the run with an empty trace."""
    fleet = uniform_fleet(4, ClientProfile(dropout_prob=1.0))
    trace = _stub_run(fleet, AsyncBuffer(2), rounds=3, cohort_ids=[0, 1, 2, 3])
    assert len(trace) == 0


def test_async_rotates_through_population():
    """Async redispatch draws fresh cohorts: with a round-robin cohort
    stream, clients beyond the initial in-flight set must participate."""
    fleet = uniform_fleet(8)
    rng = np.random.default_rng(5)
    sched = Scheduler(fleet=fleet, policy=AsyncBuffer(2), seed=0)
    trace = sched.run(12, sample_cohort=lambda w: rng.choice(8, 4, replace=False),
                      uplink_bytes=10, downlink_bytes=10,
                      execute=lambda i, parts, w: {"loss": 0.0})
    seen = {c for r in trace for c in r.participants}
    assert len(seen) > 4


def test_dropout_only_round_executes_no_step():
    fleet = uniform_fleet(8, ClientProfile(dropout_prob=1.0))
    calls = []
    sched = Scheduler(fleet=fleet, policy=FullSync(), seed=0)
    trace = sched.run(2, sample_cohort=lambda rd: [0, 1],
                      uplink_bytes=10, downlink_bytes=10,
                      execute=lambda *a: calls.append(a) or {})
    assert not calls
    assert all(r.participants == () and len(r.dropped) == 2 for r in trace)
    assert trace.total_uplink_bytes == 0


def test_heterogeneous_fleet_still_trains():
    """End-to-end: lognormal fleet + drop-slowest policy reduces the loss
    and records nonzero network time."""
    fleet = lognormal_fleet(8, median_uplink_bps=2e6, seed=3)
    tr = _trainer(policy=DropSlowestK(1), fleet=fleet)
    state, hist = tr.run(6, jax.random.PRNGKey(0))
    losses = [h["loss"] for h in hist if "loss" in h]
    assert losses and np.isfinite(losses).all()
    assert losses[-1] < losses[0]
    trace = tr.last_trace
    assert trace.total_dropped >= len(trace)  # one cut per round minimum
    assert trace.simulated_seconds > 6 * tr.client_step_seconds


def test_async_trainer_run_smoke():
    tr = _trainer(policy=AsyncBuffer(2))
    state, hist = tr.run(4, jax.random.PRNGKey(0))
    assert len(hist) == 4
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert all(len(r.staleness) == 2 for r in tr.last_trace)


# ---------------------------------------------------------------------------
# weighted client sampling (FedAvg baseline)
# ---------------------------------------------------------------------------

def test_weighted_sampling_tracks_client_weights():
    rng = np.random.default_rng(0)
    num_clients, cohort = 16, 4
    w = np.arange(1, num_clients + 1, dtype=np.float64)
    w /= w.sum()
    counts = np.zeros(num_clients)
    draws = 3000
    for _ in range(draws):
        ids = sample_clients(rng, num_clients, cohort, weights=w)
        assert len(ids) == cohort and len(set(ids.tolist())) == cohort
        counts[ids] += 1
    freq = counts / draws
    # inclusion frequency increases with p_i and beats uniform for the
    # heaviest clients (exact inclusion probs are not proportional under
    # without-replacement sampling, but monotonicity must hold)
    assert freq[-1] > freq[0]
    assert np.corrcoef(w, freq)[0, 1] > 0.95


def test_weighted_average_renormalizes_under_partial_participation():
    """Aggregation weights of a PARTIAL cohort must be renormalized to sum
    to one — the p_i of unsampled clients cannot leak into the average."""
    trees = [{"a": np.full((2,), 1.0)}, {"a": np.full((2,), 3.0)}]
    # raw p_i sum to 0.5: a partial cohort of a larger population
    out = weighted_average(trees, [0.2, 0.3])
    np.testing.assert_allclose(out["a"], 0.4 * 1.0 + 0.6 * 3.0)


def test_uniform_sampling_unchanged():
    rng = np.random.default_rng(0)
    ids = sample_clients(rng, 10, 4)
    assert len(ids) == 4 and len(set(ids.tolist())) == 4
    assert sample_clients(rng, 3, 8).shape == (3,)


def test_sample_clients_rejects_bad_weights():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        sample_clients(rng, 4, 2, weights=np.array([1.0, -1.0, 1.0, 1.0]))
    with pytest.raises(ValueError):
        sample_clients(rng, 4, 2, weights=np.zeros(3))
