"""Optimizer unit tests: each minimizes a quadratic; states stay finite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (adafactor, adagrad, adam, get_optimizer, momentum,
                         sgd, warmup_cosine, cosine_decay)

OPTS = {
    "sgd": sgd(0.1), "momentum": momentum(0.05), "adam": adam(0.1),
    "adagrad": adagrad(0.5), "adafactor": adafactor(0.3),
}


@pytest.mark.parametrize("name", list(OPTS))
def test_minimizes_quadratic(name):
    opt = OPTS[name]
    params = {"w": jnp.array([3.0, -2.0]), "m": jnp.ones((4, 8)) * 2}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["m"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, upd)
    assert float(loss(params)) < 0.05 * l0, name


def test_adam_matches_reference_first_step():
    opt = adam(0.1)
    p = {"w": jnp.array([1.0])}
    s = opt.init(p)
    g = {"w": jnp.array([0.5])}
    upd, s = opt.update(g, s, p)
    # bias-corrected first step == -lr * sign-ish: m̂=g, v̂=g² -> -lr*g/(|g|+eps)
    np.testing.assert_allclose(upd["w"], -0.1 * 0.5 / (0.5 + 1e-8), rtol=1e-5)


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    p = {"w": jnp.ones((64, 32)), "b": jnp.ones((16,))}
    s = opt.init(p)
    assert s["v"]["w"]["row"].shape == (64,)
    assert s["v"]["w"]["col"].shape == (32,)
    assert s["v"]["b"]["full"].shape == (16,)
    # factored state is ~(n+m)/(n·m) of the dense second moment
    dense = 64 * 32
    fact = 64 + 32
    assert fact < dense / 20


def test_bf16_params_stay_bf16():
    opt = adam(0.01)
    p = {"w": jnp.ones((8, 8), jnp.bfloat16)}
    s = opt.init(p)
    g = {"w": jnp.ones((8, 8), jnp.bfloat16) * 0.1}
    upd, s = opt.update(g, s, p)
    assert upd["w"].dtype == jnp.bfloat16
    assert s["m"]["w"].dtype == jnp.float32  # fp32 accumulators


def test_schedules():
    ws = warmup_cosine(1.0, 10, 110)
    assert float(ws(0)) == pytest.approx(0.1)
    assert float(ws(9)) == pytest.approx(1.0)
    assert float(ws(109)) < 0.2
    cd = cosine_decay(2.0, 100, final_frac=0.5)
    assert float(cd(0)) == pytest.approx(2.0)
    assert float(cd(100)) == pytest.approx(1.0)
