"""Sharding rules + context tests (single CPU device: no-op behavior; spec
construction is pure and testable without a multi-device mesh)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import (AxisType, current_mesh, make_mesh, param_specs,
                            set_mesh, shard, spec_for_param, use_mesh)
from repro.sharding.ctx import filter_spec, shard_residual


def _fake_mesh(data=4, model=2):
    # a mesh OBJECT for spec computation only (no constraint application)
    devs = np.array(jax.devices() * (data * model))[:data * model]
    return Mesh(devs.reshape(data, model), ("data", "model"))


MESH = _fake_mesh()


def test_spec_rules_basic():
    assert spec_for_param("client/layers/p0/mixer/wq", (1, 512, 256), MESH) \
        == P(None, "data", "model")
    assert spec_for_param("server/layers/p0/mixer/wo", (1, 256, 512), MESH) \
        == P(None, "model", "data")
    assert spec_for_param("client/tok_embed", (50304, 512), MESH) \
        == P(None, "data")
    assert spec_for_param("server/head", (512, 50304), MESH) \
        == P("data", "model")
    assert spec_for_param("server/layers/p0/ln1/scale", (1, 512), MESH) \
        == P()  # replicated (P() == all-None)


def test_expert_rule_divisibility():
    # E=4 divides model=2 -> expert parallel
    assert spec_for_param("s/layers/p0/ffn/we_up", (1, 4, 256, 512), MESH) \
        == P(None, "model", "data", None)
    # E=3 does not -> Megatron TP inside each expert (+ FSDP over data)
    assert spec_for_param("s/layers/p0/ffn/we_up", (1, 3, 256, 512), MESH) \
        == P(None, None, "data", "model")
    assert spec_for_param("s/layers/p0/ffn/we_down", (1, 3, 512, 256), MESH) \
        == P(None, None, "model", "data")


def test_divisibility_guard_drops_axis():
    # dim 6 not divisible by data=4 -> replicated on that dim
    spec = spec_for_param("x/head", (6, 50304), MESH)
    assert spec == P(None, "model")


def test_filter_spec_drops_missing_axes():
    assert filter_spec(P(("pod", "data"), None), MESH) == P("data", None)
    assert filter_spec(P("pod", "model"), MESH) == P(None, "model")


def test_param_specs_walks_opt_state_shapes():
    tree = {"m": {"client": {"layers": {"p0": {"mixer": {
        "wq": jnp.zeros((2, 512, 256))}}}}},
        "step": jnp.zeros(())}
    specs = param_specs(tree, MESH)
    assert specs["m"]["client"]["layers"]["p0"]["mixer"]["wq"] == \
        P(None, "data", "model")
    assert specs["step"] == P()


def test_shard_noop_without_mesh():
    assert current_mesh() is None
    x = jnp.ones((4, 4))
    y = shard(x, "data", None)
    np.testing.assert_array_equal(x, y)
    z = shard_residual(jnp.ones((2, 3, 4)))
    assert z.shape == (2, 3, 4)


def test_use_mesh_restores():
    real = make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    with use_mesh(real) as m:
        assert current_mesh() is real
    assert current_mesh() is None


def test_inference_spec_folds_data_into_tp():
    from repro.sharding.rules import inference_spec
    # column weight (512, 256): data on dim0 folds into dim1's TP group
    sp = inference_spec(P("data", "model"), (512, 256), MESH)
    assert sp == P(None, ("model", "data"))
    # row weight
    sp = inference_spec(P("model", "data"), (512, 256), MESH)
    assert sp == P(("model", "data"), None)
    # non-divisible merged axis -> unchanged
    sp = inference_spec(P("data", "model"), (512, 6), MESH)
    assert sp == P("data", "model")
    # no model dim -> unchanged (e.g. embeddings)
    sp = inference_spec(P(None, "data"), (50304, 512), MESH)
    assert sp == P(None, "data")
