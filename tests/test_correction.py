"""Gradient-correction tests (paper §4.2 / Appendix A)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.correction import quantize_with_correction
from repro.core.quantizer import PQConfig, quantize


CFG = PQConfig(num_subvectors=4, num_clusters=4, kmeans_iters=8)


def test_forward_equals_plain_quantize():
    z = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    zt = quantize_with_correction(z, 0.1, CFG)
    np.testing.assert_allclose(zt, quantize(z, CFG).dequantized, rtol=1e-6)


@pytest.mark.parametrize("lam", [0.0, 1e-4, 0.5])
def test_vjp_is_eq5(lam):
    """cotangent(z) == g + λ(z − z̃) exactly (paper eq. 5)."""
    z = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
    g_in = jax.random.normal(jax.random.PRNGKey(2), (16, 16))
    zt, vjp = jax.vjp(lambda x: quantize_with_correction(x, lam, CFG), z)
    (g_out,) = vjp(g_in)
    expected = g_in + lam * (z - zt)
    np.testing.assert_allclose(g_out, expected, rtol=1e-5, atol=1e-6)


def test_surrogate_loss_equivalence():
    """Appendix A: the corrected gradient is the gradient of
    ‖z−ẑ‖² + (λ/2)‖z−z̃‖² with ẑ = z − g/2 and z̃ fixed."""
    lam = 0.3
    z = jax.random.normal(jax.random.PRNGKey(3), (8, 16))
    g = jax.random.normal(jax.random.PRNGKey(4), (8, 16))
    zt = quantize(z, CFG).dequantized
    z_hat = jax.lax.stop_gradient(z - g / 2)
    zt_f = jax.lax.stop_gradient(zt)

    def surrogate(x):
        return (jnp.sum((x - z_hat) ** 2) + lam / 2 * jnp.sum((x - zt_f) ** 2))

    grad_s = jax.grad(surrogate)(z)
    # eq. (5) cotangent with incoming g
    _, vjp = jax.vjp(lambda x: quantize_with_correction(x, lam, CFG), z)
    (g_corrected,) = vjp(g)
    np.testing.assert_allclose(grad_s, g_corrected, rtol=1e-4, atol=1e-5)


def test_lambda_zero_is_straight_through():
    z = jax.random.normal(jax.random.PRNGKey(5), (16, 16))
    g = jnp.ones_like(z)
    _, vjp = jax.vjp(lambda x: quantize_with_correction(x, 0.0, CFG), z)
    (g_out,) = vjp(g)
    np.testing.assert_allclose(g_out, g)


def test_correction_pulls_toward_lower_quantization_error():
    """Gradient descent on 0 loss with λ>0 reduces ‖z−z̃‖ (the regularizer
    effect of eq. 6): moving z along -λ(z−z̃) shrinks the residual."""
    z = jax.random.normal(jax.random.PRNGKey(6), (64, 16)) * 3
    lam = 1.0
    zt = quantize(z, CFG).dequantized
    err0 = float(jnp.mean(jnp.sum((z - zt) ** 2, -1)))
    _, vjp = jax.vjp(lambda x: quantize_with_correction(x, lam, CFG), z)
    (g,) = vjp(jnp.zeros_like(z))       # pure correction term
    z2 = z - 0.5 * g
    zt2 = quantize(z2, CFG).dequantized
    err1 = float(jnp.mean(jnp.sum((z2 - zt2) ** 2, -1)))
    assert err1 < err0


def test_downlink_quantization():
    """Beyond-paper: identity forward, PQ-compressed cotangent backward."""
    from repro.core.correction import quantize_downlink
    from repro.core.quantizer import quantize
    z = jax.random.normal(jax.random.PRNGKey(8), (32, 16))
    g_in = jax.random.normal(jax.random.PRNGKey(9), (32, 16))
    out, vjp = jax.vjp(lambda x: quantize_downlink(x, CFG), z)
    np.testing.assert_array_equal(out, z)          # identity forward
    (g_out,) = vjp(g_in)
    expected = quantize(g_in, CFG).dequantized
    np.testing.assert_allclose(g_out, expected, rtol=1e-5, atol=1e-6)
    # the compressed gradient is close to (but not equal to) the raw one
    assert not np.allclose(g_out, g_in)
    rel = np.linalg.norm(g_out - g_in) / np.linalg.norm(g_in)
    assert rel < 0.9


def test_downlink_in_model_trains():
    from repro.configs.base import get_arch
    from repro.core.quantizer import PQConfig
    from repro.models.transformer import TransformerLM
    from repro.data.synthetic import make_lm_batch
    cfg = get_arch("llama3_8b", smoke=True)
    pq = PQConfig(num_subvectors=cfg.d_model // 8, num_clusters=4,
                  kmeans_iters=3)
    model = TransformerLM(cfg, pq=pq, lam=1e-4, downlink_pq=pq)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_lm_batch(jax.random.PRNGKey(1), 2, 32, cfg.vocab_size)
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for leaf in jax.tree.leaves(g):
        assert np.isfinite(np.asarray(leaf)).all()
    # client grads nonzero through the doubly-compressed link
    gn = float(jnp.linalg.norm(g["client"]["layers"]["p0"]["mixer"]["wq"]))
    assert gn > 0
