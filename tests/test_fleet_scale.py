"""Fleet-scale scheduler core: backend parity, `ClientFleet`, topology.

The vectorized scheduler backend exists to make 10^6-client fleets cheap;
its contract is that it is *bitwise indistinguishable* from the heapq
reference event loop. These tests sweep fleet x policy x cohort asserting
record-for-record trace equality (with and without a two-tier topology),
pin the policy edge semantics in BOTH backends, and unit-test the
struct-of-arrays `ClientFleet` and the `TwoTierTopology` helpers.
"""

import math

import numpy as np
import pytest

from repro.federated import (AsyncBuffer, ClientFleet, ClientProfile,
                             Deadline, DropSlowestK, FaultPlan, FullSync,
                             Scheduler, TwoTierTopology, lognormal_fleet,
                             mobile_fleet, uniform_fleet, validate_fleet)
from repro.federated.network import IDEAL, transfer_seconds
from repro.federated.topology import kmeans_points, simulate_locations


def _run(fleet, policy, backend, rounds=5, cohort=4, topology=None,
         seed=0, wire_kinds=None, uplink=1000, downlink=4000, faults=None):
    """Drive one scheduler run with a stub execute and a cohort stream
    that is deterministic across calls (so backends see identical rounds)."""
    rng = np.random.default_rng(99)
    cohorts = [rng.choice(len(fleet), cohort, replace=False)
               for _ in range(rounds + 64)]
    sched = Scheduler(fleet=fleet, policy=policy, seed=seed, backend=backend,
                      topology=topology, faults=faults)
    return sched.run(rounds, sample_cohort=lambda rd: cohorts[rd],
                     uplink_bytes=uplink, downlink_bytes=downlink,
                     execute=lambda i, parts, w: {"loss": float(len(parts))},
                     wire_kinds=wire_kinds)


def _fleets():
    return {
        "uniform": uniform_fleet(12, ClientProfile(dropout_prob=0.2)),
        "lognormal": lognormal_fleet(12, median_uplink_bps=2e6,
                                     dropout_prob=0.1, seed=3),
        "mobile": mobile_fleet(12, flaky_fraction=0.5, seed=7),
    }


def _policies():
    return {
        "full_sync": FullSync(),
        "drop_slowest_3": DropSlowestK(3),
        "deadline_2.5": Deadline(2.5),
        "async_4": AsyncBuffer(4),
    }


# ---------------------------------------------------------------------------
# bitwise backend parity (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fleet_name", sorted(_fleets()))
@pytest.mark.parametrize("policy_name", sorted(_policies()))
@pytest.mark.parametrize("cohort", [4, 9])
def test_backend_traces_bitwise_identical(fleet_name, policy_name, cohort):
    """heapq vs vector: every RoundRecord field equal — including float
    times, which must be the same IEEE doubles, not approximately so."""
    fleet = _fleets()[fleet_name]
    policy = _policies()[policy_name]
    ref = _run(fleet, policy, "heapq", cohort=cohort,
               wire_kinds=("pq", "dense"))
    vec = _run(fleet, policy, "vector", cohort=cohort,
               wire_kinds=("pq", "dense"))
    assert len(ref) == len(vec)
    for a, b in zip(ref, vec):
        assert a == b  # dataclass equality: exact floats, tuples, ledger


@pytest.mark.parametrize("policy_name", sorted(_policies()))
def test_backend_parity_holds_under_two_tier_topology(policy_name):
    fleet = _fleets()["mobile"]
    policy = _policies()[policy_name]
    traces = []
    for backend in ("heapq", "vector"):
        topo = TwoTierTopology(num_edges=4, seed=0)
        traces.append(_run(fleet, policy, backend, topology=topo,
                           wire_kinds=("pq", "dense")))
    assert traces[0].records == traces[1].records


@pytest.mark.parametrize("fleet_name", sorted(_fleets()))
@pytest.mark.parametrize("policy_name", sorted(_policies()))
def test_backend_parity_holds_under_fault_schedule(fleet_name, policy_name):
    """The bitwise-parity contract extends to armed fault plans: crash
    retries, reorder jitter and the per-round fault counters must be
    identical across backends for every fleet x policy cell."""
    fleet = _fleets()[fleet_name]
    policy = _policies()[policy_name]
    plan = FaultPlan(seed=13, crash_rate=0.25, max_retries=1,
                     reorder_rate=0.4, reorder_max_s=1.0)
    ref = _run(fleet, policy, "heapq", wire_kinds=("pq", "dense"),
               faults=plan)
    vec = _run(fleet, policy, "vector", wire_kinds=("pq", "dense"),
               faults=plan)
    assert ref.records == vec.records
    assert ref.fault_totals() == vec.fault_totals()


@pytest.mark.parametrize("policy_name", sorted(_policies()))
@pytest.mark.parametrize("chaos", [False, True], ids=["clean", "chaos"])
def test_flight_frames_identical_across_backends(policy_name, chaos):
    """The contribution flight recorder inherits the parity contract:
    both backends must record the exact same FlightFrame columns —
    ids, dispatch/arrival times, retry counts, placement, terminal
    states — for every policy, with and without an armed fault plan."""
    fleet = _fleets()["mobile"]
    policy = _policies()[policy_name]
    plan = FaultPlan(seed=13, crash_rate=0.25, max_retries=1,
                     reorder_rate=0.4, reorder_max_s=1.0) if chaos else None
    traces = []
    for backend in ("heapq", "vector"):
        topo = TwoTierTopology(num_edges=4, seed=0)
        traces.append(_run(fleet, policy, backend, topology=topo,
                           wire_kinds=("pq", "dense"), faults=plan))
    ref, vec = traces
    assert len(ref.flights) == len(vec.flights) > 0
    assert ref.flights == vec.flights   # column-for-column, NaN-aware
    # the recorded flight ids form exactly one flight per sampled
    # contribution per round — stable across backends by construction
    for frame in ref.flights:
        ids = [frame.flight_id(i) for i in range(len(frame))]
        assert len(set(ids)) == len(ids)


def test_flights_can_be_disabled_for_benchmarks():
    from repro.obs import flight as flightlib
    fleet = _fleets()["uniform"]
    prev = flightlib.set_flights(False)
    try:
        trace = _run(fleet, FullSync(), "vector", rounds=2)
    finally:
        flightlib.set_flights(prev)
    assert trace.flights == []
    assert flightlib.flights_enabled()


def test_auto_backend_matches_explicit_vector():
    fleet = _fleets()["lognormal"]
    auto = _run(fleet, DropSlowestK(2), "auto")
    vec = _run(fleet, DropSlowestK(2), "vector")
    assert auto.records == vec.records


# ---------------------------------------------------------------------------
# policy edge semantics, pinned in BOTH backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_drop_slowest_overprovisioned_k_keeps_one_survivor(backend):
    """k >= cohort size degrades to "fastest client wins", never zero:
    keep = max(len(arrivals) - k, 1)."""
    fleet = uniform_fleet(8)  # no dropout: all 4 uploads arrive
    trace = _run(fleet, DropSlowestK(10), backend, cohort=4)
    for r in trace:
        assert len(r.participants) == 1
        assert len(r.dropped) == 3


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_drop_slowest_empty_arrivals_round_is_instant(backend):
    """The whole cohort dropping out leaves nothing to wait for: zero
    survivors and t_end == t_start."""
    fleet = uniform_fleet(8, ClientProfile(dropout_prob=1.0))
    trace = _run(fleet, DropSlowestK(1), backend, rounds=3)
    for r in trace:
        assert r.participants == ()
        assert len(r.dropped) == 4
        assert r.t_end == r.t_start
        assert r.uplink_bytes == 0


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_deadline_empty_arrivals_waits_out_the_budget(backend):
    """With no arrivals the server still waits out its budget before
    deciding nobody came: t_end == t_start + deadline."""
    fleet = uniform_fleet(8, ClientProfile(dropout_prob=1.0))
    trace = _run(fleet, Deadline(2.5), backend, rounds=3)
    for r in trace:
        assert r.participants == ()
        assert r.duration == pytest.approx(2.5)


def test_explicit_vector_backend_rejects_split_only_policy():
    class SplitOnly:
        name = "split_only"

        def split(self, arrivals, t_start):
            return list(arrivals), [], t_start

    with pytest.raises(ValueError, match="split_vector"):
        _run(uniform_fleet(4), SplitOnly(), "vector", rounds=1)
    # auto falls back to the reference loop and still runs
    trace = _run(uniform_fleet(4), SplitOnly(), "auto", rounds=2)
    assert len(trace) == 2


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="backend"):
        _run(uniform_fleet(4), FullSync(), "simd", rounds=1)


# ---------------------------------------------------------------------------
# per-tier byte accounting under the two-tier topology
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_two_tier_ledger_splits_uplink_by_tier(backend):
    topo = TwoTierTopology(num_edges=4, payload_overhead_bytes=8, seed=0)
    fleet = uniform_fleet(12)
    trace = _run(fleet, FullSync(), backend, cohort=6, topology=topo,
                 wire_kinds=("pq", "dense"), uplink=1000, downlink=4000)
    tiers = trace.tier_totals()
    assert set(tiers) == {"edge_uplink", "server_uplink", "downlink"}
    for r in trace:
        edge = r.ledger["edge_uplink/pq"]
        server = r.ledger["server_uplink/pq"]
        # every client->edge upload crossed the last mile ...
        assert edge == 6 * 1000
        # ... while the PS link carried one combined payload per
        # participating edge (sum + count header)
        n_edges = len(set(int(topo.cluster_of[c]) for c in r.participants))
        assert server == n_edges * (1000 + 8)
        assert server < edge
        # RoundRecord.uplink_bytes is the sum of both tiers
        assert r.uplink_bytes == edge + server
    assert tiers["edge_uplink"] + tiers["server_uplink"] \
        == trace.total_uplink_bytes


def test_flat_star_ledger_has_no_tier_split():
    trace = _run(uniform_fleet(8), FullSync(), "vector",
                 wire_kinds=("pq", "dense"))
    tiers = trace.tier_totals()
    assert set(tiers) == {"uplink", "downlink"}
    assert trace.tier_bytes_per_round("server_uplink") == 0.0
    assert trace.tier_bytes_per_round("uplink") > 0.0


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_topology_edge_hop_extends_the_round(backend):
    """A slow backhaul must push t_end past the flat-star round end."""
    slow = TwoTierTopology(num_edges=2, edge_uplink_bps=1e3,
                           edge_latency_s=1.0, seed=0)
    flat = _run(uniform_fleet(8, ClientProfile(uplink_bps=1e6)),
                FullSync(), backend)
    edged = _run(uniform_fleet(8, ClientProfile(uplink_bps=1e6)),
                 FullSync(), backend, topology=slow)
    for f, e in zip(flat, edged):
        assert e.t_end > f.t_end


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_async_topology_relays_without_precombination(backend):
    """Async edges are store-and-forward: each contribution pays the
    relay hop (longer rounds) and the server tier carries every payload
    1:1 — no combine, because staleness weights are per contribution."""
    topo = TwoTierTopology(num_edges=2, edge_uplink_bps=1e6,
                           edge_latency_s=0.5, seed=0)
    fleet = uniform_fleet(8, ClientProfile(uplink_bps=1e6))
    flat = _run(fleet, AsyncBuffer(3), backend, wire_kinds=("pq", "dense"))
    edged = _run(fleet, AsyncBuffer(3), backend, topology=topo,
                 wire_kinds=("pq", "dense"))
    assert edged.simulated_seconds > flat.simulated_seconds
    tiers = edged.tier_totals()
    assert tiers["edge_uplink"] == tiers["server_uplink"]  # 1:1 relay


# ---------------------------------------------------------------------------
# ClientFleet: construction, validation, adapter protocol
# ---------------------------------------------------------------------------

def test_fleet_from_profiles_roundtrips_rows():
    profiles = [ClientProfile(uplink_bps=1e6 * (i + 1), latency_s=0.01 * i,
                              compute_multiplier=1.0 + i,
                              dropout_prob=0.1 * i) for i in range(5)]
    fleet = ClientFleet.from_profiles(profiles)
    assert len(fleet) == 5
    for i, p in enumerate(profiles):
        assert fleet[i] == p                      # int index -> ClientProfile
    assert [p.latency_s for p in fleet] == [p.latency_s for p in profiles]
    sub = fleet[1:3]                              # slice -> ClientFleet
    assert isinstance(sub, ClientFleet) and len(sub) == 2
    assert isinstance(fleet[np.array([0, 4])], ClientFleet)
    assert ClientFleet.from_any(fleet) is fleet
    assert ClientFleet.from_any(profiles)[0] == profiles[0]


def test_fleet_bulk_validation_mirrors_profile_validation():
    ClientFleet.from_profiles([IDEAL])  # baseline constructs fine
    with pytest.raises(ValueError, match="bandwidth"):
        ClientFleet(uplink_bps=np.array([1e6, -1.0]),
                    downlink_bps=np.ones(2), latency_s=np.zeros(2),
                    compute_multiplier=np.ones(2), dropout_prob=np.zeros(2))
    with pytest.raises(ValueError, match="dropout_prob"):
        ClientFleet(uplink_bps=np.ones(2), downlink_bps=np.ones(2),
                    latency_s=np.zeros(2), compute_multiplier=np.ones(2),
                    dropout_prob=np.array([0.5, 1.5]))
    with pytest.raises(ValueError, match="shared"):
        ClientFleet(uplink_bps=np.ones(3), downlink_bps=np.ones(2),
                    latency_s=np.zeros(2), compute_multiplier=np.ones(2),
                    dropout_prob=np.zeros(2))


def test_vectorized_times_bitwise_match_scalar_profiles():
    fleet = lognormal_fleet(32, median_uplink_bps=3e6, seed=11)
    ids = np.arange(32)
    vec = fleet.round_trip_seconds(ids, 1000, 4000, 1.0)
    for i in range(32):
        p = fleet[i]
        scalar = (p.downlink_seconds(4000) + p.compute_seconds(1.0)) \
            + p.uplink_seconds(1000)
        assert vec[i] == scalar  # exact equality, not approx
    # zero-byte transfers are free (skip the latency term) in both paths
    assert fleet.uplink_seconds(0, ids).tolist() == [0.0] * 32
    assert transfer_seconds(0, 1e6, 0.5) == 0.0
    # infinite bandwidth costs only latency, elementwise as in scalar
    ideal = uniform_fleet(3)
    assert ideal.round_trip_seconds(np.arange(3), 10, 10, 1.0).tolist() \
        == [1.0] * 3


def test_samplers_return_fleets_and_validate_length():
    for fleet in (uniform_fleet(6), lognormal_fleet(6),
                  mobile_fleet(6, seed=2)):
        assert isinstance(fleet, ClientFleet) and len(fleet) == 6
        validate_fleet(fleet, 6)
        with pytest.raises(ValueError, match="profiles"):
            validate_fleet(fleet, 7)
    validate_fleet([IDEAL, IDEAL], 2)  # profile lists still accepted


def test_mobile_fleet_mixture_has_both_populations():
    fleet = mobile_fleet(200, flaky_fraction=0.3, seed=0)
    mobile = fleet.dropout_prob > 0
    assert 0 < mobile.sum() < 200
    assert np.all(fleet.compute_multiplier[mobile] == 3.0)
    assert np.all(fleet.compute_multiplier[~mobile] == 1.0)


# ---------------------------------------------------------------------------
# topology helpers: locations, k-means, lifecycle
# ---------------------------------------------------------------------------

def test_kmeans_partitions_hotspot_points():
    pts = simulate_locations(2000, hotspots=6, seed=0)
    labels, centers = kmeans_points(pts, 8, iters=6, seed=0, chunk=300)
    assert labels.shape == (2000,) and centers.shape == (8, 2)
    assert labels.min() >= 0 and labels.max() < 8
    # clustering must beat a single global centroid on within-cluster SSE
    sse = ((pts - centers[labels]) ** 2).sum()
    sse_one = ((pts - pts.mean(axis=0)) ** 2).sum()
    assert sse < 0.5 * sse_one
    # chunking is an implementation detail: same labels regardless
    labels2, _ = kmeans_points(pts, 8, iters=6, seed=0, chunk=2048)
    assert np.array_equal(labels, labels2)


def test_kmeans_degenerate_shapes():
    pts = np.random.default_rng(0).uniform(size=(3, 2))
    labels, centers = kmeans_points(pts, 5)
    assert labels.tolist() == [0, 1, 2] and centers.shape == (3, 2)
    with pytest.raises(ValueError):
        kmeans_points(pts, 0)


def test_topology_lifecycle_and_meta():
    topo = TwoTierTopology(num_edges=3, seed=0)
    with pytest.raises(RuntimeError, match="ensure"):
        topo.sync_round(np.array([0]), np.array([1.0]), 1.0, 100)
    topo.ensure(50)
    first = topo.cluster_of
    topo.ensure(50)                      # idempotent: same clustering
    assert topo.cluster_of is first
    with pytest.raises(ValueError, match="clustered"):
        topo.ensure(60)
    with pytest.raises(ValueError, match="num_edges"):
        TwoTierTopology(num_edges=0)
    meta = topo.meta()
    assert meta["topology"] == "two_tier" and meta["topology_edges"] == 3


def test_sync_round_empty_survivors():
    topo = TwoTierTopology(num_edges=3, seed=0)
    topo.ensure(10)
    t_end, edges, server_bytes = topo.sync_round(
        np.array([], dtype=np.int64), np.array([]), 4.5, 1000)
    assert (t_end, edges, server_bytes) == (4.5, 0, 0)


def test_sync_round_times_and_bytes():
    topo = TwoTierTopology(num_edges=2, edge_uplink_bps=1e6,
                           edge_latency_s=0.25, payload_overhead_bytes=8,
                           seed=0)
    topo.ensure(4)
    survivors = np.arange(4)
    t = np.array([1.0, 2.0, 3.0, 4.0])
    t_end, edges, server_bytes = topo.sync_round(survivors, t, 4.0, 1000)
    hop = 0.25 + (1000 + 8) * 8.0 / 1e6
    assert t_end == pytest.approx(4.0 + hop)
    assert edges == len(set(topo.cluster_of[:4].tolist()))
    assert server_bytes == edges * 1008
    # a late policy decision time dominates a fast backhaul
    t_end2, _, _ = topo.sync_round(survivors, t, 100.0, 1000)
    assert t_end2 == 100.0


# ---------------------------------------------------------------------------
# fleet-scale smoke (small enough for tier-1; the 10^6 cell lives in
# benchmarks/bench_network.py --fleet-scale)
# ---------------------------------------------------------------------------

def test_vector_backend_scales_to_a_large_fleet_smoke():
    fleet = lognormal_fleet(50_000, dropout_prob=0.01, seed=1)
    topo = TwoTierTopology(num_edges=8, seed=0)
    trace = _run(fleet, DropSlowestK(50), "vector", rounds=3, cohort=500,
                 topology=topo, wire_kinds=("pq", "dense"))
    assert len(trace) == 3
    for r in trace:
        # 500 sampled = survivors + (straggler cuts + dropouts)
        assert len(r.participants) + len(r.dropped) == 500
        assert len(r.dropped) >= 50  # at least the k cut stragglers
    tiers = trace.tier_totals()
    assert tiers["server_uplink"] < tiers["edge_uplink"]
