"""End-to-end behaviour tests for the FedLite system.

Covers: full federated training loop with compression + correction on the
paper's task; the big-arch split train step under jit; serve path
(prefill with quantized uplink -> decode); spec builders for every
supported (arch × shape) pair on a 1-device mesh (multi-device sharding is
exercised by launch/dryrun.py)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_arch, InputShape
from repro.core.fedlite import TrainState, comm_report, make_train_step
from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data, make_lm_batch
from repro.federated.runtime import FederatedTrainer
from repro.launch.specs import (cache_specs, default_pq, input_specs,
                                make_model, state_specs)
from repro.models.paper_models import FemnistCNN
from repro.optim import adam, get_optimizer, sgd


def test_end_to_end_fedlite_femnist():
    """30 rounds of compressed federated training make real progress and
    report the paper's accounting metrics."""
    data = make_federated_image_data(num_clients=16, seed=0)
    pq = PQConfig(num_subvectors=288, num_clusters=8, kmeans_iters=4)
    model = FemnistCNN(pq=pq, lam=1e-4, client_batch=10)
    trainer = FederatedTrainer(model, sgd(10 ** -1.5), data, cohort=8,
                               client_batch=10)
    state, hist = trainer.run(30, jax.random.PRNGKey(0))
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert hist[-1]["pq_compression_ratio"] > 50
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_split_llm_train_step_under_jit():
    """Smoke-size llama3 FedLite step: quantized cut, both sides update."""
    cfg = get_arch("llama3_8b", smoke=True)
    model = make_model(cfg)
    opt = get_optimizer("adam", 1e-3)
    step = make_train_step(model, opt, donate=False)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    batch = make_lm_batch(jax.random.PRNGKey(1), 4, 64, cfg.vocab_size)
    p0 = state.params
    state, metrics = step(state, batch)
    # client params changed => corrected gradients crossed the quantizer
    delta_c = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(state.params["client"]), jax.tree.leaves(p0["client"])))
    delta_s = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree.leaves(state.params["server"]), jax.tree.leaves(p0["server"])))
    assert delta_c > 0 and delta_s > 0
    assert metrics["pq_compression_ratio"] > 5


def test_split_serving_quantized_prefill():
    """Split inference: prefill with PQ-compressed uplink still decodes
    sensibly (logits finite, close to the uncompressed prefill)."""
    cfg = get_arch("starcoder2_3b", smoke=True)
    model = make_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 31), 0,
                              cfg.vocab_size)
    caches = model.init_caches(2, 40)
    lg_q, caches_q = model.prefill(params, {"tokens": toks}, caches,
                                   quantize=True)
    caches2 = model.init_caches(2, 40)
    lg_u, _ = model.prefill(params, {"tokens": toks}, caches2, quantize=False)
    assert np.isfinite(np.asarray(lg_q)).all()
    # compressed-uplink logits correlate with uncompressed (untrained nets:
    # logits are near-noise, so correlation is informative but modest), and
    # a finer quantizer correlates more strongly — the knob works
    import dataclasses
    a = np.asarray(lg_q, np.float32).ravel()
    b = np.asarray(lg_u, np.float32).ravel()
    corr = np.corrcoef(a, b)[0, 1]
    assert corr > 0.5
    fine = dataclasses.replace(model.pq, num_clusters=64)
    model_fine = dataclasses.replace(model, pq=fine)
    lg_f, _ = model_fine.prefill(params, {"tokens": toks},
                                 model.init_caches(2, 40), quantize=True)
    corr_f = np.corrcoef(np.asarray(lg_f, np.float32).ravel(), b)[0, 1]
    assert corr_f > corr
    lg2, _ = model.decode_step(params, caches_q,
                               jnp.ones((2, 1), jnp.int32), 31)
    assert np.isfinite(np.asarray(lg2)).all()


def test_spec_builders_cover_all_arch_shape_pairs():
    """input_specs/cache_specs/state_specs build for every supported
    (arch × shape) without touching devices (1-device mesh)."""
    from repro.configs.base import ARCH_IDS, supports_shape
    from repro.sharding import AxisType, make_mesh
    mesh = make_mesh((1, 1), ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
    small = {
        "train_4k": InputShape("train_4k", 128, 8, "train"),
        "prefill_32k": InputShape("prefill_32k", 128, 4, "prefill"),
        "decode_32k": InputShape("decode_32k", 128, 4, "decode"),
        "long_500k": InputShape("long_500k", 256, 1, "decode"),
    }
    for arch in ARCH_IDS:
        cfg = get_arch(arch, smoke=True)
        model = make_model(cfg)
        for sname, shp in small.items():
            if not supports_shape(arch, sname):
                continue
            b = input_specs(cfg, shp, mesh, with_labels=shp.kind == "train")
            assert "tokens" in b
            cs = cache_specs(model, shp.global_batch, shp.seq_len, mesh)
            assert isinstance(cs, dict)
        ss = state_specs(model, get_optimizer("adam", 1e-3), mesh)
        assert ss.params["client"]


def test_comm_report_consistency_across_archs():
    for arch in ["gemma_7b", "mamba2_1p3b"]:
        cfg = get_arch(arch, smoke=True)
        model = make_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rep = comm_report(model, params, tokens_per_client=256)
        assert rep["fedlite_uplink_bits"] < rep["splitfed_uplink_bits"] < \
            rep["fedavg_uplink_bits"]
