"""Paper-task models (FEMNIST CNN / SO Tag / SO NWP) behave per Appendix C."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantizer import PQConfig
from repro.data.synthetic import (make_federated_lm_data,
                                  make_federated_tag_data)
from repro.models.paper_models import FemnistCNN, SONwpLSTM, SOTagMLP


def test_femnist_cut_dimension_is_papers():
    model = FemnistCNN()
    p = model.init(jax.random.PRNGKey(0))
    acts = model.client_forward(p["client"], {"image": jnp.zeros((2, 28, 28, 1))})
    assert acts.shape == (2, 9216)  # the paper's d


def test_sotag_shapes_and_recall():
    model = SOTagMLP(bow_dim=256, cut_dim=64, num_tags=32,
                     pq=PQConfig(num_subvectors=8, num_clusters=4,
                                 kmeans_iters=3), lam=1e-3)
    data = make_federated_tag_data(num_clients=4, bow_dim=256, num_tags=32)
    p = model.init(jax.random.PRNGKey(0))
    b = data.sample_batch(0, jax.random.PRNGKey(1), 16)
    loss, m = model.loss(p, b)
    assert np.isfinite(float(loss))
    r5 = model.recall_at_5(p, b)
    assert 0.0 <= float(r5) <= 1.0


def test_sonwp_lstm_learns_and_quantizes():
    model = SONwpLSTM(vocab=200, hidden=64, pq=PQConfig(num_subvectors=12,
                                                        num_clusters=4,
                                                        kmeans_iters=3),
                      lam=1e-3)
    data = make_federated_lm_data(num_clients=4, vocab=200)
    p = model.init(jax.random.PRNGKey(0))
    b = data.sample_batch(0, jax.random.PRNGKey(1), 8, seq=20)
    loss0, _ = model.loss(p, b)
    g = jax.grad(lambda q: model.loss(q, b)[0])(p)
    p2 = jax.tree.map(lambda a, gg: a - 0.5 * gg, p, g)
    loss1, _ = model.loss(p2, b)
    assert float(loss1) < float(loss0)
    # cut activation is d=96-ish (here cut_dim default 96)
    acts = model.client_forward(p["client"], b)
    assert acts.shape[-1] == model.cut_dim


def test_client_batch_per_client_codebooks_change_result():
    """Per-client (vmapped) quantization differs from pooled quantization —
    i.e. the client_batch plumbing is actually doing something."""
    pq = PQConfig(num_subvectors=4, num_clusters=2, kmeans_iters=6)
    m_pooled = SOTagMLP(bow_dim=64, cut_dim=16, num_tags=8, pq=pq, lam=0.0)
    m_per = SOTagMLP(bow_dim=64, cut_dim=16, num_tags=8, pq=pq, lam=0.0,
                     client_batch=4)
    p = m_pooled.init(jax.random.PRNGKey(0))
    b = {"bow": jax.random.normal(jax.random.PRNGKey(1), (16, 64)),
         "tags": jnp.zeros((16, 8))}
    l1, _ = m_pooled.loss(p, b)
    l2, _ = m_per.loss(p, b)
    assert not np.isclose(float(l1), float(l2))
