"""Synthetic federated data pipeline tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import (make_federated_image_data,
                                  make_federated_lm_data,
                                  make_federated_tag_data, make_lm_batch)


def test_image_data_shapes_and_determinism():
    data = make_federated_image_data(num_clients=8, seed=0)
    b1 = data.sample_batch(0, jax.random.PRNGKey(1), 16)
    b2 = data.sample_batch(0, jax.random.PRNGKey(1), 16)
    assert b1["image"].shape == (16, 28, 28, 1)
    np.testing.assert_array_equal(b1["label"], b2["label"])
    assert float(data.client_weights.sum()) == 1.0 or \
        abs(float(data.client_weights.sum()) - 1.0) < 1e-9


def test_image_data_is_non_iid():
    """Dirichlet(0.5) skew: per-client label histograms differ materially."""
    data = make_federated_image_data(num_clients=4, alpha=0.1, seed=1)
    h = []
    for c in range(4):
        b = data.sample_batch(c, jax.random.PRNGKey(c), 256)
        h.append(np.bincount(np.asarray(b["label"]), minlength=62) / 256)
    h = np.stack(h)
    # total variation between client distributions should be large
    tv = np.abs(h[0] - h[1]).sum() / 2
    assert tv > 0.3


def test_lm_data_learnable_structure():
    data = make_federated_lm_data(num_clients=4, vocab=100, seed=0)
    b = data.sample_batch(0, jax.random.PRNGKey(0), 8, seq=20)
    assert b["tokens"].shape == (8, 20)
    assert b["labels"].shape == (8, 20)
    # labels are next tokens
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert int(b["labels"][0, -1]) == -1


def test_tag_data_multilabel():
    data = make_federated_tag_data(num_clients=4, bow_dim=128, num_tags=64,
                                   seed=0)
    b = data.sample_batch(1, jax.random.PRNGKey(0), 8)
    assert b["bow"].shape == (8, 128)
    assert b["tags"].shape == (8, 64)
    assert float(b["tags"].max()) <= 1.0
    assert float(b["tags"].sum(1).mean()) > 2  # several tags per example


def test_lm_batch_smoke():
    b = make_lm_batch(jax.random.PRNGKey(0), 4, 16, 1000)
    assert b["tokens"].shape == (4, 16)
    assert int(b["tokens"].max()) < 1000
