"""Flash-attention Pallas kernel vs the row-block oracle (interpret mode)."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import row_block_attention


def _mk(B, S, H, Kv, hd, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, Kv, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, Kv, hd), dtype)
    return q, k, v


def _ref(q, k, v, window, scale):
    pos = jnp.arange(q.shape[1])
    return row_block_attention(q, k, v, pos, pos, window=window,
                               q_chunk=q.shape[1], scale=scale)


@pytest.mark.parametrize("B,S,H,Kv,hd,bq,bk", [
    (1, 64, 2, 2, 16, 32, 32),     # MHA
    (2, 128, 4, 2, 32, 64, 32),    # GQA group 2
    (1, 128, 8, 2, 16, 128, 64),   # GQA group 4, single q block
])
@pytest.mark.parametrize("window", [None, 32])
def test_flash_matches_rowblock(B, S, H, Kv, hd, bq, bk, window):
    q, k, v = _mk(B, S, H, Kv, hd)
    scale = 1.0 / math.sqrt(hd)
    ref = _ref(q, k, v, window, scale)                       # (B,S,H,hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Kv, S, hd)
    out = flash_attention(qf, kf, vf, num_q_heads=H, num_kv_heads=Kv,
                          scale=scale, window=window, block_q=bq, block_k=bk,
                          interpret=True)
    out = out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)


def test_flash_bf16_io():
    q, k, v = _mk(1, 64, 2, 1, 16, dtype=jnp.bfloat16, seed=3)
    scale = 0.25
    out = flash_attention(
        q.transpose(0, 2, 1, 3).reshape(2, 64, 16),
        k.transpose(0, 2, 1, 3).reshape(1, 64, 16),
        v.transpose(0, 2, 1, 3).reshape(1, 64, 16),
        num_q_heads=2, num_kv_heads=1, scale=scale, interpret=True)
    assert out.dtype == jnp.bfloat16
    ref = _ref(q, k, v, None, scale).transpose(0, 2, 1, 3).reshape(2, 64, 16)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=3e-2,
                               atol=3e-2)


def test_flash_causality():
    """Future kv perturbations never change earlier outputs."""
    q, k, v = _mk(1, 64, 2, 2, 16, seed=5)
    scale = 0.25
    def run(kk, vv):
        return flash_attention(
            q.transpose(0, 2, 1, 3).reshape(2, 64, 16),
            kk.transpose(0, 2, 1, 3).reshape(2, 64, 16),
            vv.transpose(0, 2, 1, 3).reshape(2, 64, 16),
            num_q_heads=2, num_kv_heads=2, scale=scale, block_q=32,
            block_k=32, interpret=True)
    o1 = run(k, v)
    o2 = run(k.at[:, -1].add(50.0), v.at[:, -1].add(50.0))
    np.testing.assert_allclose(o1[:, :-1], o2[:, :-1], rtol=1e-5, atol=1e-6)
