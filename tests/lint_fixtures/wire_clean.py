"""Clean counterpart to wire_bad.py: zero findings once its encoders are
pinned in a (test-local) wire manifest."""
import struct

_HEADER = struct.Struct("<BBH")
_VERSION = 1

KIND_DENSE = 0
KIND_SPARSE = 1


def encode_dense(payload):
    return _HEADER.pack(KIND_DENSE, _VERSION, len(payload)) + payload


def encode_sparse(payload):
    return _HEADER.pack(KIND_SPARSE, _VERSION, len(payload)) + payload


def decode(buf):
    kind, version, n = _HEADER.unpack_from(buf)
    del version, n
    if kind not in (KIND_DENSE, KIND_SPARSE):
        raise ValueError(f"unknown wire kind {kind}")
    if kind == KIND_DENSE:
        return buf[_HEADER.size:]
    if kind == KIND_SPARSE:
        return buf[_HEADER.size:]
    return None
