"""Seeded violations for the fleet-scale pass.

Loaded by tests/test_lint.py under a ``src/repro/federated/`` pseudo-path
(the pass only fires on federated hot paths, so the standard ``fixtures/``
pseudo-path would silence it)."""


def total_latency(fleet):
    total = 0.0
    for p in fleet:  # SEED: python-loop-over-fleet
        total += p.latency_s
    return total


def slowest(arrivals):
    worst = None
    for i, a in enumerate(arrivals):  # SEED: python-loop-over-fleet
        if worst is None or a.t_arrival > worst.t_arrival:
            worst = a
    return worst


def uplinks(profiles, nbytes):
    return [p.uplink_seconds(nbytes) for p in profiles]  # SEED: python-loop-over-fleet


def pair_up(fleet, arrivals):
    out = {}
    for p, a in zip(fleet, sorted(arrivals)):  # SEED: python-loop-over-fleet
        out[a.client] = p
    return out
