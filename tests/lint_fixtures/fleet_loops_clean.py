"""Clean counterpart for the fleet-scale pass: vectorized idiom plus one
reviewed reference-backend suppression."""

import numpy as np


def total_latency(fleet):
    return float(fleet.latency_s.sum())


def slowest(t_arrivals):
    order = np.argsort(t_arrivals, kind="stable")
    return int(order[-1])


def uplinks(fleet, ids, nbytes):
    return fleet.uplink_seconds(nbytes, ids)


def cohort_loop(cohort):
    # cohort-sized (round-boundary) sequences are not fleet-scaled
    return [c for c in cohort]


def reference_backend(fleet):
    return [p.latency_s for p in fleet]  # fedlint: disable=python-loop-over-fleet
