"""Seeded mesh-axis violations (SEED markers give the expected rule
and line). Never imported — parsed by tests/test_lint.py only."""
import jax
from jax.sharding import PartitionSpec as P

CLIENTS_AXIS = "clients"


def build_mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


def all_reduce(x):
    return jax.lax.psum(x, "clientz")  # SEED: mesh-axis-undeclared


def client_reduce(x):
    return jax.lax.psum(x, CLIENTS_AXIS)


BAD_SPEC = P("data", "modell")  # SEED: mesh-axis-undeclared
GOOD_SPEC = P("data", None, "model")
