"""Seeded violations for the obs-events pass.

Loaded by tests/test_lint.py under a ``src/repro/federated/`` pseudo-path
(the pass only fires on federated hot paths)."""

from repro import obs


def emit_typo(rd):
    # a name the schema registry has never heard of: tooling-invisible
    obs.event("fault.round_vioded", cat="faults", round=rd)  # SEED: orphan-obs-event


def emit_dynamic(kind):
    name = "fault." + kind
    obs.event(name, cat="faults")  # SEED: dynamic-obs-event
