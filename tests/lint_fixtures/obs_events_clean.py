"""Clean baseline for the obs-events pass: registered names only, plus a
reviewed suppression for a deliberately dynamic name.

Loaded by tests/test_lint.py under a ``src/repro/federated/`` pseudo-path."""

from repro import obs


def emit_registered(rd, quarantined, cohort):
    obs.event("fault.round_voided", cat="faults", round=rd,
              quarantined=quarantined, cohort=cohort)
    obs.event("slo_violation", cat="slo", rule="drop-rate",
              signal="drop_rate", op="<=", threshold=0.5, value=0.7,
              window=None)


def emit_reviewed_dynamic(kind):
    obs.event("fault." + kind, cat="faults")  # fedlint: disable=dynamic-obs-event


def not_an_event_call(rd, log):
    # same arity/shape, different callee: the pass must not fire
    log("fault.round_vioded", rd)
