"""Clean counterpart to vjp_bad.py: zero findings expected."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def leaky_relu(alpha, x):
    return jnp.where(x > 0, x, alpha * x)


def leaky_relu_fwd(alpha, x):
    return leaky_relu(alpha, x), (x > 0)


def leaky_relu_bwd(alpha, mask, ct):
    return (jnp.where(mask, ct, alpha * ct),)


leaky_relu.defvjp(leaky_relu_fwd, leaky_relu_bwd)
