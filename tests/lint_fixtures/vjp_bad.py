"""Seeded custom-VJP contract violations (SEED markers give the expected
rule and line). Never imported — parsed by tests/test_lint.py only."""
import functools

import jax
import jax.numpy as jnp


@jax.custom_vjp
def orphan(x, y):  # SEED: vjp-missing-defvjp
    return x * y


@jax.custom_vjp
def scaled(x, y):
    return x * y


def scaled_fwd(x):  # SEED: vjp-fwd-arity
    return scaled(x, x), x, x  # SEED: vjp-fwd-pair


def scaled_bwd(res, ct, extra):  # SEED: vjp-bwd-arity
    del extra
    return (res * ct,)  # SEED: vjp-bwd-return-arity


scaled.defvjp(scaled_fwd, scaled_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def clipped(x, lo):  # SEED: vjp-nondiff-range
    return jnp.clip(x, lo, None)


def clipped_fwd(x, lo):
    return clipped(x, lo), (x, lo)


def clipped_bwd(lo, res, ct):
    del lo, res
    return (ct,)


clipped.defvjp(clipped_fwd, clipped_bwd)
