"""Clean counterpart to pallas_bad.py: zero findings expected."""
import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def tiled_matmul(a, b):
    return pl.pallas_call(
        matmul_kernel,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((128, 128), lambda i, j: (i, 0)),
            pl.BlockSpec((128, 128), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((128, 128), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((512, 512), jnp.float32),
    )(a, b)


def run(x, interpret=None):
    del interpret
    return x
