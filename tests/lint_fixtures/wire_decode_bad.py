"""Red fixture for the wire-decode pass: unguarded decodes in a hot path
(linted under a fake ``src/repro/federated/`` path)."""
from repro.federated import wire


def harvest(payload):
    # no try at all
    return wire.decode_payload(payload)  # SEED: unchecked-wire-decode


def lineage(payload, ref):
    try:
        out = wire.decode_pq_delta(payload, ref)  # SEED: unchecked-wire-decode
    except KeyError:   # catches the WRONG hierarchy: still unguarded
        out = None
    return out


def handler_body_is_not_protected(payload):
    try:
        return wire.decode_payload(payload)
    except wire.WireError:
        # decoding a fallback INSIDE the handler is outside the try
        return wire.decode_bytes(payload)  # SEED: unchecked-wire-decode
