"""One seeded violation, suppressed in-line: zero findings expected."""
import jax
import jax.numpy as jnp


@jax.jit
def leaky_step(p, b):
    m = float(jnp.mean(p))  # fedlint: disable=host-sync-in-jit
    return p - m * b
