"""Clean counterpart to mesh_bad.py: zero findings expected."""
import jax
from jax.sharding import PartitionSpec as P

CLIENTS_AXIS = "clients"


def build_mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


def all_reduce(x):
    return jax.lax.psum(x, "data")


def client_reduce(x):
    return jax.lax.psum(x, CLIENTS_AXIS)


SPEC = P("data", None, "model")
