"""Seeded Pallas kernel violations (SEED markers give the expected rule
and line). Never imported — parsed by tests/test_lint.py only."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def matmul_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...])  # SEED: pallas-accum-dtype


def outer_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] @ y_ref[...]  # SEED: pallas-accum-dtype


def copy_kernel(a_ref, o_ref):
    o_ref[...] = a_ref[...] * 2.0


def bad_blocks(a, b):
    return pl.pallas_call(
        matmul_kernel,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((128, 128), lambda i: (i, 0)),  # SEED: pallas-index-map-arity
            pl.BlockSpec((128,), lambda i, j: (i, j)),  # SEED: pallas-index-map-rank
        ],
        out_specs=pl.BlockSpec((100, 128), lambda i, j: (i, j)),  # SEED: pallas-block-divide
        out_shape=jax.ShapeDtypeStruct((512, 512), jnp.float32),
    )(a, b)


def hot_blocks(a):
    return pl.pallas_call(  # SEED: pallas-vmem-budget
        copy_kernel,
        grid=(8,),
        in_specs=[pl.BlockSpec((2048, 2048), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((2048, 2048), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((16384, 2048), jnp.float32),
    )(a)


def run_interpreted(x, interpret=True):  # SEED: pallas-interpret-hardcoded
    del interpret
    return x


def call_interpreted(x):
    return run_interpreted(x, interpret=True)  # SEED: pallas-interpret-hardcoded
