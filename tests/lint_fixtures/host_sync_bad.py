"""Seeded host-sync/retrace violations (SEED markers give the expected
rule and line). Never imported — parsed by tests/test_lint.py only."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def leaky_step(p, b):
    m = float(jnp.mean(p))  # SEED: host-sync-in-jit
    return p - m * b


def train(params, batches):
    @jax.jit
    def inner(p, b):  # SEED: jit-closure-rebuild
        return p - jnp.mean(b)

    for b in batches:
        params = inner(params, b)
        loss = float(params)  # SEED: host-sync-in-loop
    return params, loss


def submit_all(scheduler, results):
    def on_done(update):
        results.append(update.block_until_ready())  # SEED: host-sync-in-callback

    scheduler.run(execute=on_done)


@functools.partial(jax.jit, static_argnames=("mode", "typo_param"))  # SEED: jit-static-args
def run(x, mode):
    del mode
    return x
