# SEED: wire-unknown-kind-guard
"""Seeded wire-format violations. The unknown-kind-guard finding anchors
at line 1 (module scope), hence the marker above the docstring. Never
imported — parsed by tests/test_lint.py only."""
import struct

_HEADER = struct.Struct("<BBH")
_VERSION = 1

KIND_DENSE = 0
KIND_SPARSE = 1  # SEED: wire-kind-no-decoder
KIND_GHOST = 2  # SEED: wire-kind-no-encoder


def encode_dense(payload):  # SEED: wire-version-stale
    return _HEADER.pack(KIND_DENSE, _VERSION, len(payload)) + payload


def encode_sparse(payload):  # SEED: wire-version-stale
    return _HEADER.pack(KIND_SPARSE, _VERSION, len(payload)) + payload


def decode(buf):
    kind, version, n = _HEADER.unpack_from(buf)
    del version, n
    if kind == KIND_DENSE:
        return buf[_HEADER.size:]
    if kind == KIND_GHOST:
        return b""
    return None
