"""Clean counterpart to host_sync_bad.py: zero findings expected."""
import functools

import jax
import jax.numpy as jnp


@jax.jit
def step(p, b):
    return p - 0.1 * jnp.mean(b)


def make_step():
    @jax.jit
    def inner(p, b):
        return p - jnp.mean(b)
    return inner


def train(params, batches):
    inner = make_step()
    losses = []
    for b in batches:
        params = inner(params, b)
        losses.append(params)
    return params, jax.device_get(losses)


@functools.partial(jax.jit, static_argnames=("mode",))
def run(x, mode):
    del mode
    return x
