"""Green fixture for the wire-decode pass: every decode either guarded by
the typed hierarchy or carrying a reviewed loopback suppression."""
from repro.federated import wire


def harvest(payload):
    try:
        return wire.decode_payload(payload)
    except wire.WireError:
        return None   # quarantine: corrupt in transit


def lineage(link, payload, ref):
    try:
        return wire.decode_pq_delta(payload, ref)
    except (wire.WireResyncError, wire.WireCorruptionError):
        link.request_resync()
        return None


def broad_catch_is_fine(payload):
    try:
        return wire.decode_payload(payload)
    except ValueError:   # WireError subclasses ValueError
        return None


def measured_loopback(qb):
    # bytes we encoded one expression earlier: nothing untrusted here
    return wire.decode_bytes(wire.encode_bytes(qb))  # fedlint: disable=unchecked-wire-decode
