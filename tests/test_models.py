"""Model-component tests: SSD scan, attention paths, RoPE, MoE dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models.rope import apply_rope, rope_angles
from repro.models.ssm import ssd_scan


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def _naive_ssd(xh, dt, A, Bm, Cm):
    B_, S_, H_, P_ = xh.shape
    N_ = Bm.shape[-1]
    h = jnp.zeros((B_, H_, P_, N_))
    ys = []
    for t in range(S_):
        dA = jnp.exp(dt[:, t] * A[None, :])
        h = dA[:, :, None, None] * h + jnp.einsum(
            "bn,bh,bhp->bhpn", Bm[:, t], dt[:, t], xh[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("chunk", [6, 8, 24])
def test_ssd_chunked_equals_naive(chunk):
    key = jax.random.PRNGKey(0)
    B, S, H, P, N = 2, 24, 3, 4, 5
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_ref, h_ref = _naive_ssd(xh, dt, A, Bm, Cm)
    y, h = ssd_scan(xh, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(y, y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h, h_ref, rtol=1e-4, atol=1e-5)


def test_ssd_initial_state_continuation():
    """Splitting a sequence in two with state carry == one pass (prefill->
    decode consistency at the scan level)."""
    key = jax.random.PRNGKey(1)
    B, S, H, P, N = 1, 16, 2, 4, 3
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_all, h_all = ssd_scan(xh, dt, A, Bm, Cm, 8)
    y1, h1 = ssd_scan(xh[:, :8], dt[:, :8], A, Bm[:, :8], Cm[:, :8], 8)
    y2, h2 = ssd_scan(xh[:, 8:], dt[:, 8:], A, Bm[:, 8:], Cm[:, 8:], 8, h0=h1)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_all,
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(h2, h_all, rtol=1e-4, atol=1e-5)


def test_ssd_gradients_finite():
    B, S, H, P, N = 1, 12, 2, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    args = (jax.random.normal(ks[0], (B, S, H, P)),
            jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))),
            -jnp.exp(jax.random.normal(ks[2], (H,))),
            jax.random.normal(ks[3], (B, S, N)),
            jax.random.normal(ks[4], (B, S, N)))
    g = jax.grad(lambda *a: jnp.sum(ssd_scan(*a, 4)[0] ** 2), argnums=(0, 1))(
        *args)
    for gg in g:
        assert np.isfinite(np.asarray(gg)).all()


# ---------------------------------------------------------------------------
# attention paths agree
# ---------------------------------------------------------------------------

def _qkv(key, B, S, H, Kv, hd):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd)),
            jax.random.normal(ks[1], (B, S, Kv, hd)),
            jax.random.normal(ks[2], (B, S, Kv, hd)))


def test_row_block_chunking_invariance():
    B, S, H, Kv, hd = 2, 64, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, Kv, hd)
    pos = jnp.arange(S)
    o1 = attn_mod.row_block_attention(q, k, v, pos, pos, window=None,
                                      q_chunk=64, scale=0.25)
    o2 = attn_mod.row_block_attention(q, k, v, pos, pos, window=None,
                                      q_chunk=16, scale=0.25)
    np.testing.assert_allclose(o1, o2, rtol=1e-5, atol=1e-6)


def test_local_window_equals_masked_row_block():
    """Exact SWA: block-local path == row-block path with window mask."""
    B, S, H, Kv, hd, W = 1, 96, 2, 1, 8, 16
    q, k, v = _qkv(jax.random.PRNGKey(1), B, S, H, Kv, hd)
    pos = jnp.arange(S)
    o_local = attn_mod.local_window_attention(q, k, v, pos, pos, window=W,
                                              scale=0.3)
    o_ref = attn_mod.row_block_attention(q, k, v, pos, pos, window=W,
                                         q_chunk=S, scale=0.3)
    np.testing.assert_allclose(o_local, o_ref, rtol=1e-5, atol=1e-6)


def test_decode_attention_matches_last_row():
    B, S, H, Kv, hd = 2, 32, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(2), B, S, H, Kv, hd)
    pos = jnp.arange(S)
    full = attn_mod.row_block_attention(q, k, v, pos, pos, window=None,
                                        q_chunk=S, scale=0.25)
    dec = attn_mod.decode_attention(q[:, -1:], k, v, pos, S - 1, window=None,
                                    scale=0.25)
    np.testing.assert_allclose(dec[:, 0], full[:, -1], rtol=1e-5, atol=1e-6)


def test_causality():
    """Perturbing future tokens never changes past outputs."""
    B, S, H, Kv, hd = 1, 16, 2, 2, 8
    q, k, v = _qkv(jax.random.PRNGKey(3), B, S, H, Kv, hd)
    pos = jnp.arange(S)
    o1 = attn_mod.row_block_attention(q, k, v, pos, pos, window=None,
                                      q_chunk=8, scale=1.0)
    k2 = k.at[:, -1].add(100.0)
    v2 = v.at[:, -1].add(100.0)
    o2 = attn_mod.row_block_attention(q, k2, v2, pos, pos, window=None,
                                      q_chunk=8, scale=1.0)
    np.testing.assert_allclose(o1[:, :-1], o2[:, :-1], rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def test_rope_preserves_norm_and_relativity():
    B, S, H, hd = 1, 8, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    ang = rope_angles(pos, hd, 10_000.0)
    y = apply_rope(x, ang)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # shift invariance of inner products: <R_m q, R_n k> == f(m-n)
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, hd))
    def score(m, n):
        am = rope_angles(jnp.full((1, 1), m), hd, 10_000.0)
        an = rope_angles(jnp.full((1, 1), n), hd, 10_000.0)
        return float(jnp.sum(apply_rope(q, am) * apply_rope(k, an)))
    assert score(3, 1) == pytest.approx(score(7, 5), rel=1e-4)


def test_mrope_degenerates_to_rope_on_text():
    """(t,t,t) positions => M-RoPE == RoPE."""
    hd = 32
    pos1 = jnp.broadcast_to(jnp.arange(8), (1, 8))
    pos3 = jnp.broadcast_to(pos1, (3, 1, 8))
    a1 = rope_angles(pos1, hd, 1e4)
    a3 = rope_angles(pos3, hd, 1e4, mrope_sections=(4, 6, 6))
    np.testing.assert_allclose(a1, a3, rtol=1e-6)


# ---------------------------------------------------------------------------
# MoE dispatch
# ---------------------------------------------------------------------------

def _moe_cfg(**kw):
    base = dict(name="m", family="moe", num_layers=2, d_model=32,
                vocab_size=64, num_heads=2, num_kv_heads=1, head_dim=16,
                d_ff=48, num_experts=4, experts_per_token=2, vocab_pad_to=16,
                cut_periods=1)
    base.update(kw)
    return ArchConfig(**base)


def test_moe_equals_dense_expert_computation():
    """With capacity ample, the scatter dispatch must equal running each
    token through its top-k experts densely."""
    cfg = _moe_cfg(capacity_factor=4.0)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    y, _ = moe_mod.apply_moe(p, x, cfg)

    xf = x.reshape(-1, 32)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, 2)
    w = w / w.sum(-1, keepdims=True)
    def expert(e, v):
        return (jax.nn.silu(v @ p["we_gate"][e]) * (v @ p["we_up"][e])) @ \
            p["we_down"][e]
    y_ref = jnp.zeros_like(xf)
    for i in range(xf.shape[0]):
        for j in range(2):
            y_ref = y_ref.at[i].add(w[i, j] * expert(int(idx[i, j]), xf[i]))
    np.testing.assert_allclose(y.reshape(-1, 32), y_ref, rtol=2e-4, atol=1e-5)


def test_moe_capacity_drops_tokens():
    """Tiny capacity must drop overflow tokens (outputs partially zero), and
    never NaN."""
    cfg = _moe_cfg(capacity_factor=0.05)
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32))
    y, aux = moe_mod.apply_moe(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    # some tokens got no expert -> exact zero rows exist
    zero_rows = np.mean(np.abs(np.asarray(y).reshape(-1, 32)).sum(-1) < 1e-9)
    assert zero_rows > 0.1


def test_moe_aux_loss_uniform_router_is_one_times_weight():
    """A perfectly uniform router gives aux = E * (1/E · k/E) * E·w = k·w."""
    cfg = _moe_cfg()
    p = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    p = dict(p, router=jnp.zeros_like(p["router"]))  # uniform routing
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    _, aux = moe_mod.apply_moe(p, x, cfg)
    expected = cfg.experts_per_token * cfg.router_aux_weight
    assert float(aux) == pytest.approx(expected, rel=0.05)
