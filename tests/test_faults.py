"""Chaos layer: deterministic fault injection + self-healing runtime.

Covers the fault plan's determinism contract (stateless hash draws, no
training-RNG perturbation), heapq/vector backend parity under faults,
retry-byte ledgering, edge-outage re-homing, server-kill semantics,
scheduler cursor resume, the runtime's quarantine/quorum screening, and
the headline acceptance criterion: kill-and-resume through
`run_with_recovery` is bitwise identical to the never-killed run.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.quantizer import PQConfig
from repro.data.synthetic import make_federated_image_data
from repro.federated import (AsyncBuffer, ClientProfile, DropSlowestK,
                             FaultPlan, FederatedTrainer, FullSync,
                             Scheduler, ServerKilled, TwoTierTopology,
                             lognormal_fleet, make_injector,
                             run_with_recovery, uniform_fleet)
from repro.models.paper_models import FemnistCNN
from repro.obs import flight as flightlib
from repro.optim import sgd


def _run(fleet, policy, backend, rounds=6, cohort=4, faults=None,
         topology=None, seed=0, cursor=None, on_round=None,
         wire_kinds=("pq", "dense")):
    """Stub-executor scheduler run with a cohort stream deterministic
    across calls, so backends and resumed runs see identical rounds."""
    rng = np.random.default_rng(99)
    cohorts = [rng.choice(len(fleet), cohort, replace=False)
               for _ in range(rounds + 64)]
    sched = Scheduler(fleet=fleet, policy=policy, seed=seed, backend=backend,
                      topology=topology, faults=faults)
    return sched.run(rounds, sample_cohort=lambda rd: cohorts[rd],
                     uplink_bytes=1000, downlink_bytes=4000,
                     execute=lambda i, parts, w: {"loss": float(len(parts))},
                     wire_kinds=wire_kinds, cursor=cursor, on_round=on_round)


def _chaos_trainer(plan, seed=0, **kw):
    data = make_federated_image_data(num_clients=8, seed=0)
    pq = PQConfig(num_subvectors=288, num_clusters=4, kmeans_iters=2)
    model = FemnistCNN(pq=pq, lam=1e-4)
    return FederatedTrainer(model, sgd(0.03), data, cohort=4, client_batch=8,
                            quantize=True, seed=seed, fault_plan=plan, **kw)


# ---------------------------------------------------------------------------
# plan validation + injector determinism
# ---------------------------------------------------------------------------

def test_plan_validates_rates_and_quorum():
    with pytest.raises(ValueError):
        FaultPlan(crash_rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(quorum_fraction=-0.1)
    with pytest.raises(ValueError):
        FaultPlan(max_retries=-1)
    assert not FaultPlan().any_faults
    assert FaultPlan(poison_clients=(3,)).any_faults
    assert make_injector(None) is None
    assert make_injector(FaultPlan()) is None   # zero-fault plan == no plan


def test_injector_draws_are_stateless_and_seeded():
    """Same (plan, round, client) -> same draw, in any call order; a
    different plan seed decorrelates every mask."""
    inj = make_injector(FaultPlan(seed=7, crash_rate=0.5, corrupt_rate=0.5,
                                  poison_rate=0.5))
    cids = np.arange(64)
    a = inj.corrupt_mask(3, cids)
    # interleave unrelated draws: stateless hashing must not care
    inj.poison_mask(0, cids)
    inj.crash_attempts_sync(9, cids)
    np.testing.assert_array_equal(a, inj.corrupt_mask(3, cids))
    np.testing.assert_array_equal(inj.corrupt_mask(3, cids[::-1])[::-1], a)

    other = make_injector(dataclasses.replace(inj.plan, seed=8))
    assert not np.array_equal(a, other.corrupt_mask(3, cids))


def test_corrupt_payload_is_deterministic_and_mutating():
    inj = make_injector(FaultPlan(seed=0, corrupt_rate=1.0))
    payload = bytes(range(256)) * 8
    for cid in range(16):
        bad = inj.corrupt_payload(payload, 2, cid)
        assert bad != payload
        assert bad == inj.corrupt_payload(payload, 2, cid)


# ---------------------------------------------------------------------------
# backend parity under faults (acceptance criterion)
# ---------------------------------------------------------------------------

_PARITY_POLICIES = {
    "full_sync": FullSync(),
    "drop_slowest_3": DropSlowestK(3),
    "async_4": AsyncBuffer(4),
}


@pytest.mark.parametrize("policy_name", sorted(_PARITY_POLICIES))
def test_backend_parity_under_fault_schedule(policy_name):
    """heapq vs vector under crashes + reordering: record-for-record
    equality including fault counters, retry ledger and IEEE times."""
    fleet = lognormal_fleet(64, dropout_prob=0.05, seed=3)
    plan = FaultPlan(seed=3, crash_rate=0.15, reorder_rate=0.3,
                     reorder_max_s=1.5)
    ref = _run(fleet, _PARITY_POLICIES[policy_name], "heapq", faults=plan)
    vec = _run(fleet, _PARITY_POLICIES[policy_name], "vector", faults=plan)
    assert len(ref) == len(vec)
    for a, b in zip(ref, vec):
        assert a == b  # dataclass equality: floats, tuples, ledger, faults
    assert ref.fault_totals() == vec.fault_totals()
    assert ref.fault_totals()   # the plan actually injected something


def test_zero_fault_plan_is_bitwise_no_plan_at_scheduler():
    fleet = lognormal_fleet(32, dropout_prob=0.1, seed=1)
    for backend in ("heapq", "vector"):
        plain = _run(fleet, DropSlowestK(2), backend)
        zeroed = _run(fleet, DropSlowestK(2), backend, faults=FaultPlan())
        assert plain.records == zeroed.records


# ---------------------------------------------------------------------------
# retry ledger + edge outages
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_crash_retries_are_ledgered(backend):
    fleet = uniform_fleet(16)
    plan = FaultPlan(seed=11, crash_rate=0.6, max_retries=2)
    trace = _run(fleet, FullSync(), backend, faults=plan)
    totals = trace.fault_totals()
    assert totals["crashes"] > 0 and totals["retries"] > 0
    retried = [r for r in trace if r.faults.get("retries")]
    assert retried
    for r in retried:
        # every retry re-sends the full model downlink, and the ledger
        # says so in its own entry (the base entry stays analytic)
        assert r.ledger["retry_downlink/dense"] == \
            r.faults["retries"] * 4000
        assert r.ledger["downlink/dense"] == 4 * 4000
        assert r.downlink_bytes == (4 + r.faults["retries"]) * 4000


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_retry_budget_exhaustion_drops_the_client(backend):
    """max_retries=0 turns every crash into a permanent drop: the crashed
    client never uploads, but its wasted downlink is still ledgered."""
    fleet = uniform_fleet(16)
    plan = FaultPlan(seed=11, crash_rate=0.6, max_retries=0)
    trace = _run(fleet, FullSync(), backend, faults=plan)
    totals = trace.fault_totals()
    assert totals["crashes"] > 0
    assert totals.get("retries", 0) == 0
    assert totals["crash_dropped"] == totals["crashes"]
    for r in trace:
        if r.faults.get("crash_dropped"):
            assert len(r.participants) == 4 - r.faults["crash_dropped"]


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_edge_outage_rehomes_clients(backend):
    """An edge down for the whole run: its clients re-home to the next
    nearest edge, every round reports the outage, parity holds."""
    fleet = lognormal_fleet(24, dropout_prob=0.0, seed=2)
    plan = FaultPlan(seed=0, edge_outages=((0, 0.0, 1e9),))
    topo = TwoTierTopology(num_edges=4, seed=0)
    trace = _run(fleet, FullSync(), backend, faults=plan, topology=topo,
                 cohort=12)
    assert all(r.faults.get("edges_down") == 1 for r in trace)
    assert trace.fault_totals().get("rehomed", 0) > 0
    # a two-tier ledger still accounts every surviving byte
    for r in trace:
        assert r.ledger["server_uplink/pq"] > 0


def test_edge_outage_backend_parity():
    fleet = lognormal_fleet(24, dropout_prob=0.0, seed=2)
    plan = FaultPlan(seed=0, edge_outages=((1, 0.0, 8.0),))
    traces = []
    for backend in ("heapq", "vector"):
        topo = TwoTierTopology(num_edges=4, seed=0)
        traces.append(_run(fleet, DropSlowestK(2), backend, faults=plan,
                           topology=topo, cohort=8))
    assert traces[0].records == traces[1].records


# ---------------------------------------------------------------------------
# server kills + cursor resume
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", [FullSync(), AsyncBuffer(4)],
                         ids=["sync", "async"])
@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_server_kill_raises_at_the_scheduled_round(backend, policy):
    fleet = uniform_fleet(16)
    plan = FaultPlan(seed=0, server_kill_rounds=(2,))
    with pytest.raises(ServerKilled) as exc:
        _run(fleet, policy, backend, faults=plan)
    assert exc.value.round_index == 2
    assert plan.disarm_kills_through(2).server_kill_rounds == ()


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_cursor_resume_reproduces_the_tail_under_faults(backend):
    """Resuming from the round-3 cursor replays rounds 3..5 bitwise —
    fault draws are keyed on (plan.seed, round), so a restarted process
    redraws the same faults."""
    fleet = lognormal_fleet(32, dropout_prob=0.1, seed=5)
    plan = FaultPlan(seed=4, crash_rate=0.3)
    cursors = {}
    full = _run(fleet, DropSlowestK(2), backend, faults=plan,
                on_round=lambda rd, cur: cursors.__setitem__(rd, cur))
    resumed = _run(fleet, DropSlowestK(2), backend, faults=plan,
                   cursor=cursors[2])
    assert resumed.records == full.records[3:]
    assert full.cursor["round"] == 6


def test_async_rejects_cursor_resume():
    fleet = uniform_fleet(8)
    with pytest.raises(ValueError, match="async"):
        _run(fleet, AsyncBuffer(2), "heapq", cursor={"round": 1, "t": 0.0,
                                                     "rng": None})


# ---------------------------------------------------------------------------
# runtime screening: quarantine, canary, quorum
# ---------------------------------------------------------------------------

def test_chaos_training_quarantines_and_stays_finite():
    """Poisoned + corrupted cohorts: every bad contribution is screened
    out (the canary detects 100% of wire corruption), the aggregate stays
    finite, and training still makes progress."""
    plan = FaultPlan(seed=1, corrupt_rate=0.25, poison_rate=0.2,
                     quorum_fraction=0.25)
    tr = _chaos_trainer(plan)
    state, hist = tr.run(8, jax.random.PRNGKey(0))
    totals = tr.last_trace.fault_totals()
    assert totals.get("quarantined", 0) > 0
    assert totals.get("corrupt_undetected", 0) == 0
    losses = [h["loss"] for h in hist if "loss" in h]
    assert losses and all(np.isfinite(l) for l in losses)
    assert all(np.isfinite(x).all() for x in jax.tree.leaves(state.params))


def test_quorum_collapse_voids_every_round():
    """poison_rate=1: nothing survives screening, every round is voided,
    and the server parameters never move."""
    plan = FaultPlan(seed=0, poison_rate=1.0, quorum_fraction=0.5)
    tr = _chaos_trainer(plan)
    key = jax.random.PRNGKey(0)
    init = tr.init_state(key)
    state, hist = tr.run(3, key, state=init)
    assert tr.last_trace.fault_totals()["round_voided"] == 3
    assert all("loss" not in h for h in hist)
    for a, b in zip(jax.tree.leaves(init.params), jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(a, b)


def test_zero_fault_plan_is_bitwise_no_plan_at_trainer():
    key = jax.random.PRNGKey(0)
    a_state, a_hist = _chaos_trainer(None).run(3, key)
    b_state, b_hist = _chaos_trainer(FaultPlan()).run(3, key)
    assert a_hist == b_hist
    for a, b in zip(jax.tree.leaves(a_state.params),
                    jax.tree.leaves(b_state.params)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# kill-and-resume (the headline acceptance criterion)
# ---------------------------------------------------------------------------

def test_kill_and_resume_is_bitwise_identical(tmp_path):
    """A server killed at round 7 and restored from the round-6 snapshot
    finishes with bitwise-identical params, opt state, history and trace
    to the run that was never killed."""
    base = FaultPlan(seed=5, crash_rate=0.1)
    kill = dataclasses.replace(base, server_kill_rounds=(7,))
    key = jax.random.PRNGKey(0)

    tr_a = _chaos_trainer(base, warm_start=True, error_feedback=True)
    st_a, hist_a = run_with_recovery(tr_a, 9, key, str(tmp_path / "a"),
                                     checkpoint_every=3)
    tr_b = _chaos_trainer(kill, warm_start=True, error_feedback=True)
    st_b, hist_b = run_with_recovery(tr_b, 9, key, str(tmp_path / "b"),
                                     checkpoint_every=3)

    assert hist_a == hist_b
    for a, b in zip(jax.tree.leaves(st_a.params), jax.tree.leaves(st_b.params)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(st_a.opt_state),
                    jax.tree.leaves(st_b.opt_state)):
        np.testing.assert_array_equal(a, b)
    assert tr_a.last_trace.records == tr_b.last_trace.records
    # the restarted process must not re-die on the fired kill
    assert tr_b.fault_plan.server_kill_rounds == (7,)  # plan restored


def test_kill_on_first_segment_cold_restarts(tmp_path):
    """A kill before the first snapshot exists: recovery re-initializes
    from scratch (nothing on disk yet) and still finishes the run."""
    plan = FaultPlan(seed=0, server_kill_rounds=(1,))
    tr = _chaos_trainer(plan)
    st, hist = run_with_recovery(tr, 4, jax.random.PRNGKey(0),
                                 str(tmp_path / "ck"), checkpoint_every=3)
    assert len(tr.last_trace.records) == 4
    assert all(np.isfinite(h["loss"]) for h in hist if "loss" in h)


def test_pathological_kill_plan_exhausts_restart_budget(tmp_path):
    """A plan that kills every round can never complete a segment:
    run_with_recovery must give up after max_restarts, not loop."""
    plan = FaultPlan(seed=0, server_kill_rounds=tuple(range(20)))
    tr = _chaos_trainer(plan)
    with pytest.raises(ServerKilled):
        run_with_recovery(tr, 6, jax.random.PRNGKey(0),
                          str(tmp_path / "ck"), checkpoint_every=3,
                          max_restarts=2)


# ---------------------------------------------------------------------------
# contribution flight lineage (flight recorder <-> fault bookkeeping)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_flight_lineage_reconciles_with_fault_counters(backend):
    """Every per-round fault counter must be re-derivable from the flight
    frames alone: crashes from per-flight retry counts, ledgered retry
    downlinks from retry_downlinks, permanent drops from terminal states."""
    fleet = uniform_fleet(16)
    plan = FaultPlan(seed=11, crash_rate=0.6, max_retries=2)
    trace = _run(fleet, FullSync(), backend, faults=plan)
    assert len(trace.flights) == len(trace.records)
    for frame, rec in zip(trace.flights, trace):
        assert frame.round == rec.round and frame.kind == "sync"
        assert int(frame.retries.sum()) == rec.faults.get("crashes", 0)
        assert int(frame.retry_downlinks.sum()) == rec.faults.get("retries", 0)
        assert int((frame.state == flightlib.S_CRASH_DROPPED).sum()) == \
            rec.faults.get("crash_dropped", 0)
        # the byte ledger's retry entry is exactly the flight-sum times the
        # per-retry downlink cost
        assert rec.ledger.get("retry_downlink/dense", 0) == \
            int(frame.retry_downlinks.sum()) * 4000
        # crash-dropped flights never arrive; aggregated ones always do
        dropped = frame.state == flightlib.S_CRASH_DROPPED
        assert np.isnan(frame.t_arrival[dropped]).all()
        agg = frame.state == flightlib.S_AGGREGATED
        assert np.isfinite(frame.t_arrival[agg]).all()


@pytest.mark.parametrize("backend", ["heapq", "vector"])
def test_flight_lineage_records_rehoming(backend):
    """An edge outage shows up per flight: re-homed contributions carry
    rehomed=True and a live edge id, and the frame-sum matches the
    trace's rehomed counter."""
    fleet = lognormal_fleet(24, dropout_prob=0.0, seed=2)
    plan = FaultPlan(seed=0, edge_outages=((0, 0.0, 1e9),))
    topo = TwoTierTopology(num_edges=4, seed=0)
    trace = _run(fleet, FullSync(), backend, faults=plan, topology=topo,
                 cohort=12)
    total_rehomed = sum(int(f.rehomed.sum()) for f in trace.flights)
    assert total_rehomed == trace.fault_totals()["rehomed"] > 0
    for frame in trace.flights:
        # edge 0 is down for the whole run: no flight may route through it
        assert not (frame.edge == 0).any()
        agg = frame.state == flightlib.S_AGGREGATED
        assert (frame.edge[agg] >= 0).all()


def test_flight_lineage_records_quarantine():
    """Server-side screening is replayed onto the frames after the run:
    the number of S_QUARANTINED flights equals the trace's quarantine
    counter, and quarantined flights are never also aggregated."""
    plan = FaultPlan(seed=1, corrupt_rate=0.25, poison_rate=0.2,
                     quorum_fraction=0.25)
    tr = _chaos_trainer(plan)
    tr.run(8, jax.random.PRNGKey(0))
    trace = tr.last_trace
    totals = trace.fault_totals()
    nq = sum(int((f.state == flightlib.S_QUARANTINED).sum())
             for f in trace.flights)
    assert nq == totals["quarantined"] > 0
    counts = {}
    for f in trace.flights:
        for k, v in f.state_counts().items():
            counts[k] = counts.get(k, 0) + v
    assert counts.get("quarantined", 0) == nq
    assert counts.get("aggregated", 0) > 0


def test_voided_rounds_void_every_surviving_flight():
    plan = FaultPlan(seed=0, poison_rate=1.0, quorum_fraction=0.5)
    tr = _chaos_trainer(plan)
    tr.run(3, jax.random.PRNGKey(0))
    for frame in tr.last_trace.flights:
        survived = frame.state != flightlib.S_QUARANTINED
        assert (frame.state[survived] == flightlib.S_VOIDED).all()
        assert not (frame.state == flightlib.S_AGGREGATED).any()


def test_kill_and_resume_preserves_flight_lineage(tmp_path):
    """Flight frames ride the snapshot: a killed-and-restored run ends
    with the same flight set, frame-for-frame, as the uninterrupted run."""
    base = FaultPlan(seed=5, crash_rate=0.1)
    kill = dataclasses.replace(base, server_kill_rounds=(7,))
    key = jax.random.PRNGKey(0)
    tr_a = _chaos_trainer(base)
    run_with_recovery(tr_a, 9, key, str(tmp_path / "a"), checkpoint_every=3)
    tr_b = _chaos_trainer(kill)
    run_with_recovery(tr_b, 9, key, str(tmp_path / "b"), checkpoint_every=3)
    assert len(tr_b.last_trace.flights) == 9
    assert tr_a.last_trace.flights == tr_b.last_trace.flights
