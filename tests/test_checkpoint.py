"""Checkpoint round-trip tests."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpointing import latest_step, restore_checkpoint, save_checkpoint


def test_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6.0).reshape(2, 3)},
            "c": jnp.ones((4,), jnp.bfloat16),
            "step": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 7, tree, extra={"loss": 1.0})
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path))
    np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])
    assert back["c"].dtype == jnp.bfloat16
    assert int(back["step"]) == 7


def test_multiple_steps_latest_wins(tmp_path):
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, {"x": jnp.asarray(float(s))})
    assert latest_step(str(tmp_path)) == 5
    assert float(restore_checkpoint(str(tmp_path))["x"]) == 5.0


def test_train_state_roundtrip(tmp_path):
    from repro.core.fedlite import TrainState
    from repro.models.paper_models import SOTagMLP
    from repro.optim import adagrad
    model = SOTagMLP(bow_dim=64, cut_dim=32, num_tags=16)
    opt = adagrad(0.1)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    save_checkpoint(str(tmp_path), 0, {"params": state.params,
                                       "opt": state.opt_state})
    back = restore_checkpoint(str(tmp_path), 0)
    for a, b in zip(jax.tree.leaves(back["params"]),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(a, b)
