"""Checkpoint round-trip + crash-consistency tests.

The save path is atomic (tmp + os.replace, manifest written last): a
process killed mid-write must leave either the previous committed step
or no step — never a half-written one that restores garbage. These
tests simulate every mid-write crash point by hand-crafting the on-disk
states the real sequence can pass through."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing import (CheckpointError, latest_step,
                                 restore_checkpoint, save_checkpoint,
                                 verify_checkpoint)


def test_roundtrip(tmp_path):
    tree = {"a": {"b": jnp.arange(6.0).reshape(2, 3)},
            "c": jnp.ones((4,), jnp.bfloat16),
            "step": jnp.asarray(7, jnp.int32)}
    save_checkpoint(str(tmp_path), 7, tree, extra={"loss": 1.0})
    assert latest_step(str(tmp_path)) == 7
    back = restore_checkpoint(str(tmp_path))
    np.testing.assert_array_equal(back["a"]["b"], tree["a"]["b"])
    assert back["c"].dtype == jnp.bfloat16
    assert int(back["step"]) == 7


def test_multiple_steps_latest_wins(tmp_path):
    for s in (1, 5, 3):
        save_checkpoint(str(tmp_path), s, {"x": jnp.asarray(float(s))})
    assert latest_step(str(tmp_path)) == 5
    assert float(restore_checkpoint(str(tmp_path))["x"]) == 5.0


# ---------------------------------------------------------------------------
# crash consistency
# ---------------------------------------------------------------------------

def _ckpt(tmp_path, step, value):
    return save_checkpoint(str(tmp_path), step, {"x": jnp.asarray(value)},
                           extra={"v": value})


def test_crash_before_manifest_leaves_step_invisible(tmp_path):
    """Crash after the npz rename but before the manifest: the step was
    never committed — latest_step must keep returning the previous one."""
    _ckpt(tmp_path, 1, 1.0)
    _ckpt(tmp_path, 2, 2.0)                         # the doomed step...
    os.remove(os.path.join(tmp_path, "manifest_00000002.json"))  # ...died
    assert latest_step(str(tmp_path)) == 1
    assert float(restore_checkpoint(str(tmp_path))["x"]) == 1.0


def test_crash_mid_npz_leaves_only_the_tmp_file(tmp_path):
    """Crash during np.savez: only a ``.tmp.npz`` exists. It matches no
    committed pattern, so the directory still reads as empty."""
    with open(os.path.join(tmp_path, "ckpt_00000003.npz.tmp.npz"), "wb") as f:
        f.write(b"half a zip")
    assert latest_step(str(tmp_path)) is None
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path))


def test_truncated_payload_behind_a_manifest_is_rejected(tmp_path):
    """Bit-rot / torn write after commit: the manifest checksum catches a
    truncated npz and restore raises instead of returning garbage."""
    path = _ckpt(tmp_path, 4, 4.0)
    data = open(path, "rb").read()
    with open(path, "wb") as f:
        f.write(data[:len(data) // 2])
    with pytest.raises(CheckpointError, match="checksum mismatch"):
        restore_checkpoint(str(tmp_path), 4)


def test_missing_payload_behind_a_manifest_is_rejected(tmp_path):
    path = _ckpt(tmp_path, 5, 5.0)
    os.remove(path)
    with pytest.raises(CheckpointError, match="missing file"):
        verify_checkpoint(str(tmp_path), 5)


def test_corrupt_manifest_is_rejected(tmp_path):
    _ckpt(tmp_path, 6, 6.0)
    mpath = os.path.join(tmp_path, "manifest_00000006.json")
    with open(mpath, "w") as f:
        f.write("{not json")
    with pytest.raises(CheckpointError, match="unreadable manifest"):
        restore_checkpoint(str(tmp_path), 6)


def test_meta_json_is_covered_by_the_manifest(tmp_path):
    """The extra/meta sidecar is named in the manifest too: flipping one
    byte of it fails verification."""
    _ckpt(tmp_path, 7, 7.0)
    mpath = os.path.join(tmp_path, "meta_00000007.json")
    meta = json.load(open(mpath))
    meta["v"] = 999.0
    with open(mpath, "w") as f:
        json.dump(meta, f)
    with pytest.raises(CheckpointError, match="checksum mismatch"):
        verify_checkpoint(str(tmp_path), 7)


def test_legacy_bare_npz_still_restores(tmp_path):
    """Pre-manifest checkpoints (bare npz, no manifest) keep working:
    latest_step falls back and verify is a no-op without a manifest."""
    path = _ckpt(tmp_path, 8, 8.0)
    os.remove(os.path.join(tmp_path, "manifest_00000008.json"))
    os.rename(path, os.path.join(tmp_path, "ckpt_00000009.npz"))
    assert latest_step(str(tmp_path)) == 9
    assert float(restore_checkpoint(str(tmp_path))["x"]) == 8.0


def test_manifest_steps_take_priority_over_bare_npz(tmp_path):
    """A stray newer bare npz (e.g. an interrupted foreign write) must not
    outrank the newest *committed* step."""
    _ckpt(tmp_path, 1, 1.0)
    with open(os.path.join(tmp_path, "ckpt_00000099.npz"), "wb") as f:
        f.write(b"garbage")
    assert latest_step(str(tmp_path)) == 1


def test_train_state_roundtrip(tmp_path):
    from repro.core.fedlite import TrainState
    from repro.models.paper_models import SOTagMLP
    from repro.optim import adagrad
    model = SOTagMLP(bow_dim=64, cut_dim=32, num_tags=16)
    opt = adagrad(0.1)
    state = TrainState.create(model.init(jax.random.PRNGKey(0)), opt)
    save_checkpoint(str(tmp_path), 0, {"params": state.params,
                                       "opt": state.opt_state})
    back = restore_checkpoint(str(tmp_path), 0)
    for a, b in zip(jax.tree.leaves(back["params"]),
                    jax.tree.leaves(state.params)):
        np.testing.assert_array_equal(a, b)
